#!/usr/bin/env python3
"""Unit tests for bench_diff.py — stdlib only, no Rust toolchain needed.

Run from the repo root (or anywhere):

    python3 scripts/test_bench_diff.py
"""

import contextlib
import io
import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import bench_diff  # noqa: E402


def doc(arms, schema=1):
    return {
        "schema": schema,
        "budget_ms": 100,
        "results": [
            {"name": name, "iters": 10, "median_ns": med, "p10_ns": med, "p90_ns": med}
            for name, med in arms.items()
        ],
    }


class BenchDiffTest(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.addCleanup(self.dir.cleanup)

    def write(self, name, payload):
        path = os.path.join(self.dir.name, name)
        with open(path, "w") as f:
            if isinstance(payload, str):
                f.write(payload)
            else:
                json.dump(payload, f)
        return path

    def run_main(self, baseline, fresh, extra=()):
        argv = ["bench_diff.py", baseline, fresh, *extra]
        out, err = io.StringIO(), io.StringIO()
        old = sys.argv
        sys.argv = argv
        try:
            with contextlib.redirect_stdout(out), contextlib.redirect_stderr(err):
                code = bench_diff.main()
        finally:
            sys.argv = old
        return code, out.getvalue(), err.getvalue()

    def test_within_threshold_passes(self):
        base = self.write("base.json", doc({"fold": 100.0, "encode": 200.0}))
        fresh = self.write("fresh.json", doc({"fold": 140.0, "encode": 150.0}))
        code, out, _ = self.run_main(base, fresh)
        self.assertEqual(code, 0)
        self.assertIn("2 shared arm(s) within 1.5x", out)
        self.assertNotIn("REGRESSION", out)

    def test_exactly_at_threshold_is_not_a_regression(self):
        # the gate is strictly greater-than, so 1.5x on the nose passes
        base = self.write("base.json", doc({"fold": 100.0}))
        fresh = self.write("fresh.json", doc({"fold": 150.0}))
        code, out, _ = self.run_main(base, fresh)
        self.assertEqual(code, 0)
        self.assertNotIn("REGRESSION", out)

    def test_past_threshold_fails_and_names_the_arm(self):
        base = self.write("base.json", doc({"fold": 100.0, "encode": 50.0}))
        fresh = self.write("fresh.json", doc({"fold": 151.0, "encode": 50.0}))
        code, out, err = self.run_main(base, fresh)
        self.assertEqual(code, 1)
        self.assertIn("REGRESSION", out)
        self.assertIn("fold", err)
        self.assertNotIn("encode", err)

    def test_custom_threshold_is_respected(self):
        base = self.write("base.json", doc({"fold": 100.0}))
        fresh = self.write("fresh.json", doc({"fold": 250.0}))
        code, _, _ = self.run_main(base, fresh, extra=["--threshold", "3.0"])
        self.assertEqual(code, 0)

    def test_zero_baseline_median_counts_as_regression(self):
        base = self.write("base.json", doc({"fold": 0.0}))
        fresh = self.write("fresh.json", doc({"fold": 1.0}))
        code, out, _ = self.run_main(base, fresh)
        self.assertEqual(code, 1)
        self.assertIn("REGRESSION", out)

    def test_new_and_retired_arms_report_but_never_gate(self):
        base = self.write("base.json", doc({"fold": 100.0, "old_arm": 10.0}))
        fresh = self.write("fresh.json", doc({"fold": 100.0, "new_arm": 10.0}))
        code, out, _ = self.run_main(base, fresh)
        self.assertEqual(code, 0)
        self.assertIn("new arm", out)
        self.assertIn("new_arm", out)
        self.assertIn("retired", out)
        self.assertIn("old_arm", out)

    def test_unknown_schema_is_rejected(self):
        base = self.write("base.json", doc({"fold": 100.0}, schema=2))
        fresh = self.write("fresh.json", doc({"fold": 100.0}))
        with self.assertRaises(SystemExit) as ctx:
            self.run_main(base, fresh)
        self.assertIn("unknown bench schema", str(ctx.exception))

    def test_malformed_json_raises(self):
        base = self.write("base.json", "{not json")
        fresh = self.write("fresh.json", doc({"fold": 100.0}))
        with self.assertRaises(json.JSONDecodeError):
            self.run_main(base, fresh)


if __name__ == "__main__":
    unittest.main()
