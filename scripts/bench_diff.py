#!/usr/bin/env python3
"""Median-diff gate over the BENCH_*.json perf trajectories.

Usage: bench_diff.py BASELINE.json FRESH.json [--threshold 1.5]

Compares per-arm `median_ns` between a committed baseline and a fresh
run of the same bench binary (schema: src/util/bench.rs `write_json` —
{"schema": 1, "budget_ms": ..., "results": [{"name", "iters",
"median_ns", "p10_ns", "p90_ns"}]}). Arms present in only one file are
reported but never gate (new arms land without a baseline; retired arms
leave one behind). Exits non-zero iff any shared arm's fresh median
exceeds threshold x its baseline median.

The default threshold is deliberately loose (1.5x): shared CI runners
are noisy and the p10/p90 spread in the trajectory files regularly
brackets +/-20%. This gate exists to catch order-of-magnitude cliffs
(an accidental O(n^2), a lost fast path), not single-digit drift — the
committed trajectory itself is the fine-grained record.

Stdlib only; no third-party dependencies.
"""

import argparse
import json
import sys


def medians(path):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != 1:
        sys.exit(f"{path}: unknown bench schema {doc.get('schema')!r}")
    return {m["name"]: float(m["median_ns"]) for m in doc["results"]}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--threshold", type=float, default=1.5)
    args = ap.parse_args()

    base = medians(args.baseline)
    fresh = medians(args.fresh)
    shared = sorted(base.keys() & fresh.keys())
    regressions = []
    for name in shared:
        ratio = fresh[name] / base[name] if base[name] > 0 else float("inf")
        marker = "REGRESSION" if ratio > args.threshold else "ok"
        print(f"{marker:>10}  {ratio:6.2f}x  {name}")
        if ratio > args.threshold:
            regressions.append(name)
    for name in sorted(fresh.keys() - base.keys()):
        print(f"{'new arm':>10}  {'-':>7}  {name}")
    for name in sorted(base.keys() - fresh.keys()):
        print(f"{'retired':>10}  {'-':>7}  {name}")

    if regressions:
        print(
            f"\n{len(regressions)} arm(s) regressed past "
            f"{args.threshold}x: {', '.join(regressions)}",
            file=sys.stderr,
        )
        return 1
    print(f"\n{len(shared)} shared arm(s) within {args.threshold}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
