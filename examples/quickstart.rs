//! Quickstart: the smallest complete fedmask run.
//!
//! Trains LeNet federated across 4 simulated clients for 3 rounds with
//! dynamic sampling + selective masking, then prints the accuracy and the
//! communication spend.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use fedmask::config::experiment::ExperimentConfig;
use fedmask::fl::masking::MaskPolicy;
use fedmask::fl::sampling::SamplingSchedule;
use fedmask::fl::server::Server;
use fedmask::runtime::manifest::Manifest;

fn main() -> fedmask::Result<()> {
    fedmask::util::logging::init();

    // 1. Load the AOT artifacts (HLO text + manifest) produced by python.
    let manifest = Manifest::load("artifacts")?;

    // 2. Describe the experiment. Everything is seeded => reproducible.
    let mut cfg = ExperimentConfig::defaults("lenet")?;
    cfg.label = "quickstart".into();
    cfg.clients = 4;
    cfg.rounds = 3;
    cfg.n_train = 1_024;
    cfg.n_test = 512;
    cfg.sampling = SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.1 };
    cfg.min_clients = 2;
    cfg.masking = MaskPolicy::selective(0.3); // keep top-30% |delta|
    cfg.eval_max_chunks = 2;

    // 3. Run. The server loads the PJRT engine pool, partitions data IID,
    //    and drives sample -> train -> mask -> aggregate each round.
    let outcome = Server::new(cfg, &manifest)?.run()?;

    // 4. Inspect.
    println!("{}", outcome.recorder.summary());
    for r in &outcome.recorder.rounds {
        println!(
            "round {}: {} clients, rate {:.2}, accuracy {:.3}, cumulative cost {:.2} model-units",
            r.round, r.clients, r.sample_rate, r.test_accuracy, r.uplink_units
        );
    }
    Ok(())
}
