//! WikiText/GRU scenario (paper §5.3): private language modeling with
//! tied-embedding GRU clients — the mobile-keyboard next-word use case the
//! paper motivates. Prints the perplexity trajectory under dynamic
//! sampling + selective masking vs the dense static baseline.

use std::sync::Arc;

use fedmask::config::experiment::ExperimentConfig;
use fedmask::fl::masking::MaskPolicy;
use fedmask::fl::sampling::SamplingSchedule;
use fedmask::fl::server::Server;
use fedmask::runtime::manifest::Manifest;
use fedmask::runtime::pool::EnginePool;

fn main() -> fedmask::Result<()> {
    fedmask::util::logging::init();
    let manifest = Manifest::load("artifacts")?;
    let rounds: usize = std::env::var("FEDMASK_ROUNDS").ok().and_then(|v| v.parse().ok()).unwrap_or(8);
    let pool = Arc::new(EnginePool::new(&manifest, &["gru"], 6)?);

    let mut runs = Vec::new();
    for (label, sampling, masking) in [
        ("static+dense", SamplingSchedule::Static { c0: 1.0 }, MaskPolicy::None),
        (
            "dynamic+selective",
            SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.2 },
            MaskPolicy::selective(0.5),
        ),
    ] {
        let mut cfg = ExperimentConfig::defaults("gru")?;
        cfg.label = label.into();
        cfg.clients = 8;
        cfg.rounds = rounds;
        cfg.min_clients = sampling.default_min_clients();
        cfg.sampling = sampling;
        cfg.masking = masking;
        cfg.eval_every = 1; // trajectory
        let out = Server::with_pool(cfg, &manifest, Arc::clone(&pool))?.run()?;
        runs.push(out);
    }

    println!("\nperplexity trajectory (vocab = {}):", manifest.model("gru")?.vocab().unwrap());
    println!("{:<7} {:>18} {:>22}", "round", "static+dense", "dynamic+selective");
    for t in 0..rounds {
        println!(
            "{:<7} {:>18.2} {:>22.2}",
            t + 1,
            runs[0].recorder.rounds[t].test_perplexity,
            runs[1].recorder.rounds[t].test_perplexity,
        );
    }
    println!(
        "\ncost: static+dense {:.1} units vs dynamic+selective {:.1} units ({:.1}% saved)",
        runs[0].ledger.uplink_units,
        runs[1].ledger.uplink_units,
        100.0 * (1.0 - runs[1].ledger.uplink_units / runs[0].ledger.uplink_units)
    );
    Ok(())
}
