//! MNIST/LeNet scenario (paper §5.2): head-to-head of the four policy
//! combinations on the image-classification task.
//!
//!   static + dense          (vanilla FedAvg, Alg. 1)
//!   dynamic + dense         (paper contribution 1, Alg. 3)
//!   static + selective      (paper contribution 2, Alg. 4)
//!   dynamic + selective     (both combined, §5.2.3)
//!
//! Prints a final table of accuracy vs communication cost — the trade-off
//! the whole paper is about. Knobs via env: FEDMASK_ROUNDS, FEDMASK_CLIENTS.

use std::sync::Arc;

use fedmask::config::experiment::ExperimentConfig;
use fedmask::fl::masking::MaskPolicy;
use fedmask::fl::sampling::SamplingSchedule;
use fedmask::fl::server::Server;
use fedmask::runtime::manifest::Manifest;
use fedmask::runtime::pool::EnginePool;

fn env_or(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> fedmask::Result<()> {
    fedmask::util::logging::init();
    let manifest = Manifest::load("artifacts")?;
    let rounds = env_or("FEDMASK_ROUNDS", 15);
    let clients = env_or("FEDMASK_CLIENTS", 10);
    let pool = Arc::new(EnginePool::new(&manifest, &["lenet"], 6)?);

    let dynamic = SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.1 };
    let settings: [(&str, SamplingSchedule, MaskPolicy); 4] = [
        ("static+dense", SamplingSchedule::Static { c0: 1.0 }, MaskPolicy::None),
        ("dynamic+dense", dynamic.clone(), MaskPolicy::None),
        ("static+selective", SamplingSchedule::Static { c0: 1.0 }, MaskPolicy::selective(0.3)),
        ("dynamic+selective", dynamic, MaskPolicy::selective(0.3)),
    ];

    println!("{:<20} {:>9} {:>14} {:>14}", "setting", "accuracy", "cost(units)", "uplink(KiB)");
    for (label, sampling, masking) in settings {
        let mut cfg = ExperimentConfig::defaults("lenet")?;
        cfg.label = label.into();
        cfg.clients = clients;
        cfg.rounds = rounds;
        cfg.min_clients = sampling.default_min_clients();
        cfg.sampling = sampling;
        cfg.masking = masking;
        cfg.eval_every = rounds;
        let out = Server::with_pool(cfg, &manifest, Arc::clone(&pool))?.run()?;
        println!(
            "{:<20} {:>9.4} {:>14.2} {:>14.1}",
            label,
            out.recorder.final_accuracy(),
            out.ledger.uplink_units,
            out.ledger.uplink_bytes as f64 / 1024.0
        );
    }
    Ok(())
}
