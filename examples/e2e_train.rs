//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Exercises the full three-layer stack on a real training workload:
//! federated training of the GRU language model (155k params, the paper's
//! §5.3 task) across 10 simulated devices for a sustained run, with BOTH
//! paper techniques enabled — dynamic sampling (beta = 0.1) and selective
//! top-k masking (gamma = 0.3) — plus the simulated network for virtual
//! wall-clock accounting. Logs the loss curve every round and finishes
//! with a dense static baseline comparison.
//!
//! Layers proven composed: L3 rust coordinator (this binary) -> PJRT
//! runtime -> L2 JAX train/eval artifacts -> L1 Pallas selective-mask
//! kernel (inside {gru}_mask.hlo.txt).
//!
//! FEDMASK_ROUNDS overrides the default 25-round horizon.

use std::sync::Arc;
use std::time::Instant;

use fedmask::config::experiment::{ExperimentConfig, NetworkKind};
use fedmask::fl::masking::MaskPolicy;
use fedmask::fl::sampling::SamplingSchedule;
use fedmask::fl::server::Server;
use fedmask::runtime::manifest::Manifest;
use fedmask::runtime::pool::EnginePool;

fn main() -> fedmask::Result<()> {
    fedmask::util::logging::init();
    let manifest = Manifest::load("artifacts")?;
    let rounds: usize = std::env::var("FEDMASK_ROUNDS").ok().and_then(|v| v.parse().ok()).unwrap_or(25);
    let pool = Arc::new(EnginePool::new(&manifest, &["gru"], 6)?);

    let build = |label: &str, sampling: SamplingSchedule, masking: MaskPolicy| {
        let mut cfg = ExperimentConfig::defaults("gru").unwrap();
        cfg.label = label.into();
        cfg.clients = 10;
        cfg.rounds = rounds;
        cfg.min_clients = sampling.default_min_clients();
        cfg.sampling = sampling;
        cfg.masking = masking;
        cfg.network = NetworkKind::Simulated;
        cfg.eval_every = 1;
        cfg
    };

    let wall = Instant::now();
    println!("=== e2e: dynamic sampling (beta=0.1) + selective masking (gamma=0.3), GRU LM ===");
    let cfg = build(
        "e2e-dynamic-selective",
        SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.1 },
        MaskPolicy::selective(0.3),
    );
    let out = Server::with_pool(cfg, &manifest, Arc::clone(&pool))?.run()?;
    println!(
        "{:<7} {:>8} {:>10} {:>12} {:>12} {:>12} {:>12}",
        "round", "clients", "rate", "train_loss", "test_ppl", "cost_units", "vtime_s"
    );
    for r in &out.recorder.rounds {
        println!(
            "{:<7} {:>8} {:>10.3} {:>12.4} {:>12.2} {:>12.2} {:>12.2}",
            r.round, r.clients, r.sample_rate, r.train_loss, r.test_perplexity, r.uplink_units, r.virtual_time_s
        );
    }

    println!("\n=== baseline: static sampling + dense uploads ===");
    let base_cfg = build("e2e-baseline", SamplingSchedule::Static { c0: 1.0 }, MaskPolicy::None);
    let base = Server::with_pool(base_cfg, &manifest, pool)?.run()?;

    let (ours, theirs) = (out.recorder.last_evaluated().unwrap(), base.recorder.last_evaluated().unwrap());
    println!("\n=== summary after {rounds} rounds ===");
    println!(
        "dynamic+selective: ppl {:.2}, cost {:.1} units, {} uplink bytes, virtual time {:.1}s",
        ours.test_perplexity, out.ledger.uplink_units, out.ledger.uplink_bytes, ours.virtual_time_s
    );
    println!(
        "static+dense     : ppl {:.2}, cost {:.1} units, {} uplink bytes, virtual time {:.1}s",
        theirs.test_perplexity, base.ledger.uplink_units, base.ledger.uplink_bytes, theirs.virtual_time_s
    );
    println!(
        "communication saved: {:.1}% units / {:.1}% bytes; perplexity gap {:+.2}",
        100.0 * (1.0 - out.ledger.uplink_units / base.ledger.uplink_units),
        100.0 * (1.0 - out.ledger.uplink_bytes as f64 / base.ledger.uplink_bytes as f64),
        ours.test_perplexity - theirs.test_perplexity,
    );
    println!("real wall time: {:.1}s", wall.elapsed().as_secs_f64());
    Ok(())
}
