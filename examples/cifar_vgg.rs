//! CIFAR-10/VGG scenario (paper §5.2.4): masking-policy comparison on the
//! large conv model, reporting accuracy and the byte-level saving of
//! shipping sparse masked updates.
//!
//! Knobs via env: FEDMASK_ROUNDS, FEDMASK_CLIENTS, FEDMASK_GAMMAS (csv).

use std::sync::Arc;

use fedmask::config::experiment::ExperimentConfig;
use fedmask::fl::masking::MaskPolicy;
use fedmask::fl::server::Server;
use fedmask::runtime::manifest::Manifest;
use fedmask::runtime::pool::EnginePool;

fn main() -> fedmask::Result<()> {
    fedmask::util::logging::init();
    let manifest = Manifest::load("artifacts")?;
    let rounds: usize = std::env::var("FEDMASK_ROUNDS").ok().and_then(|v| v.parse().ok()).unwrap_or(6);
    let clients: usize = std::env::var("FEDMASK_CLIENTS").ok().and_then(|v| v.parse().ok()).unwrap_or(6);
    let gammas: Vec<f32> = std::env::var("FEDMASK_GAMMAS")
        .map(|s| s.split(',').filter_map(|g| g.parse().ok()).collect())
        .unwrap_or_else(|_| vec![0.2, 0.6]);
    let pool = Arc::new(EnginePool::new(&manifest, &["vggmini"], 6)?);

    let p = manifest.model("vggmini")?.p;
    println!("VGG-mini: P = {p} parameters; dense upload = {:.1} KiB", (4 * p) as f64 / 1024.0);
    println!("{:<24} {:>9} {:>12} {:>16}", "setting", "accuracy", "cost(units)", "mean KiB/upload");
    for &gamma in &gammas {
        for policy in [MaskPolicy::random(gamma), MaskPolicy::selective(gamma)] {
            let mut cfg = ExperimentConfig::defaults("vggmini")?;
            cfg.label = format!("cifar-{}", policy.label());
            cfg.clients = clients;
            cfg.rounds = rounds;
            cfg.masking = policy;
            cfg.eval_every = rounds;
            let out = Server::with_pool(cfg, &manifest, Arc::clone(&pool))?.run()?;
            let uploads = out.ledger.messages as f64 / 2.0;
            println!(
                "{:<24} {:>9.4} {:>12.2} {:>16.1}",
                cfg_label(&policy, gamma),
                out.recorder.final_accuracy(),
                out.ledger.uplink_units,
                out.ledger.uplink_bytes as f64 / 1024.0 / uploads,
            );
        }
    }
    Ok(())
}

fn cfg_label(policy: &MaskPolicy, gamma: f32) -> String {
    match policy {
        MaskPolicy::Random { .. } => format!("random gamma={gamma}"),
        MaskPolicy::Selective { .. } => format!("selective gamma={gamma}"),
        MaskPolicy::None => "dense".into(),
    }
}
