//! Transport bench: codec encode/decode at model sizes across densities
//! (the wire work per upload), per-encoding byte + latency measurements
//! (dense / sparse / delta+varint / q8 / q4), plus raw quantizer
//! throughput. Establishes that transport never dominates a round
//! (DESIGN.md §6 L3 target), and pits the bulk `chunks_exact` decoder
//! against the seed's per-element cursor loop (`scalar_decode`, kept here
//! as the baseline) and the owned decode against the scratch-reusing
//! borrowed view.
//!
//! Writes BENCH_transport.json at the repo root (the perf trajectory).
//!
//! Run: cargo bench --bench transport

use std::time::Duration;

use fedmask::sim::rng::Rng;
use fedmask::transport::codec::{
    decode_update, decode_update_view, encode_update, wire_bytes, DecodeScratch, Encoding,
};
use fedmask::transport::link::{Transport, TransportKind};
use fedmask::transport::quantize::{dequantize, dequantize4, quantize, quantize4};
use fedmask::transport::socket::{ClientConn, Loopback, WireAddr};
use fedmask::util::bench::Bench;

/// The seed decoder, preserved as a baseline: per-element cursor reads
/// (`take::<4>`-style) and unconditional densification. Supports the dense
/// and sparse f32 tags, which is all the Auto encoding emits.
fn scalar_decode(data: &[u8]) -> Vec<f32> {
    fn take<const N: usize>(data: &[u8], at: &mut usize) -> [u8; N] {
        let s: [u8; N] = data[*at..*at + N].try_into().unwrap();
        *at += N;
        s
    }
    let mut at = 0usize;
    let _magic = u16::from_le_bytes(take::<2>(data, &mut at));
    let _version = take::<1>(data, &mut at)[0];
    let tag = take::<1>(data, &mut at)[0];
    let _client = u32::from_le_bytes(take::<4>(data, &mut at));
    let _round = u32::from_le_bytes(take::<4>(data, &mut at));
    let _n = u32::from_le_bytes(take::<4>(data, &mut at));
    let p = u32::from_le_bytes(take::<4>(data, &mut at)) as usize;
    let count = u32::from_le_bytes(take::<4>(data, &mut at)) as usize;
    let mut params = vec![0.0f32; p];
    match tag {
        0 => {
            for slot in params.iter_mut() {
                *slot = f32::from_le_bytes(take::<4>(data, &mut at));
            }
        }
        1 => {
            for _ in 0..count {
                let idx = u32::from_le_bytes(take::<4>(data, &mut at)) as usize;
                let val = f32::from_le_bytes(take::<4>(data, &mut at));
                params[idx] = val;
            }
        }
        other => panic!("scalar_decode: unsupported tag {other}"),
    }
    params
}

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(11);
    println!("== wire codec (bulk vs scalar, owned vs view) ==");
    for (model, p) in [("lenet", 20_522usize), ("vggmini", 51_666)] {
        for density in [1.0f32, 0.5, 0.1, 0.01] {
            let params: Vec<f32> = (0..p)
                .map(|_| if rng.next_f32() < density { rng.next_normal() } else { 0.0 })
                .collect();
            let m = b.run(&format!("encode/{model}/density={density}"), || {
                encode_update(1, 1, 100, &params, Encoding::Auto)
            });
            println!("{}", m.report(Some((p as f64, "param"))));
            // the scalar baseline predates the entropy-coded tags: feed it
            // the flat dense/sparse representation it understands
            let nnz = params.iter().filter(|v| **v != 0.0).count();
            let flat = if 8 * nnz < 4 * p { Encoding::Sparse } else { Encoding::Dense };
            let encoded = encode_update(1, 1, 100, &params, flat);

            let m = b.run(&format!("decode_scalar/{model}/density={density}"), || {
                scalar_decode(&encoded)
            });
            println!("{}", m.report(Some((p as f64, "param"))));

            let m = b.run(&format!("decode_owned/{model}/density={density}"), || {
                decode_update(&encoded).unwrap()
            });
            println!("{}", m.report(Some((p as f64, "param"))));

            let mut scratch = DecodeScratch::default();
            let m = b.run(&format!("decode_view/{model}/density={density}"), || {
                decode_update_view(&encoded, &mut scratch).unwrap().n_samples
            });
            println!("{}", m.report(Some((p as f64, "param"))));
        }
    }

    // Per-encoding wire cost + latency at masked densities: the byte
    // numbers land in the bench trajectory (iters-invariant, so the
    // *_bytes measurements are comparable across machines) alongside the
    // encode/decode latency of each tag family.
    println!("== per-encoding wire bytes + encode/decode latency ==");
    let p = 51_666usize; // vggmini P
    for density in [0.1f32, 0.01] {
        let params: Vec<f32> = (0..p)
            .map(|_| if rng.next_f32() < density { rng.next_normal() } else { 0.0 })
            .collect();
        let nnz = params.iter().filter(|v| **v != 0.0).count();
        for &enc in Encoding::ALL {
            let tag = format!("{}/density={density}", enc.as_str());
            let encoded = encode_update(1, 1, 100, &params, enc);
            println!(
                "  {tag}: {} bytes ({:.2} bytes/nnz, bound {})",
                encoded.len(),
                encoded.len() as f64 / nnz.max(1) as f64,
                wire_bytes(p, nnz, enc),
            );
            let m = b.run(&format!("encode_enc/{tag}"), || {
                encode_update(1, 1, 100, &params, enc)
            });
            println!("{}", m.report(Some((p as f64, "param"))));
            let mut scratch = DecodeScratch::default();
            let m = b.run(&format!("decode_enc/{tag}"), || {
                decode_update_view(&encoded, &mut scratch).unwrap().n_samples
            });
            println!("{}", m.report(Some((p as f64, "param"))));
        }
    }

    // Many-client fan-in over real sockets: 64 persistent authenticated
    // sessions vs. a fresh connection + handshake per upload — the number
    // behind the scaling claim that connect-per-upload does not survive
    // fleet growth. Gated like the socket test suite (sealed sandboxes
    // have no loopback TCP).
    if std::env::var("FEDMASK_SOCKET_TESTS").map(|v| v == "1" || v == "true").unwrap_or(false) {
        println!("== 64-client fan-in: persistent sessions vs session-per-upload ==");
        let n = 64usize;
        let p = 2_000usize;
        let payloads: Vec<Vec<u8>> = (0..n)
            .map(|c| {
                let params: Vec<f32> = (0..p)
                    .map(|_| if rng.next_f32() < 0.1 { rng.next_normal() } else { 0.0 })
                    .collect();
                encode_update(c as u32, 1, 100, &params, Encoding::Auto)
            })
            .collect();
        let total_bytes: usize = payloads.iter().map(Vec::len).sum();
        println!("  {n} uploads, {total_bytes} bytes total per fan-in");

        // Persistent: the run-long sessions the transport actually uses —
        // register (connect + handshake) once, then every iteration ships
        // the whole cohort through the live connections and drains it.
        let mut server = Loopback::bind(TransportKind::Tcp).unwrap();
        server.set_timeout(Duration::from_secs(30));
        let ids: Vec<u32> = (0..n as u32).collect();
        server.register_clients(&ids).unwrap();
        let sink = server.sink();
        let m = b.run("fanin64/persistent_sessions", || {
            for pl in &payloads {
                sink.send(pl.clone()).unwrap();
            }
            for _ in 0..n {
                server.recv().unwrap();
            }
        });
        println!("{}", m.report(Some((n as f64, "upload"))));

        // Session-per-upload: the pre-refactor shape — every message pays
        // a connect + hello/welcome handshake + teardown. Reconnecting a
        // just-closed id can race the server's EOF processing, so the
        // client retries briefly (as a real reconnecting client would).
        let mut server2 = Loopback::bind(TransportKind::Tcp).unwrap();
        server2.set_timeout(Duration::from_secs(30));
        // open the registration window without holding sessions ourselves:
        // each upload opens (and tears down) its own
        server2.allow_clients(&ids).unwrap();
        let addr = server2.addr().clone();
        let connect_retry = |addr: &WireAddr, c: u32| -> ClientConn {
            for _ in 0..500 {
                match ClientConn::connect(addr, c) {
                    Ok(conn) => return conn,
                    Err(_) => std::thread::sleep(Duration::from_micros(200)),
                }
            }
            panic!("could not re-establish a session for client {c}")
        };
        let m = b.run("fanin64/session_per_upload", || {
            for (c, pl) in payloads.iter().enumerate() {
                let conn = connect_retry(&addr, c as u32);
                conn.upload(pl).unwrap();
                drop(conn);
            }
            for _ in 0..n {
                server2.recv().unwrap();
            }
        });
        println!("{}", m.report(Some((n as f64, "upload"))));
    } else {
        println!("== 64-client fan-in skipped (set FEDMASK_SOCKET_TESTS=1 to enable) ==");
    }

    println!("== 8-bit / 4-bit quantization (compression extension) ==");
    let params: Vec<f32> = (0..51_666).map(|_| rng.next_normal()).collect();
    let m = b.run("quantize/vggmini", || quantize(&params).unwrap());
    println!("{}", m.report(Some((51_666f64, "param"))));
    let q = quantize(&params).unwrap();
    let m = b.run("dequantize/vggmini", || dequantize(&q));
    println!("{}", m.report(Some((51_666f64, "param"))));
    let m = b.run("quantize4/vggmini", || quantize4(&params).unwrap());
    println!("{}", m.report(Some((51_666f64, "param"))));
    let q4 = quantize4(&params).unwrap();
    let m = b.run("dequantize4/vggmini", || dequantize4(&q4));
    println!("{}", m.report(Some((51_666f64, "param"))));

    b.write_trajectory("BENCH_transport.json");
}
