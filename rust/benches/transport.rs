//! Transport bench: codec encode/decode at model sizes across densities
//! (the wire work per upload), per-encoding byte + latency measurements
//! (dense / sparse / delta+varint / q8 / q4), raw quantizer throughput,
//! the sharded tree fold vs the single-threaded fold at 1k–10k simulated
//! clients, and — when sockets are enabled — many-client fan-in over the
//! reactor vs both a session-per-upload shape and a minimal
//! thread-per-connection baseline server. Establishes that transport
//! never dominates a round (DESIGN.md §6 L3 target), and pits the bulk
//! `chunks_exact` decoder against the seed's per-element cursor loop
//! (`scalar_decode`, kept here as the baseline) and the owned decode
//! against the scratch-reusing borrowed view.
//!
//! Writes BENCH_transport.json at the repo root (the perf trajectory).
//!
//! Run: cargo bench --bench transport

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use fedmask::fl::aggregate::{Aggregator, Contribution, SparseContribution, StreamingFedAvg};
use fedmask::fl::ShardedAggregator;
use fedmask::sim::rng::Rng;
use fedmask::transport::codec::{
    decode_update, decode_update_cached, decode_update_view, encode_update, encode_update_cached,
    peek_client, wire_bytes, BodyView, DecodeScratch, Encoding,
};
use fedmask::transport::frame::{write_frame, FrameKind, FrameStream};
use fedmask::transport::link::{Transport, TransportKind};
use fedmask::transport::quantize::{dequantize, dequantize4, quantize, quantize4};
use fedmask::transport::session::IndexCache;
use fedmask::transport::socket::{ClientConn, Loopback, WireAddr};
use fedmask::util::bench::Bench;

/// Re-establishing a just-closed client id can race the server's EOF
/// processing (the session is still live until the reactor scans the
/// close), so fan-in clients retry briefly — as a real reconnecting
/// client would.
fn connect_retry(addr: &WireAddr, c: u32) -> ClientConn {
    for _ in 0..2_000 {
        match ClientConn::connect(addr, c) {
            Ok(conn) => return conn,
            Err(_) => std::thread::sleep(Duration::from_micros(200)),
        }
    }
    panic!("could not establish a session for client {c}")
}

/// Wave-structured fan-in driver: `workers` client threads stride the id
/// space, each cycling connect → handshake → upload → disconnect, so at
/// most `workers` sockets are live at once — a 1k-client fleet fans in
/// without tripping the default fd rlimit. Returns the running handles;
/// the caller drains the server concurrently, then joins.
fn drive_waves(
    addr: &WireAddr,
    payloads: &Arc<Vec<Vec<u8>>>,
    workers: usize,
) -> Vec<std::thread::JoinHandle<()>> {
    (0..workers)
        .map(|w| {
            let addr = addr.clone();
            let payloads = Arc::clone(payloads);
            std::thread::spawn(move || {
                let mut c = w;
                while c < payloads.len() {
                    let conn = connect_retry(&addr, c as u32);
                    conn.upload(&payloads[c]).unwrap();
                    drop(conn);
                    c += workers;
                }
            })
        })
        .collect()
}

/// The pre-reactor server shape, kept as an in-bench baseline: blocking
/// accept loop, one OS thread per accepted connection, hello → welcome →
/// uploads into a channel. It speaks the real frame grammar (so
/// `ClientConn` runs against it unchanged) but skips the session table,
/// token checks, and admission control entirely — every simplification
/// biases the comparison in its favor, and it still pays a thread spawn
/// plus stack per connection.
fn thread_per_conn_server(
    listener: std::net::TcpListener,
    uploads: std::sync::mpsc::Sender<Vec<u8>>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        while !stop.load(Ordering::SeqCst) {
            let Ok((mut stream, _)) = listener.accept() else { break };
            let uploads = uploads.clone();
            // detached: each worker exits when its peer disconnects
            std::thread::spawn(move || {
                let mut frames = FrameStream::new();
                match frames.next(&mut stream) {
                    Ok(Some(f)) if f.kind == FrameKind::Hello => {
                        if write_frame(&mut stream, FrameKind::Welcome, 1, &[]).is_err() {
                            return;
                        }
                        use std::io::Write as _;
                        if stream.flush().is_err() {
                            return;
                        }
                    }
                    _ => return,
                }
                while let Ok(Some(f)) = frames.next(&mut stream) {
                    if f.kind == FrameKind::Upload && uploads.send(f.payload).is_err() {
                        return;
                    }
                }
            });
        }
    })
}

/// The seed decoder, preserved as a baseline: per-element cursor reads
/// (`take::<4>`-style) and unconditional densification. Supports the dense
/// and sparse f32 tags, which is all the Auto encoding emits.
fn scalar_decode(data: &[u8]) -> Vec<f32> {
    fn take<const N: usize>(data: &[u8], at: &mut usize) -> [u8; N] {
        let s: [u8; N] = data[*at..*at + N].try_into().unwrap();
        *at += N;
        s
    }
    let mut at = 0usize;
    let _magic = u16::from_le_bytes(take::<2>(data, &mut at));
    let _version = take::<1>(data, &mut at)[0];
    let tag = take::<1>(data, &mut at)[0];
    let _client = u32::from_le_bytes(take::<4>(data, &mut at));
    let _round = u32::from_le_bytes(take::<4>(data, &mut at));
    let _n = u32::from_le_bytes(take::<4>(data, &mut at));
    let p = u32::from_le_bytes(take::<4>(data, &mut at)) as usize;
    let count = u32::from_le_bytes(take::<4>(data, &mut at)) as usize;
    let mut params = vec![0.0f32; p];
    match tag {
        0 => {
            for slot in params.iter_mut() {
                *slot = f32::from_le_bytes(take::<4>(data, &mut at));
            }
        }
        1 => {
            for _ in 0..count {
                let idx = u32::from_le_bytes(take::<4>(data, &mut at)) as usize;
                let val = f32::from_le_bytes(take::<4>(data, &mut at));
                params[idx] = val;
            }
        }
        other => panic!("scalar_decode: unsupported tag {other}"),
    }
    params
}

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(11);
    println!("== wire codec (bulk vs scalar, owned vs view) ==");
    for (model, p) in [("lenet", 20_522usize), ("vggmini", 51_666)] {
        for density in [1.0f32, 0.5, 0.1, 0.01] {
            let params: Vec<f32> = (0..p)
                .map(|_| if rng.next_f32() < density { rng.next_normal() } else { 0.0 })
                .collect();
            let m = b.run(&format!("encode/{model}/density={density}"), || {
                encode_update(1, 1, 100, &params, Encoding::Auto)
            });
            println!("{}", m.report(Some((p as f64, "param"))));
            // the scalar baseline predates the entropy-coded tags: feed it
            // the flat dense/sparse representation it understands
            let nnz = params.iter().filter(|v| **v != 0.0).count();
            let flat = if 8 * nnz < 4 * p { Encoding::Sparse } else { Encoding::Dense };
            let encoded = encode_update(1, 1, 100, &params, flat);

            let m = b.run(&format!("decode_scalar/{model}/density={density}"), || {
                scalar_decode(&encoded)
            });
            println!("{}", m.report(Some((p as f64, "param"))));

            let m = b.run(&format!("decode_owned/{model}/density={density}"), || {
                decode_update(&encoded).unwrap()
            });
            println!("{}", m.report(Some((p as f64, "param"))));

            let mut scratch = DecodeScratch::default();
            let m = b.run(&format!("decode_view/{model}/density={density}"), || {
                decode_update_view(&encoded, &mut scratch).unwrap().n_samples
            });
            println!("{}", m.report(Some((p as f64, "param"))));
        }
    }

    // Per-encoding wire cost + latency at masked densities: the byte
    // numbers land in the bench trajectory (iters-invariant, so the
    // *_bytes measurements are comparable across machines) alongside the
    // encode/decode latency of each tag family.
    println!("== per-encoding wire bytes + encode/decode latency ==");
    let p = 51_666usize; // vggmini P
    for density in [0.1f32, 0.01] {
        let params: Vec<f32> = (0..p)
            .map(|_| if rng.next_f32() < density { rng.next_normal() } else { 0.0 })
            .collect();
        let nnz = params.iter().filter(|v| **v != 0.0).count();
        for &enc in Encoding::ALL {
            let tag = format!("{}/density={density}", enc.as_str());
            let encoded = encode_update(1, 1, 100, &params, enc);
            println!(
                "  {tag}: {} bytes ({:.2} bytes/nnz, bound {})",
                encoded.len(),
                encoded.len() as f64 / nnz.max(1) as f64,
                wire_bytes(p, nnz, enc),
            );
            let m = b.run(&format!("encode_enc/{tag}"), || {
                encode_update(1, 1, 100, &params, enc)
            });
            println!("{}", m.report(Some((p as f64, "param"))));
            let mut scratch = DecodeScratch::default();
            let m = b.run(&format!("decode_enc/{tag}"), || {
                decode_update_view(&encoded, &mut scratch).unwrap().n_samples
            });
            println!("{}", m.report(Some((p as f64, "param"))));
        }
    }

    // Wire v3 steady state: a slowly-churning top-k mask re-sends nearly
    // its whole index set under the stateless SparseDelta arm every
    // round, while the cross-round cache (SparseCached) pays only the
    // churn. 2% churn per round at 10% density is the steady-state shape
    // dynamic sparse training settles into; the assert pins the
    // acceptance criterion — steady-state cached uploads strictly below
    // the stateless ones — so a codec regression fails the bench run
    // itself, not just the trajectory diff.
    println!("== wire v3: cross-round index cache vs stateless delta (2% churn) ==");
    {
        let p = 51_666usize;
        let k = p / 10;
        let rounds = 8usize;
        let churn = k / 50; // 2% of the support per round
        let mut support: Vec<u32> = {
            let mut s: Vec<u32> = (0..p as u32).collect();
            rng.shuffle(&mut s);
            s.truncate(k);
            s.sort_unstable();
            s
        };
        let mut cache: Option<IndexCache> = None;
        let (mut cached_total, mut stateless_total) = (0usize, 0usize);
        let mut steady_payload: Option<(Vec<u8>, IndexCache)> = None;
        for r in 1..=rounds as u32 {
            if cache.is_some() {
                // churn: drop `churn` members, admit `churn` outsiders
                for _ in 0..churn {
                    let drop_at = (rng.next_f32() * support.len() as f32) as usize % support.len();
                    support.remove(drop_at);
                }
                let mut added = 0usize;
                while added < churn {
                    let cand = (rng.next_f32() * p as f32) as u32 % p as u32;
                    if let Err(slot) = support.binary_search(&cand) {
                        support.insert(slot, cand);
                        added += 1;
                    }
                }
            }
            let mut params = vec![0.0f32; p];
            for &i in &support {
                params[i as usize] = 0.5 + rng.next_f32();
            }
            let stateless = encode_update(1, r, 100, &params, Encoding::SparseDelta);
            let cached = encode_update_cached(1, r, 100, &params, Encoding::SparseCached, cache.as_ref());
            let a = decode_update(&stateless).unwrap().into_dense();
            let b2 = decode_update_cached(&cached, cache.as_ref()).unwrap().into_dense();
            assert_eq!(a, b2, "round {r}: cached decode must match stateless bitwise");
            if cache.is_some() {
                // steady-state rounds only: round 1 is the full send both ways
                cached_total += cached.len();
                stateless_total += stateless.len();
            }
            let next = match &cache {
                Some(c) => c.advance(support.clone()),
                None => IndexCache::first(support.clone()),
            };
            if r == rounds as u32 {
                steady_payload = Some((cached, cache.clone().unwrap()));
            }
            cache = Some(next);
        }
        let per_round = (cached_total / (rounds - 1), stateless_total / (rounds - 1));
        println!(
            "  steady-state upload: cached {} B/round vs stateless {} B/round ({:.1}% of stateless)",
            per_round.0,
            per_round.1,
            100.0 * per_round.0 as f64 / per_round.1 as f64
        );
        assert!(
            cached_total < stateless_total,
            "steady-state SparseCached ({cached_total} B) must beat stateless SparseDelta \
             ({stateless_total} B) on a slowly-churning mask"
        );
        // decode latency of the stateful arm at steady state
        let (payload, decode_cache) = steady_payload.expect("rounds >= 2");
        let mut scratch = DecodeScratch::default();
        let m = b.run("decode_enc/sparse-cached/steady-state", || {
            fedmask::transport::codec::decode_update_view_cached(
                &payload,
                &mut scratch,
                Some(&decode_cache),
            )
            .unwrap()
            .n_samples
        });
        println!("{}", m.report(Some((p as f64, "param"))));
    }

    // Many-client fan-in over real sockets: 64 persistent authenticated
    // sessions vs. a fresh connection + handshake per upload — the number
    // behind the scaling claim that connect-per-upload does not survive
    // fleet growth. Gated like the socket test suite (sealed sandboxes
    // have no loopback TCP).
    if std::env::var("FEDMASK_SOCKET_TESTS").map(|v| v == "1" || v == "true").unwrap_or(false) {
        println!("== 64-client fan-in: persistent sessions vs session-per-upload ==");
        let n = 64usize;
        let p = 2_000usize;
        let payloads: Vec<Vec<u8>> = (0..n)
            .map(|c| {
                let params: Vec<f32> = (0..p)
                    .map(|_| if rng.next_f32() < 0.1 { rng.next_normal() } else { 0.0 })
                    .collect();
                encode_update(c as u32, 1, 100, &params, Encoding::Auto)
            })
            .collect();
        let total_bytes: usize = payloads.iter().map(Vec::len).sum();
        println!("  {n} uploads, {total_bytes} bytes total per fan-in");

        // Persistent: the run-long sessions the transport actually uses —
        // register (connect + handshake) once, then every iteration ships
        // the whole cohort through the live connections and drains it.
        let mut server = Loopback::bind(TransportKind::Tcp).unwrap();
        server.set_timeout(Duration::from_secs(30));
        let ids: Vec<u32> = (0..n as u32).collect();
        server.register_clients(&ids).unwrap();
        let sink = server.sink();
        let m = b.run("fanin64/persistent_sessions", || {
            for pl in &payloads {
                sink.send(pl.clone()).unwrap();
            }
            for _ in 0..n {
                server.recv().unwrap();
            }
        });
        println!("{}", m.report(Some((n as f64, "upload"))));

        // Session-per-upload: the pre-refactor shape — every message pays
        // a connect + hello/welcome handshake + teardown. Reconnecting a
        // just-closed id can race the server's EOF processing, so the
        // client retries briefly (as a real reconnecting client would).
        let mut server2 = Loopback::bind(TransportKind::Tcp).unwrap();
        server2.set_timeout(Duration::from_secs(30));
        // open the registration window without holding sessions ourselves:
        // each upload opens (and tears down) its own
        server2.allow_clients(&ids).unwrap();
        let addr = server2.addr().clone();
        let m = b.run("fanin64/session_per_upload", || {
            for (c, pl) in payloads.iter().enumerate() {
                let conn = connect_retry(&addr, c as u32);
                conn.upload(pl).unwrap();
                drop(conn);
            }
            for _ in 0..n {
                server2.recv().unwrap();
            }
        });
        println!("{}", m.report(Some((n as f64, "upload"))));
        drop(server);
        drop(server2);

        // 1k-client fan-in: the reactor vs the thread-per-conn baseline.
        // The identical wave driver (64 client threads striding the id
        // space: connect → handshake → upload → disconnect, ≤64 sockets
        // live at once — fd-limit friendly) runs against both servers;
        // the main thread drains concurrently so the bounded upload
        // queue never stalls the reactor. The baseline skips sessions
        // and admission entirely and is *still* the arm paying a thread
        // per connection.
        println!("== 1k-client fan-in: reactor vs thread-per-conn baseline ==");
        let n_big = 1_000usize;
        let waves = 64usize;
        let big_payloads: Arc<Vec<Vec<u8>>> = Arc::new(
            (0..n_big)
                .map(|c| {
                    let params: Vec<f32> = (0..256)
                        .map(|_| if rng.next_f32() < 0.1 { rng.next_normal() } else { 0.0 })
                        .collect();
                    encode_update(c as u32, 1, 100, &params, Encoding::Auto)
                })
                .collect(),
        );

        let mut server = Loopback::bind(TransportKind::Tcp).unwrap();
        server.set_timeout(Duration::from_secs(60));
        let ids: Vec<u32> = (0..n_big as u32).collect();
        server.allow_clients(&ids).unwrap();
        let addr = server.addr().clone();
        let m = b.run("fanin1k/reactor", || {
            let handles = drive_waves(&addr, &big_payloads, waves);
            for _ in 0..n_big {
                server.recv().unwrap();
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        println!("{}", m.report(Some((n_big as f64, "upload"))));
        drop(server);

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let tcp_addr = listener.local_addr().unwrap();
        let baseline_addr = WireAddr::Tcp(tcp_addr);
        let (up_tx, up_rx) = channel::<Vec<u8>>();
        let stop = Arc::new(AtomicBool::new(false));
        let accept_thread = thread_per_conn_server(listener, up_tx, Arc::clone(&stop));
        let m = b.run("fanin1k/thread_per_conn", || {
            let handles = drive_waves(&baseline_addr, &big_payloads, waves);
            for _ in 0..n_big {
                up_rx.recv().unwrap();
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        println!("{}", m.report(Some((n_big as f64, "upload"))));
        stop.store(true, Ordering::SeqCst);
        // one dummy connect unblocks the baseline's final accept()
        let _ = std::net::TcpStream::connect(tcp_addr);
        let _ = accept_thread.join();
    } else {
        println!("== 64-client fan-in skipped (set FEDMASK_SOCKET_TESTS=1 to enable) ==");
    }

    // Sharded tree aggregation vs the single-threaded fold at fleet-size
    // fan-in — in memory, no sockets, so this always runs. Each iteration
    // folds every payload of a 1k/10k-client cohort: the serial arm
    // decodes inline on one thread (the server's `agg_shards = 1` path);
    // the sharded arm routes each payload to its shard worker and merges
    // at the root, with the per-round spawn + join cost included, exactly
    // as the server pays it. Bitwise equality of the two paths is
    // asserted once up front.
    println!("== sharded tree fold vs single fold (simulated 1k–10k fan-in) ==");
    let p = 1_000usize;
    for k in [1_000usize, 10_000] {
        let payloads: Vec<Vec<u8>> = (0..k)
            .map(|c| {
                let params: Vec<f32> = (0..p)
                    .map(|_| if rng.next_f32() < 0.05 { rng.next_normal() } else { 0.0 })
                    .collect();
                encode_update(c as u32, 1, 100, &params, Encoding::Auto)
            })
            .collect();
        let serial_fold = |payloads: &[Vec<u8>]| -> Vec<f32> {
            let mut agg = StreamingFedAvg::new(p);
            let mut scratch = DecodeScratch::default();
            for pl in payloads {
                let u = decode_update_view(pl, &mut scratch).unwrap();
                match u.body {
                    BodyView::Dense(d) => agg
                        .fold(Contribution {
                            client: u.client as usize,
                            params: d,
                            n_samples: u.n_samples,
                        })
                        .unwrap(),
                    BodyView::Sparse { indices, values } => agg
                        .fold_sparse(SparseContribution {
                            client: u.client as usize,
                            p: u.p,
                            indices,
                            values,
                            n_samples: u.n_samples,
                        })
                        .unwrap(),
                }
            }
            Box::new(agg).finish().unwrap()
        };
        let sharded_fold = |payloads: &[Vec<u8>], shards: usize| -> Vec<f32> {
            let partials: Vec<Box<dyn Aggregator>> = (0..shards)
                .map(|_| Box::new(StreamingFedAvg::new(p)) as Box<dyn Aggregator>)
                .collect();
            let mut tree = ShardedAggregator::spawn(partials).unwrap();
            for pl in payloads {
                tree.route(peek_client(pl).unwrap(), pl.clone(), None).unwrap();
            }
            tree.finish().unwrap()
        };
        let reference = serial_fold(&payloads);
        for shards in [2usize, 8] {
            assert_eq!(
                sharded_fold(&payloads, shards),
                reference,
                "tree merge must be bitwise-exact ({shards} shards, {k} clients)"
            );
        }
        let m = b.run(&format!("fold/{k}clients/serial"), || serial_fold(&payloads));
        println!("{}", m.report(Some((k as f64, "upload"))));
        for shards in [2usize, 8] {
            let m = b.run(&format!("fold/{k}clients/sharded{shards}"), || {
                sharded_fold(&payloads, shards)
            });
            println!("{}", m.report(Some((k as f64, "upload"))));
        }
    }

    println!("== 8-bit / 4-bit quantization (compression extension) ==");
    let params: Vec<f32> = (0..51_666).map(|_| rng.next_normal()).collect();
    let m = b.run("quantize/vggmini", || quantize(&params).unwrap());
    println!("{}", m.report(Some((51_666f64, "param"))));
    let q = quantize(&params).unwrap();
    let m = b.run("dequantize/vggmini", || dequantize(&q));
    println!("{}", m.report(Some((51_666f64, "param"))));
    let m = b.run("quantize4/vggmini", || quantize4(&params).unwrap());
    println!("{}", m.report(Some((51_666f64, "param"))));
    let q4 = quantize4(&params).unwrap();
    let m = b.run("dequantize4/vggmini", || dequantize4(&q4));
    println!("{}", m.report(Some((51_666f64, "param"))));

    b.write_trajectory("BENCH_transport.json");
}
