//! Transport bench: codec encode/decode at model sizes across densities
//! (the wire work per upload), plus 8-bit quantization. Establishes that
//! transport never dominates a round (DESIGN.md §6 L3 target).
//!
//! Run: cargo bench --bench transport

use fedmask::sim::rng::Rng;
use fedmask::transport::codec::{decode_update, encode_update, Encoding};
use fedmask::transport::quantize::{dequantize, quantize};
use fedmask::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(11);
    println!("== wire codec ==");
    for (model, p) in [("lenet", 20_522usize), ("vggmini", 51_666)] {
        for density in [1.0f32, 0.5, 0.1] {
            let params: Vec<f32> = (0..p)
                .map(|_| if rng.next_f32() < density { rng.next_normal() } else { 0.0 })
                .collect();
            let m = b.run(&format!("encode/{model}/density={density}"), || {
                encode_update(1, 1, 100, &params, Encoding::Auto)
            });
            println!("{}", m.report(Some((p as f64, "param"))));
            let encoded = encode_update(1, 1, 100, &params, Encoding::Auto);
            let m = b.run(&format!("decode/{model}/density={density}"), || {
                decode_update(&encoded).unwrap()
            });
            println!("{}", m.report(Some((p as f64, "param"))));
        }
    }
    println!("== 8-bit quantization (compression extension) ==");
    let params: Vec<f32> = (0..51_666).map(|_| rng.next_normal()).collect();
    let m = b.run("quantize/vggmini", || quantize(&params).unwrap());
    println!("{}", m.report(Some((51_666f64, "param"))));
    let q = quantize(&params).unwrap();
    let m = b.run("dequantize/vggmini", || dequantize(&q));
    println!("{}", m.report(Some((51_666f64, "param"))));
}
