//! Masking hot-path bench: the exact rust selective mask (per-layer and
//! global top-k) and random masking at each model's true P and the paper's
//! gamma sweep — plus, when artifacts exist, the L1 Pallas kernel path
//! through PJRT for direct comparison (the production mask path).
//!
//! Run: cargo bench --bench masking

use fedmask::fl::masking::{
    random_mask_rust, selective_mask_rust, selective_mask_rust_with, MaskScope, MaskScratch,
};
use fedmask::runtime::engine::Engine;
use fedmask::runtime::manifest::{LayerInfo, Manifest};
use fedmask::sim::rng::Rng;
use fedmask::util::bench::Bench;

fn flat_layer(p: usize) -> Vec<LayerInfo> {
    vec![LayerInfo { name: "w".into(), shape: vec![p], offset: 0, size: p, masked: true }]
}

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(3);
    println!("== selective masking (rust exact oracle) ==");
    for (model, p) in [("lenet", 20_522usize), ("gru", 154_768), ("vggmini", 51_666)] {
        let wn: Vec<f32> = (0..p).map(|_| rng.next_normal()).collect();
        let wo: Vec<f32> = (0..p).map(|_| rng.next_normal()).collect();
        let layers = flat_layer(p);
        for gamma in [0.1f32, 0.5, 0.9] {
            let m = b.run(&format!("selective_rust/{model}/g={gamma}"), || {
                selective_mask_rust(&wn, &wo, gamma, &layers, MaskScope::PerLayer)
            });
            println!("{}", m.report(Some((p as f64, "param"))));
            // worker-held scratch arena: the per-call delta/partition
            // allocations amortized away (the engine-pool configuration)
            let mut scratch = MaskScratch::default();
            let m = b.run(&format!("selective_rust_scratch/{model}/g={gamma}"), || {
                selective_mask_rust_with(&wn, &wo, gamma, &layers, MaskScope::PerLayer, &mut scratch)
            });
            println!("{}", m.report(Some((p as f64, "param"))));
        }
        let m = b.run(&format!("random_rust/{model}/g=0.5"), || {
            let mut r = Rng::new(1);
            random_mask_rust(&wn, 0.5, &layers, &mut r)
        });
        println!("{}", m.report(Some((p as f64, "param"))));
    }

    // Production path: the Pallas threshold-bisection kernel via PJRT.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if let Ok(manifest) = Manifest::load(&dir) {
        println!("== selective masking (L1 Pallas kernel via PJRT) ==");
        for model in ["lenet", "gru", "vggmini"] {
            let engine = Engine::load(&manifest, &[model]).unwrap();
            let p = engine.model(model).unwrap().p;
            let wn: Vec<f32> = (0..p).map(|_| rng.next_normal()).collect();
            let wo: Vec<f32> = (0..p).map(|_| rng.next_normal()).collect();
            for gamma in [0.1f32, 0.5] {
                let m = b.run(&format!("selective_hlo/{model}/g={gamma}"), || {
                    engine.mask(model, &wn, &wo, gamma).unwrap()
                });
                println!("{}", m.report(Some((p as f64, "param"))));
            }
        }
    } else {
        println!("(artifacts missing: skipping Pallas kernel bench; run `make artifacts`)");
    }
}
