//! End-to-end round bench: full federated rounds through the real PJRT
//! artifacts — the paper-table workloads in miniature. One measurement per
//! (model x policy) cell; this is the number the §Perf optimization loop
//! tracks.
//!
//! Run: cargo bench --bench e2e_round   (needs `make artifacts`)

use std::sync::Arc;

use fedmask::config::experiment::ExperimentConfig;
use fedmask::fl::masking::MaskPolicy;
use fedmask::fl::server::Server;
use fedmask::runtime::manifest::Manifest;
use fedmask::runtime::pool::EnginePool;
use fedmask::util::bench::Bench;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(manifest) = Manifest::load(&dir) else {
        println!("(artifacts missing: run `make artifacts` first)");
        return;
    };
    std::env::set_var(
        "FEDMASK_BENCH_MS",
        std::env::var("FEDMASK_BENCH_MS").unwrap_or_else(|_| "3000".into()),
    );
    let mut b = Bench::new();
    for (model, clients, n_train, n_test) in
        [("lenet", 6usize, 1536usize, 512usize), ("gru", 4, 20_000, 8_000)]
    {
        let pool = Arc::new(EnginePool::new(&manifest, &[model], 6).unwrap());
        for (plabel, policy) in [("dense", MaskPolicy::None), ("selective", MaskPolicy::selective(0.3))] {
            let mut cfg = ExperimentConfig::defaults(model).unwrap();
            cfg.label = format!("bench-{model}-{plabel}");
            cfg.clients = clients;
            cfg.rounds = 1;
            cfg.n_train = n_train;
            cfg.n_test = n_test;
            cfg.eval_every = 10; // exclude eval from the round number
            cfg.masking = policy;
            let m = b.run(&format!("round/{model}/{plabel}"), || {
                let mut server =
                    Server::with_pool(cfg.clone(), &manifest, Arc::clone(&pool)).unwrap();
                server.run_round(1).unwrap()
            });
            println!("{}", m.report(Some((clients as f64, "client"))));
        }
    }
}
