//! End-to-end round bench: full federated rounds through the real PJRT
//! artifacts — the paper-table workloads in miniature. One measurement per
//! (model x policy) cell; this is the number the §Perf optimization loop
//! tracks.
//!
//! Run: cargo bench --bench e2e_round   (needs `make artifacts`)

use std::sync::Arc;

use fedmask::config::experiment::ExperimentConfig;
use fedmask::fl::masking::{
    selective_mask_rust_with, MaskPolicy, MaskScope, MaskScratch,
};
use fedmask::fl::pipeline::mask_stream_selective;
use fedmask::fl::server::Server;
use fedmask::runtime::bufpool::BufferPool;
use fedmask::runtime::manifest::{LayerInfo, Manifest};
use fedmask::runtime::pool::EnginePool;
use fedmask::sim::rng::Rng;
use fedmask::transport::codec::{
    encode_masked, encode_update_cached_with, EncodeScratch, Encoding, MaskedStream,
};
use fedmask::util::bench::Bench;

/// The client-side upload hot path in isolation, with no engine in the
/// loop: the staged mask-then-encode pair vs the fused single-pass
/// pipeline (`fl::pipeline` + `encode_masked` + pooled frames). Runs with
/// or without artifacts — this is the half of the round the fused path
/// optimizes, at each paper model's true P.
fn bench_fused_vs_staged(b: &mut Bench) {
    println!("== fused mask+encode vs staged (engine-free) ==");
    let mut rng = Rng::new(7);
    for (model, p) in [("lenet", 20_522usize), ("gru", 154_768), ("vggmini", 51_666)] {
        let wn: Vec<f32> = (0..p).map(|_| rng.next_normal()).collect();
        let wo: Vec<f32> = (0..p).map(|_| rng.next_normal()).collect();
        let layers =
            vec![LayerInfo { name: "w".into(), shape: vec![p], offset: 0, size: p, masked: true }];
        for (elabel, enc) in [("auto", Encoding::Auto), ("autoq8", Encoding::AutoQ8)] {
            let mut mask_scratch = MaskScratch::default();
            let mut enc_scratch = EncodeScratch::default();
            let mut stream = MaskedStream::default();
            let pool = BufferPool::new();
            // parity gate before timing: the two paths must emit the same
            // bytes, or the comparison is meaningless
            let staged_bytes = {
                let masked = selective_mask_rust_with(
                    &wn, &wo, 0.3, &layers, MaskScope::PerLayer, &mut mask_scratch,
                );
                encode_update_cached_with(&mut enc_scratch, 1, 1, 64, &masked, enc, None)
            };
            mask_stream_selective(
                &wn, &wo, 0.3, &layers, MaskScope::PerLayer, &mut mask_scratch, &mut stream,
            )
            .unwrap();
            let mut probe = pool.take();
            encode_masked(&mut enc_scratch, &mut probe, 1, 1, 64, &stream, enc, None).unwrap();
            assert_eq!(probe, staged_bytes, "fused must be bitwise-identical to staged");
            pool.put(probe);

            let m = b.run(&format!("mask_encode_staged/{model}/{elabel}"), || {
                let masked = selective_mask_rust_with(
                    &wn, &wo, 0.3, &layers, MaskScope::PerLayer, &mut mask_scratch,
                );
                encode_update_cached_with(&mut enc_scratch, 1, 1, 64, &masked, enc, None).len()
            });
            println!("{}", m.report(Some((p as f64, "param"))));
            let m = b.run(&format!("mask_encode_fused/{model}/{elabel}"), || {
                mask_stream_selective(
                    &wn, &wo, 0.3, &layers, MaskScope::PerLayer, &mut mask_scratch, &mut stream,
                )
                .unwrap();
                let mut payload = pool.take();
                encode_masked(&mut enc_scratch, &mut payload, 1, 1, 64, &stream, enc, None)
                    .unwrap();
                let n = payload.len();
                pool.put(payload);
                n
            });
            println!("{}", m.report(Some((p as f64, "param"))));
        }
    }
}

fn main() {
    std::env::set_var(
        "FEDMASK_BENCH_MS",
        std::env::var("FEDMASK_BENCH_MS").unwrap_or_else(|_| "3000".into()),
    );
    let mut b = Bench::new();
    bench_fused_vs_staged(&mut b);

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(manifest) = Manifest::load(&dir) else {
        b.write_trajectory("BENCH_e2e_round.json");
        println!("(artifacts missing: skipping full rounds; run `make artifacts` first)");
        return;
    };
    for (model, clients, n_train, n_test) in
        [("lenet", 6usize, 1536usize, 512usize), ("gru", 4, 20_000, 8_000)]
    {
        let pool = Arc::new(EnginePool::new(&manifest, &[model], 6).unwrap());
        for (plabel, policy) in [("dense", MaskPolicy::None), ("selective", MaskPolicy::selective(0.3))] {
            let mut cfg = ExperimentConfig::defaults(model).unwrap();
            cfg.label = format!("bench-{model}-{plabel}");
            cfg.clients = clients;
            cfg.rounds = 1;
            cfg.n_train = n_train;
            cfg.n_test = n_test;
            cfg.eval_every = 10; // exclude eval from the round number
            cfg.masking = policy;
            let m = b.run(&format!("round/{model}/{plabel}"), || {
                let mut server =
                    Server::with_pool(cfg.clone(), &manifest, Arc::clone(&pool)).unwrap();
                server.run_round(1).unwrap()
            });
            println!("{}", m.report(Some((clients as f64, "client"))));
        }
    }
    b.write_trajectory("BENCH_e2e_round.json");
}
