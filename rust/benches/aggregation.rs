//! Aggregation hot-path bench: weighted FedAvg over flat parameter vectors
//! at each model's true P, across cohort sizes (paper Eq. 2 — the L3
//! operation executed once per round), the streaming-vs-barrier comparison
//! over real encoded wire payloads, and the headline sparse-native
//! comparison: decode+fold a masked cohort in O(nnz) (borrowed sparse
//! views + sparse fold) against the dense baseline (densify every payload,
//! fold all p coordinates) across gamma in {0.01, 0.1, 0.5} — the
//! acceptance target is >= 4x at gamma=0.1, gru P.
//!
//! Writes BENCH_aggregation.json at the repo root (the perf trajectory).
//!
//! Run: cargo bench --bench aggregation   (FEDMASK_BENCH_MS tunes budget)

use fedmask::fl::aggregate::{
    uniform_mean, weighted_mean, Aggregator, Contribution, SparseContribution, StreamingFedAvg,
};
use fedmask::runtime::manifest::LayerInfo;
use fedmask::sim::rng::Rng;
use fedmask::transport::codec::{
    decode_update, decode_update_view, encode_update, BodyView, DecodeScratch, Encoding, WireUpdate,
};
use fedmask::util::bench::Bench;

fn vectors(p: usize, k: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..k).map(|_| (0..p).map(|_| rng.next_normal()).collect()).collect()
}

/// Masked-style vectors: a `gamma` fraction of coordinates non-zero.
fn sparse_vectors(p: usize, k: usize, gamma: f32, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..k)
        .map(|_| {
            (0..p)
                .map(|_| {
                    if rng.next_f32() < gamma {
                        rng.next_normal()
                    } else {
                        0.0
                    }
                })
                .collect()
        })
        .collect()
}

fn contribs_of(vecs: &[Vec<f32>]) -> Vec<Contribution<'_>> {
    vecs.iter()
        .enumerate()
        .map(|(client, v)| Contribution { client, params: v, n_samples: 200 })
        .collect()
}

fn payloads_with(vecs: &[Vec<f32>], enc: Encoding) -> Vec<Vec<u8>> {
    vecs.iter()
        .enumerate()
        .map(|(c, v)| encode_update(c as u32, 1, 200, v, enc))
        .collect()
}

fn payloads_of(vecs: &[Vec<f32>]) -> Vec<Vec<u8>> {
    payloads_with(vecs, Encoding::Auto)
}

/// Fold one decoded view into the aggregator, sparse bodies sparsely.
fn fold_view(agg: &mut StreamingFedAvg, view: &fedmask::transport::codec::WireView<'_>) {
    let client = view.client as usize;
    match view.body {
        BodyView::Dense(params) => agg
            .fold(Contribution { client, params, n_samples: view.n_samples })
            .unwrap(),
        BodyView::Sparse { indices, values } => agg
            .fold_sparse(SparseContribution {
                client,
                p: view.p,
                indices,
                values,
                n_samples: view.n_samples,
            })
            .unwrap(),
    }
}

fn main() {
    let mut b = Bench::new();
    println!("== aggregation (weighted FedAvg, Eq. 2) ==");
    for (model, p) in [("lenet", 20_522usize), ("gru", 154_768), ("vggmini", 51_666)] {
        for clients in [4usize, 16, 64] {
            let vecs = vectors(p, clients, 7);
            let contribs = contribs_of(&vecs);
            let m = b.run(&format!("weighted_mean/{model}/m={clients}"), || {
                weighted_mean(&contribs).unwrap()
            });
            let items = (p * clients) as f64;
            println!("{}", m.report(Some((items, "param"))));
        }
    }

    // The headline comparison: the sparse-native round path (borrowed view
    // decode + O(nnz) fold) against the dense baseline every payload used
    // to pay (densify to a fresh Vec<f32>, fold scanning all p
    // coordinates). Same payloads, bit-identical results by construction.
    println!("== sparse-native decode+fold vs dense baseline ==");
    let clients = 16usize;
    for (model, p) in [("lenet", 20_522usize), ("gru", 154_768), ("vggmini", 51_666)] {
        for gamma in [0.01f32, 0.1, 0.5] {
            let vecs = sparse_vectors(p, clients, gamma, 13);
            let payloads = payloads_of(&vecs);
            let tag = format!("{model}/gamma={gamma}");

            let m = b.run(&format!("dense_round/{tag}"), || {
                let mut agg = StreamingFedAvg::new(p);
                for payload in &payloads {
                    let u: WireUpdate = decode_update(payload).unwrap();
                    let dense = u.to_dense();
                    agg.fold(Contribution {
                        client: u.client as usize,
                        params: &dense,
                        n_samples: u.n_samples,
                    })
                    .unwrap();
                }
                Box::new(agg).finish().unwrap()
            });
            println!("{}", m.report(Some(((p * clients) as f64, "param"))));

            let mut scratch = DecodeScratch::default();
            let m = b.run(&format!("sparse_round/{tag}"), || {
                let mut agg = StreamingFedAvg::new(p);
                for payload in &payloads {
                    let view = decode_update_view(payload, &mut scratch).unwrap();
                    fold_view(&mut agg, &view);
                }
                Box::new(agg).finish().unwrap()
            });
            println!("{}", m.report(Some(((p * clients) as f64, "param"))));
        }
    }

    // Per-encoding round folds at the masked density the paper sweeps:
    // same cohort, every wire tag family — bytes on the wire and the
    // decode+fold latency the server pays per round.
    println!("== per-encoding round fold (vggmini P, gamma=0.1) ==");
    {
        let p = 51_666usize;
        let vecs = sparse_vectors(p, clients, 0.1, 23);
        for &enc in Encoding::ALL {
            let payloads = payloads_with(&vecs, enc);
            let total: usize = payloads.iter().map(Vec::len).sum();
            println!("  {}: {} wire bytes for {} uploads", enc.as_str(), total, clients);
            let mut scratch = DecodeScratch::default();
            let m = b.run(&format!("enc_round/{}/gamma=0.1", enc.as_str()), || {
                let mut agg = StreamingFedAvg::new(p);
                for payload in &payloads {
                    let view = decode_update_view(payload, &mut scratch).unwrap();
                    fold_view(&mut agg, &view);
                }
                Box::new(agg).finish().unwrap()
            });
            println!("{}", m.report(Some(((p * clients) as f64, "param"))));
        }
    }

    // Delta mask-target round path: the old server reconstructed every
    // payload densely (apply_delta_target: an O(p) copy per contribution)
    // before folding; the delta-baseline aggregator folds O(nnz) and adds
    // the collapsed baseline term once at finish.
    println!("== delta-target round path (gru P, gamma=0.1) ==");
    {
        let p = 154_768usize;
        let gamma = 0.1f32;
        let layers = vec![LayerInfo {
            name: "w".into(),
            shape: vec![p],
            offset: 0,
            size: p,
            masked: true,
        }];
        let broadcast: Vec<f32> = {
            let mut rng = Rng::new(29);
            (0..p).map(|_| rng.next_normal()).collect()
        };
        let vecs = sparse_vectors(p, clients, gamma, 17);
        let payloads = payloads_of(&vecs);

        let m = b.run("dense_delta_round/gru/gamma=0.1", || {
            let mut agg = StreamingFedAvg::with_delta_baseline(&broadcast, &layers).unwrap();
            for payload in &payloads {
                let u = decode_update(payload).unwrap();
                let dense = u.to_dense();
                agg.fold(Contribution {
                    client: u.client as usize,
                    params: &dense,
                    n_samples: u.n_samples,
                })
                .unwrap();
            }
            Box::new(agg).finish().unwrap()
        });
        println!("{}", m.report(Some(((p * clients) as f64, "param"))));

        let mut scratch = DecodeScratch::default();
        let m = b.run("sparse_delta_round/gru/gamma=0.1", || {
            let mut agg = StreamingFedAvg::with_delta_baseline(&broadcast, &layers).unwrap();
            for payload in &payloads {
                let view = decode_update_view(payload, &mut scratch).unwrap();
                fold_view(&mut agg, &view);
            }
            Box::new(agg).finish().unwrap()
        });
        println!("{}", m.report(Some(((p * clients) as f64, "param"))));
    }

    // Streaming vs barrier over the real wire: the streaming side decodes
    // and folds one payload at a time and never holds more than one decoded
    // update; the barrier side decodes the whole cohort first (the seed
    // design), paying O(k*p) buffering before any aggregation starts.
    println!("== streaming vs barrier (decode + aggregate, vggmini P) ==");
    let p = 51_666usize;
    for clients in [8usize, 32, 128] {
        for gamma in [0.1f32, 0.5, 1.0] {
            let vecs = sparse_vectors(p, clients, gamma, 11);
            let payloads = payloads_of(&vecs);
            let tag = format!("k={clients}/gamma={gamma}");

            let mut scratch = DecodeScratch::default();
            let m = b.run(&format!("stream_fold/{tag}"), || {
                let mut agg = StreamingFedAvg::new(p);
                for payload in &payloads {
                    let view = decode_update_view(payload, &mut scratch).unwrap();
                    fold_view(&mut agg, &view);
                }
                Box::new(agg).finish().unwrap()
            });
            println!("{}", m.report(Some(((p * clients) as f64, "param"))));

            let m = b.run(&format!("barrier_fold/{tag}"), || {
                let decoded: Vec<(WireUpdate, Vec<f32>)> = payloads
                    .iter()
                    .map(|payload| {
                        let u = decode_update(payload).unwrap();
                        let dense = u.to_dense();
                        (u, dense)
                    })
                    .collect();
                let contribs: Vec<Contribution> = decoded
                    .iter()
                    .map(|(u, dense)| Contribution {
                        client: u.client as usize,
                        params: dense,
                        n_samples: u.n_samples,
                    })
                    .collect();
                weighted_mean(&contribs).unwrap()
            });
            println!("{}", m.report(Some(((p * clients) as f64, "param"))));

            // Peak aggregation-state memory: the O(p) claim, measured.
            let mut agg = StreamingFedAvg::new(p);
            for payload in &payloads {
                let view = decode_update_view(payload, &mut scratch).unwrap();
                fold_view(&mut agg, &view);
            }
            let streaming_state = agg.state_bytes() + 4 * p; // accumulator + one decoded update
            let barrier_state = 4 * p * clients; // k decoded updates buffered
            assert!(
                streaming_state < barrier_state || clients <= 5,
                "streaming state must undercut the barrier buffer for real cohorts"
            );
            println!(
                "  state bytes: streaming {streaming_state} (O(p), k-independent) vs barrier {barrier_state} (O(k*p))"
            );
        }
    }

    // Sharded tree fold at model-scale P: the server's `agg_shards > 1`
    // path (shard workers fold their own clients' payloads, root merges
    // the integer partials bitwise-exactly) against the single-threaded
    // stream fold, spawn + merge cost included. The transport bench
    // covers the 1k–10k-client fan-in shape; this pins the model-scale
    // arithmetic shape.
    println!("== sharded tree fold vs stream fold (gru P, k=128, gamma=0.1) ==");
    {
        let p = 154_768usize;
        let clients = 128usize;
        let vecs = sparse_vectors(p, clients, 0.1, 31);
        let payloads = payloads_of(&vecs);
        let mut scratch = DecodeScratch::default();
        let serial = |scratch: &mut DecodeScratch| {
            let mut agg = StreamingFedAvg::new(p);
            for payload in &payloads {
                let view = decode_update_view(payload, scratch).unwrap();
                fold_view(&mut agg, &view);
            }
            Box::new(agg).finish().unwrap()
        };
        let sharded = |shards: usize| {
            let partials: Vec<Box<dyn Aggregator>> = (0..shards)
                .map(|_| Box::new(StreamingFedAvg::new(p)) as Box<dyn Aggregator>)
                .collect();
            let mut tree = fedmask::fl::ShardedAggregator::spawn(partials).unwrap();
            for (c, payload) in payloads.iter().enumerate() {
                tree.route(c as u32, payload.clone(), None).unwrap();
            }
            tree.finish().unwrap()
        };
        let reference = serial(&mut scratch);
        for shards in [2usize, 8] {
            assert_eq!(sharded(shards), reference, "tree merge must be bitwise-exact");
        }
        let m = b.run("tree_fold/gru/serial", || serial(&mut scratch));
        println!("{}", m.report(Some(((p * clients) as f64, "param"))));
        for shards in [2usize, 8] {
            let m = b.run(&format!("tree_fold/gru/shards={shards}"), || sharded(shards));
            println!("{}", m.report(Some(((p * clients) as f64, "param"))));
        }
    }

    // rule ablation: uniform vs weighted at one size
    let vecs = vectors(51_666, 16, 9);
    let contribs = contribs_of(&vecs);
    let m = b.run("uniform_mean/vggmini/m=16", || uniform_mean(&contribs).unwrap());
    println!("{}", m.report(Some(((51_666 * 16) as f64, "param"))));

    // Perf trajectory: machine-readable baseline for the next PR to diff.
    b.write_trajectory("BENCH_aggregation.json");
}
