//! Aggregation hot-path bench: weighted FedAvg over flat parameter vectors
//! at each model's true P, across cohort sizes (paper Eq. 2 — the L3
//! operation executed once per round), plus the streaming-vs-barrier
//! comparison over real encoded wire payloads: decode + fold as payloads
//! "arrive" (O(p) state) against decode-everything-then-barrier
//! (O(k*p) buffering), across cohort size k and masking rate gamma.
//!
//! Run: cargo bench --bench aggregation   (FEDMASK_BENCH_MS tunes budget)

use fedmask::fl::aggregate::{
    uniform_mean, weighted_mean, Aggregator, Contribution, StreamingFedAvg,
};
use fedmask::sim::rng::Rng;
use fedmask::transport::codec::{decode_update, encode_update, Encoding, WireUpdate};
use fedmask::util::bench::Bench;

fn vectors(p: usize, k: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..k).map(|_| (0..p).map(|_| rng.next_normal()).collect()).collect()
}

/// Masked-style vectors: a `gamma` fraction of coordinates non-zero.
fn sparse_vectors(p: usize, k: usize, gamma: f32, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..k)
        .map(|_| {
            (0..p)
                .map(|_| {
                    if rng.next_f32() < gamma {
                        rng.next_normal()
                    } else {
                        0.0
                    }
                })
                .collect()
        })
        .collect()
}

fn contribs_of(vecs: &[Vec<f32>]) -> Vec<Contribution<'_>> {
    vecs.iter()
        .enumerate()
        .map(|(client, v)| Contribution { client, params: v, n_samples: 200 })
        .collect()
}

fn main() {
    let mut b = Bench::new();
    println!("== aggregation (weighted FedAvg, Eq. 2) ==");
    for (model, p) in [("lenet", 20_522usize), ("gru", 154_768), ("vggmini", 51_666)] {
        for clients in [4usize, 16, 64] {
            let vecs = vectors(p, clients, 7);
            let contribs = contribs_of(&vecs);
            let m = b.run(&format!("weighted_mean/{model}/m={clients}"), || {
                weighted_mean(&contribs).unwrap()
            });
            let items = (p * clients) as f64;
            println!("{}", m.report(Some((items, "param"))));
        }
    }

    // Streaming vs barrier over the real wire: the streaming side decodes
    // and folds one payload at a time and never holds more than one decoded
    // update; the barrier side decodes the whole cohort first (the seed
    // design), paying O(k*p) buffering before any aggregation starts.
    println!("== streaming vs barrier (decode + aggregate, vggmini P) ==");
    let p = 51_666usize;
    for clients in [8usize, 32, 128] {
        for gamma in [0.1f32, 0.5, 1.0] {
            let vecs = sparse_vectors(p, clients, gamma, 11);
            let payloads: Vec<Vec<u8>> = vecs
                .iter()
                .enumerate()
                .map(|(c, v)| encode_update(c as u32, 1, 200, v, Encoding::Auto))
                .collect();
            let tag = format!("k={clients}/gamma={gamma}");

            let m = b.run(&format!("stream_fold/{tag}"), || {
                let mut agg = StreamingFedAvg::new(p);
                for payload in &payloads {
                    let u = decode_update(payload).unwrap();
                    agg.fold(Contribution {
                        client: u.client as usize,
                        params: &u.params,
                        n_samples: u.n_samples,
                    })
                    .unwrap();
                }
                Box::new(agg).finish().unwrap()
            });
            println!("{}", m.report(Some(((p * clients) as f64, "param"))));

            let m = b.run(&format!("barrier_fold/{tag}"), || {
                let decoded: Vec<WireUpdate> =
                    payloads.iter().map(|payload| decode_update(payload).unwrap()).collect();
                let contribs: Vec<Contribution> = decoded
                    .iter()
                    .map(|u| Contribution {
                        client: u.client as usize,
                        params: &u.params,
                        n_samples: u.n_samples,
                    })
                    .collect();
                weighted_mean(&contribs).unwrap()
            });
            println!("{}", m.report(Some(((p * clients) as f64, "param"))));

            // Peak aggregation-state memory: the O(p) claim, measured.
            let mut agg = StreamingFedAvg::new(p);
            for payload in &payloads {
                let u = decode_update(payload).unwrap();
                agg.fold(Contribution {
                    client: u.client as usize,
                    params: &u.params,
                    n_samples: u.n_samples,
                })
                .unwrap();
            }
            let streaming_state = agg.state_bytes() + 4 * p; // accumulator + one decoded update
            let barrier_state = 4 * p * clients; // k decoded updates buffered
            assert!(
                streaming_state < barrier_state || clients <= 5,
                "streaming state must undercut the barrier buffer for real cohorts"
            );
            println!(
                "  state bytes: streaming {streaming_state} (O(p), k-independent) vs barrier {barrier_state} (O(k*p))"
            );
        }
    }

    // rule ablation: uniform vs weighted at one size
    let vecs = vectors(51_666, 16, 9);
    let contribs = contribs_of(&vecs);
    let m = b.run("uniform_mean/vggmini/m=16", || uniform_mean(&contribs).unwrap());
    println!("{}", m.report(Some(((51_666 * 16) as f64, "param"))));
}
