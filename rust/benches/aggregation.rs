//! Aggregation hot-path bench: weighted FedAvg over flat parameter vectors
//! at each model's true P, across cohort sizes (paper Eq. 2 — the L3
//! operation executed once per round).
//!
//! Run: cargo bench --bench aggregation   (FEDMASK_BENCH_MS tunes budget)

use fedmask::fl::aggregate::{uniform_mean, weighted_mean, Contribution};
use fedmask::sim::rng::Rng;
use fedmask::util::bench::Bench;

fn vectors(p: usize, k: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..k).map(|_| (0..p).map(|_| rng.next_normal()).collect()).collect()
}

fn main() {
    let mut b = Bench::new();
    println!("== aggregation (weighted FedAvg, Eq. 2) ==");
    for (model, p) in [("lenet", 20_522usize), ("gru", 154_768), ("vggmini", 51_666)] {
        for clients in [4usize, 16, 64] {
            let vecs = vectors(p, clients, 7);
            let contribs: Vec<Contribution> = vecs
                .iter()
                .map(|v| Contribution { params: v, n_samples: 200 })
                .collect();
            let m = b.run(&format!("weighted_mean/{model}/m={clients}"), || {
                weighted_mean(&contribs).unwrap()
            });
            let items = (p * clients) as f64;
            println!("{}", m.report(Some((items, "param"))));
        }
    }
    // rule ablation: uniform vs weighted at one size
    let vecs = vectors(51_666, 16, 9);
    let contribs: Vec<Contribution> = vecs
        .iter()
        .map(|v| Contribution { params: v, n_samples: 200 })
        .collect();
    let m = b.run("uniform_mean/vggmini/m=16", || uniform_mean(&contribs).unwrap());
    println!("{}", m.report(Some(((51_666 * 16) as f64, "param"))));
}
