//! Rule `wire-spec`: the wire constants in `transport/frame.rs` and
//! `transport/codec.rs` and the grammar tables in `docs/WIRE.md` must
//! describe the same format.
//!
//! The doc is the contract other sessions read before touching the wire;
//! the constants are what the code actually emits and rejects. This rule
//! makes every drift between them — a renumbered tag, a widened header,
//! a raised frame cap, a stale table row — a build failure with a
//! file:line pointing at whichever side is wrong.

use std::collections::BTreeMap;

use super::source::{is_ident, match_brace, Diagnostic, SourceFile, SourceTree};

pub const RULE: &str = "wire-spec";

const FRAME_RS: &str = "rust/src/transport/frame.rs";
const CODEC_RS: &str = "rust/src/transport/codec.rs";
const WIRE_MD: &str = "rust/docs/WIRE.md";

pub fn check(tree: &SourceTree) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let (Some(frame), Some(codec), Some(doc)) = (
        tree.file("transport/frame.rs"),
        tree.file("transport/codec.rs"),
        tree.file("docs/WIRE.md"),
    ) else {
        for (have, path) in [
            (tree.file("transport/frame.rs").is_some(), FRAME_RS),
            (tree.file("transport/codec.rs").is_some(), CODEC_RS),
            (tree.file("docs/WIRE.md").is_some(), WIRE_MD),
        ] {
            if !have {
                out.push(Diagnostic {
                    file: path.to_string(),
                    line: 1,
                    rule: RULE,
                    message: "wire-spec scope file missing from the tree".to_string(),
                });
            }
        }
        return out;
    };

    let fr = consts_of(frame);
    let co = consts_of(codec);
    let tables = tables_of(doc);

    check_frame(frame, &fr, doc, &tables, &mut out);
    check_codec_header(codec, &co, doc, &tables, &mut out);
    check_tags(codec, &co, doc, &tables, &mut out);
    out
}

/// One `const NAME: T = VALUE;` (or enum discriminant) pulled from
/// masked source: name, parsed value when the expression is a literal
/// (decimal, hex, or `A << B`), and the byte offset for line anchoring.
struct Const {
    name: String,
    value: Option<u64>,
    offset: usize,
}

fn consts_of(file: &SourceFile) -> Vec<Const> {
    let m = file.masked.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(rel) = file.masked.get(from..).and_then(|s| s.find("const ")) {
        let at = from + rel;
        from = at + 6;
        if at > 0 && m.get(at - 1).is_some_and(|&p| is_ident(p)) {
            continue;
        }
        let mut i = at + 6;
        while m.get(i).is_some_and(|&c| c == b' ') {
            i += 1;
        }
        let start = i;
        while m.get(i).is_some_and(|&c| is_ident(c)) {
            i += 1;
        }
        let name = file.masked.get(start..i).unwrap_or("").to_string();
        if name.is_empty() || name == "fn" {
            continue;
        }
        let Some(eq) = file.masked.get(i..).and_then(|s| s.find('=')).map(|r| i + r) else {
            continue;
        };
        let Some(semi) = file.masked.get(eq..).and_then(|s| s.find(';')).map(|r| eq + r) else {
            continue;
        };
        let value = parse_value(file.masked.get(eq + 1..semi).unwrap_or(""));
        out.push(Const { name, value, offset: at });
    }
    out
}

/// Discriminants of `enum <name> { A = 0, B = 1, ... }` in masked source.
fn enum_variants(file: &SourceFile, enum_name: &str) -> Option<Vec<(String, u64)>> {
    let needle = format!("enum {enum_name}");
    let at = file.masked.find(&needle)?;
    let open = at + file.masked.get(at..)?.find('{')?;
    let close = match_brace(file.masked.as_bytes(), open)?;
    let body = file.masked.get(open + 1..close)?;
    let mut out = Vec::new();
    for entry in body.split(',') {
        let Some((name, value)) = entry.split_once('=') else {
            continue;
        };
        let name = name.trim();
        if name.is_empty() || !name.bytes().all(is_ident) {
            continue;
        }
        if let Some(v) = parse_value(value) {
            out.push((name.to_string(), v));
        }
    }
    Some(out)
}

/// Parse a literal const expression: decimal, `0x` hex (type suffixes and
/// `_` separators tolerated), or a single `A << B` shift. Anything else
/// (e.g. `u32::MAX`, `Duration::from_secs(10)`) is None — not a wire
/// constant this rule can or should pin.
fn parse_value(expr: &str) -> Option<u64> {
    let expr = expr.trim();
    if let Some((a, b)) = expr.split_once("<<") {
        return parse_value(a)?.checked_shl(u32::try_from(parse_value(b)?).ok()?);
    }
    let expr = expr.replace('_', "");
    let expr = expr.trim();
    if let Some(hex) = expr.strip_prefix("0x") {
        let digits: String = hex.chars().take_while(char::is_ascii_hexdigit).collect();
        return (!digits.is_empty()).then(|| u64::from_str_radix(&digits, 16).ok())?;
    }
    let digits: String = expr.chars().take_while(char::is_ascii_digit).collect();
    (!digits.is_empty()).then(|| digits.parse().ok())?
}

struct Row {
    line: usize,
    cells: Vec<String>,
}

struct Table {
    heading: String,
    heading_line: usize,
    line: usize,
    rows: Vec<Row>,
}

/// Markdown tables of a doc file, each tagged with the `#` heading in
/// force where it starts. `\|` inside a cell is an escaped pipe, not a
/// column break.
fn tables_of(doc: &SourceFile) -> Vec<Table> {
    let mut out: Vec<Table> = Vec::new();
    let mut heading = String::new();
    let mut heading_line = 0usize;
    let mut cur: Option<Table> = None;
    for (k, line) in doc.raw.lines().enumerate() {
        let t = line.trim();
        if t.starts_with('#') {
            heading = t.trim_start_matches('#').trim().to_string();
            heading_line = k + 1;
        }
        if t.starts_with('|') {
            let mut cells: Vec<String> = Vec::new();
            for part in t.trim_matches('|').split('|') {
                if let Some(prev) = cells.last_mut() {
                    if prev.ends_with('\\') {
                        prev.pop();
                        prev.push('|');
                        prev.push_str(part);
                        continue;
                    }
                }
                cells.push(part.to_string());
            }
            let cells: Vec<String> = cells.into_iter().map(|c| c.trim().to_string()).collect();
            let separator = cells
                .iter()
                .all(|c| !c.is_empty() && c.bytes().all(|b| b == b'-' || b == b':'));
            if separator {
                continue;
            }
            cur.get_or_insert_with(|| Table {
                heading: heading.clone(),
                heading_line,
                line: k + 1,
                rows: Vec::new(),
            })
            .rows
            .push(Row { line: k + 1, cells });
        } else if let Some(done) = cur.take() {
            out.push(done);
        }
    }
    if let Some(done) = cur.take() {
        out.push(done);
    }
    out
}

fn value_of<'a>(
    consts: &'a [Const],
    file: &SourceFile,
    name: &str,
    out: &mut Vec<Diagnostic>,
) -> Option<(&'a Const, u64)> {
    let Some(c) = consts.iter().find(|c| c.name == name) else {
        out.push(file.diag_line(RULE, 1, format!("expected wire constant `{name}` not found")));
        return None;
    };
    let Some(v) = c.value else {
        out.push(file.diag(
            RULE,
            c.offset,
            format!("wire constant `{name}` has a value this rule cannot parse"),
        ));
        return None;
    };
    Some((c, v))
}

/// The field-name column (3rd cell) keys both header tables.
fn field_row<'a>(table: &'a Table, field: &str) -> Option<&'a Row> {
    table.rows.iter().find(|r| r.cells.get(2).is_some_and(|c| c == field))
}

/// Largest `offset + size` over rows whose first two cells are numeric —
/// the byte one past the fixed header (the payload row's `n` size cell
/// drops out naturally).
fn header_end(table: &Table) -> Option<u64> {
    table
        .rows
        .iter()
        .filter_map(|r| {
            let off: u64 = r.cells.first()?.parse().ok()?;
            let size: u64 = r.cells.get(1)?.parse().ok()?;
            Some(off + size)
        })
        .max()
}

fn check_frame(
    frame: &SourceFile,
    fr: &[Const],
    doc: &SourceFile,
    tables: &[Table],
    out: &mut Vec<Diagnostic>,
) {
    let magic = value_of(fr, frame, "FRAME_MAGIC", out);
    let version = value_of(fr, frame, "FRAME_VERSION", out);
    let header = value_of(fr, frame, "FRAME_HEADER_BYTES", out);
    let max = value_of(fr, frame, "MAX_FRAME_BYTES", out);
    let kinds = enum_variants(frame, "FrameKind");
    if kinds.is_none() {
        out.push(frame.diag_line(
            RULE,
            1,
            "expected `enum FrameKind` with explicit discriminants".to_string(),
        ));
    }

    let Some(table) = tables.iter().find(|t| t.heading.contains("Frame layer")) else {
        out.push(doc.diag_line(
            RULE,
            1,
            "WIRE.md has no table under a `Frame layer` heading".to_string(),
        ));
        return;
    };
    let mut want_cell = |field: &str, needle: String, what: &str| match field_row(table, field) {
        Some(row) => {
            if !row.cells.get(3).is_some_and(|c| c.contains(&needle)) {
                out.push(doc.diag_line(
                    RULE,
                    row.line,
                    format!("frame `{field}` row does not mention `{needle}` ({what})"),
                ));
            }
        }
        None => out.push(doc.diag_line(
            RULE,
            table.line,
            format!("frame table has no `{field}` row"),
        )),
    };
    if let Some((_, v)) = magic {
        want_cell("magic", format!("0x{v:04x}"), "frame.rs FRAME_MAGIC");
    }
    if let Some((_, v)) = version {
        want_cell("version", format!("`{v}`"), "frame.rs FRAME_VERSION");
    }
    if let Some((_, v)) = max {
        want_cell("length", format!("{} MiB", v >> 20), "frame.rs MAX_FRAME_BYTES");
    }
    if let Some(kinds) = &kinds {
        for (name, disc) in kinds {
            want_cell("kind", format!("`{disc}` {}", name.to_lowercase()), "frame.rs FrameKind");
        }
    }
    if let Some((_, v)) = header {
        match field_row(table, "payload") {
            Some(row) => {
                if row.cells.first().map(String::as_str) != Some(v.to_string().as_str()) {
                    out.push(doc.diag_line(
                        RULE,
                        row.line,
                        format!("frame payload offset disagrees with FRAME_HEADER_BYTES = {v}"),
                    ));
                }
            }
            None => out.push(doc.diag_line(
                RULE,
                table.line,
                "frame table has no `payload` row".to_string(),
            )),
        }
        if header_end(table) != Some(v) {
            out.push(doc.diag_line(
                RULE,
                table.line,
                format!(
                    "frame table fixed fields do not span exactly FRAME_HEADER_BYTES = {v} bytes"
                ),
            ));
        }
    }
}

fn check_codec_header(
    codec: &SourceFile,
    co: &[Const],
    doc: &SourceFile,
    tables: &[Table],
    out: &mut Vec<Diagnostic>,
) {
    let magic = value_of(co, codec, "MAGIC", out);
    let version = value_of(co, codec, "VERSION", out);
    let header = value_of(co, codec, "HEADER_BYTES", out);

    let Some(table) = tables.iter().find(|t| t.heading.contains("Codec header")) else {
        out.push(doc.diag_line(
            RULE,
            1,
            "WIRE.md has no table under a `Codec header` heading".to_string(),
        ));
        return;
    };
    let mut want_cell = |field: &str, needle: String, what: &str| match field_row(table, field) {
        Some(row) => {
            if !row.cells.get(3).is_some_and(|c| c.contains(&needle)) {
                out.push(doc.diag_line(
                    RULE,
                    row.line,
                    format!("codec `{field}` row does not mention `{needle}` ({what})"),
                ));
            }
        }
        None => out.push(doc.diag_line(
            RULE,
            table.line,
            format!("codec header table has no `{field}` row"),
        )),
    };
    if let Some((_, v)) = magic {
        want_cell("magic", format!("0x{v:04x}"), "codec.rs MAGIC");
    }
    if let Some((_, v)) = version {
        want_cell("version", format!("`{v}`"), "codec.rs VERSION");
    }
    if let Some((_, v)) = header {
        if !table.heading.contains(&format!("({v} bytes")) {
            out.push(doc.diag_line(
                RULE,
                table.heading_line,
                format!("codec header heading does not state `({v} bytes` (codec.rs HEADER_BYTES)"),
            ));
        }
        if header_end(table) != Some(v) {
            out.push(doc.diag_line(
                RULE,
                table.line,
                format!("codec header rows do not span exactly HEADER_BYTES = {v} bytes"),
            ));
        }
    }
}

/// The body-tag registry must match 1:1: every `TAG_*` constant is a row
/// in a `Body tags` table and every row's tag number has a constant.
fn check_tags(
    codec: &SourceFile,
    co: &[Const],
    doc: &SourceFile,
    tables: &[Table],
    out: &mut Vec<Diagnostic>,
) {
    let mut documented: BTreeMap<u64, usize> = BTreeMap::new();
    for table in tables.iter().filter(|t| t.heading.contains("Body tags")) {
        for row in &table.rows {
            if let Some(tag) = row.cells.first().and_then(|c| c.parse::<u64>().ok()) {
                documented.entry(tag).or_insert(row.line);
            }
        }
    }
    if documented.is_empty() {
        out.push(doc.diag_line(
            RULE,
            1,
            "WIRE.md has no `Body tags` table with numeric tag rows".to_string(),
        ));
        return;
    }
    let mut declared: BTreeMap<u64, &Const> = BTreeMap::new();
    for c in co.iter().filter(|c| c.name.starts_with("TAG_")) {
        match c.value {
            Some(v) => {
                declared.insert(v, c);
            }
            None => out.push(codec.diag(
                RULE,
                c.offset,
                format!("tag constant `{}` has a value this rule cannot parse", c.name),
            )),
        }
    }
    for (tag, c) in &declared {
        if !documented.contains_key(tag) {
            out.push(codec.diag(
                RULE,
                c.offset,
                format!("`{}` (= {tag}) is not documented in any WIRE.md body-tag table", c.name),
            ));
        }
    }
    for (tag, line) in &documented {
        if !declared.contains_key(tag) {
            out.push(doc.diag_line(
                RULE,
                *line,
                format!(
                    "stale entry: WIRE.md documents body tag {tag} \
                     but codec.rs declares no TAG_ constant for it"
                ),
            ));
        }
    }
}
