//! Rule `config-drift`: every `ExperimentConfig` field keeps its whole
//! surface in step.
//!
//! A config knob reaches users through up to four doors: the struct
//! field, its JSON key (hand-rolled serde in `config/experiment.rs` —
//! one mention encoding, one decoding), a CLI override flag, and a doc
//! mention where the knob changes wire or scale behavior. Past PRs have
//! drifted here in both directions (a field with no CLI override, a doc
//! describing a knob by a stale name), so the registry below is explicit
//! and exhaustive: a new field that is not classified is a diagnostic,
//! as is a classified field that no longer exists.

use super::source::{is_ident, match_brace, Diagnostic, SourceFile, SourceTree};

pub const RULE: &str = "config-drift";

const EXPERIMENT_RS: &str = "rust/src/config/experiment.rs";
/// Files that may define an override flag for a field.
const CLI_FILES: &[&str] = &["src/main.rs", "figures/common.rs"];

/// One field's declared surface: the JSON key is always the field name;
/// `cli` is the override flag (quoted somewhere in the CLI opt tables);
/// `doc` is the doc page that must mention the field by name.
pub struct Entry {
    pub field: &'static str,
    pub cli: Option<&'static str>,
    pub doc: Option<&'static str>,
}

const fn entry(field: &'static str, cli: Option<&'static str>, doc: Option<&'static str>) -> Entry {
    Entry { field, cli, doc }
}

/// The registry. Keep in step with `ExperimentConfig` and `docs/LINTS.md`.
pub const TABLE: &[Entry] = &[
    entry("label", None, None),
    entry("model", None, None),
    entry("clients", Some("clients"), None),
    entry("rounds", Some("rounds"), None),
    entry("local_epochs", None, None),
    entry("lr", None, None),
    entry("sampling", None, None),
    entry("min_clients", None, None),
    entry("masking", None, None),
    entry("mask_target", None, None),
    entry("partition", None, None),
    entry("n_train", None, None),
    entry("n_test", None, None),
    entry("seed", Some("seed"), None),
    entry("eval_every", None, None),
    entry("eval_max_chunks", None, None),
    entry("ack_prob", Some("ack-prob"), None),
    entry("straggler_prob", Some("straggler-prob"), None),
    entry("compute_mean_s", None, None),
    entry("compute_jitter", Some("compute-jitter"), None),
    entry("availability_seed", None, None),
    entry("network", None, None),
    entry("encoding", Some("encoding"), Some("WIRE.md")),
    entry("transport", Some("transport"), None),
    entry("downlink_delta", Some("downlink-delta"), Some("WIRE.md")),
    entry("aggregator", None, None),
    entry("workers", Some("workers"), None),
    entry("drain_poll_ms", Some("drain-poll-ms"), Some("SCALE.md")),
    entry("agg_shards", Some("agg-shards"), Some("SCALE.md")),
    entry("max_conns", Some("max-conns"), Some("SCALE.md")),
    entry("chaos", Some("chaos-seed"), None),
];

pub fn check(tree: &SourceTree) -> Vec<Diagnostic> {
    check_with(tree, TABLE)
}

pub fn check_with(tree: &SourceTree, table: &[Entry]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let Some(exp) = tree.file("config/experiment.rs") else {
        out.push(Diagnostic {
            file: EXPERIMENT_RS.to_string(),
            line: 1,
            rule: RULE,
            message: "config-drift scope file missing from the tree".to_string(),
        });
        return out;
    };
    let Some(fields) = struct_fields(exp, "ExperimentConfig") else {
        out.push(exp.diag_line(RULE, 1, "struct ExperimentConfig not found".to_string()));
        return out;
    };

    for (field, offset) in &fields {
        let Some(e) = table.iter().find(|e| e.field == field.as_str()) else {
            out.push(exp.diag(
                RULE,
                *offset,
                format!(
                    "unclassified config field `{field}` — add it to lint::config_drift::TABLE"
                ),
            ));
            continue;
        };
        // serde: the hand-rolled codec quotes the key once to encode and
        // once to decode; fewer mentions means one side lost the field
        let key = format!("\"{field}\"");
        let mentions = exp.raw.matches(&key).count();
        if mentions < 2 {
            out.push(exp.diag(
                RULE,
                *offset,
                format!(
                    "serde key {key} appears {mentions}x in experiment.rs — need encode and decode"
                ),
            ));
        }
        if let Some(flag) = e.cli {
            let quoted = format!("\"{flag}\"");
            let in_cli = CLI_FILES
                .iter()
                .filter_map(|s| tree.file(s))
                .any(|f| f.raw.contains(&quoted));
            if !in_cli {
                out.push(exp.diag(
                    RULE,
                    *offset,
                    format!(
                        "config field `{field}` declares CLI flag --{flag}, \
                         but no opt table quotes {quoted}"
                    ),
                ));
            }
        }
        if let Some(doc) = e.doc {
            let mentioned = tree.file(doc).is_some_and(|f| f.raw.contains(field.as_str()));
            if !mentioned {
                out.push(exp.diag(
                    RULE,
                    *offset,
                    format!("config field `{field}` must be mentioned by name in docs/{doc}"),
                ));
            }
        }
    }

    for e in table {
        if !fields.iter().any(|(f, _)| f == e.field) {
            out.push(exp.diag_line(
                RULE,
                1,
                format!(
                    "stale entry: lint::config_drift::TABLE lists `{}` \
                     but ExperimentConfig has no such field",
                    e.field
                ),
            ));
        }
    }
    out
}

/// `(field name, byte offset)` for each `pub name: Type,` line of the
/// struct's block, parsed from masked source.
fn struct_fields(file: &SourceFile, name: &str) -> Option<Vec<(String, usize)>> {
    let needle = format!("struct {name}");
    let at = file.masked.find(&needle)?;
    let open = at + file.masked.get(at..)?.find('{')?;
    let close = match_brace(file.masked.as_bytes(), open)?;
    let body = file.masked.get(open + 1..close)?;
    let b = body.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(rel) = body.get(from..).and_then(|s| s.find("pub ")) {
        let field_at = from + rel;
        from = field_at + 4;
        if field_at > 0 && b.get(field_at - 1).is_some_and(|&p| is_ident(p)) {
            continue;
        }
        let mut i = field_at + 4;
        while b.get(i).is_some_and(|&c| c == b' ') {
            i += 1;
        }
        let start = i;
        while b.get(i).is_some_and(|&c| is_ident(c)) {
            i += 1;
        }
        // a field is `pub ident:` — methods (`pub fn`) and nested items
        // fall out on the colon test
        if i > start && b.get(i).copied() == Some(b':') {
            let field = body.get(start..i)?.to_string();
            if field != "crate" {
                out.push((field, open + 1 + field_at));
            }
        }
    }
    Some(out)
}
