//! fedlint — a project-invariant static-analysis pass over this repo's
//! own sources and docs.
//!
//! Rustc and clippy check what any Rust program must satisfy; fedlint
//! checks what *this* program promised. Each rule pins an invariant a
//! previous PR established in prose (`docs/WIRE.md`, `docs/SCALE.md`)
//! or in review discipline, so the promise breaks a build instead of
//! silently rotting:
//!
//! * [`wire_spec`] — the constants in `transport/{frame,codec}.rs` and
//!   the grammar tables in `docs/WIRE.md` describe the same wire format.
//! * [`pre_decode`] — no codec decode on a frame payload before
//!   `validate_upload` has vouched for the session.
//! * [`panic_free`] — the untrusted-input paths (frame reader, codec
//!   decode, chaos ingestion) contain no panicking constructs.
//! * [`config_drift`] — every `ExperimentConfig` field keeps its serde
//!   key, CLI flag, and doc mention in step.
//! * [`lock_order`] — the socket reactor's lock acquisition graph stays
//!   acyclic.
//!
//! A finding is suppressed only by an inline annotation in a line
//! comment — the `fedlint:` marker followed by `allow(<rule>) -- <reason>`
//! (exact syntax in `docs/LINTS.md`). The annotation covers its own line
//! and the next; the reason is mandatory and a malformed annotation is
//! itself a diagnostic ([`source::ALLOWLIST_RULE`]) that nothing can
//! suppress.
//!
//! The pass is pure std and runs without the `xla` feature:
//! `cargo run --bin fedlint --no-default-features -- --deny-all`.

pub mod config_drift;
pub mod lock_order;
pub mod panic_free;
pub mod pre_decode;
pub mod source;
pub mod wire_spec;

pub use source::{Diagnostic, SourceTree};

/// Every rule fedlint knows, including the meta-rule that validates the
/// allowlist annotations themselves.
pub const RULES: &[&str] = &[
    source::ALLOWLIST_RULE,
    wire_spec::RULE,
    pre_decode::RULE,
    panic_free::RULE,
    config_drift::RULE,
    lock_order::RULE,
];

/// Run every rule over `tree`, then apply allowlist suppression and sort.
pub fn run(tree: &SourceTree) -> Vec<Diagnostic> {
    let mut diags = source::check_annotations(tree);
    diags.extend(wire_spec::check(tree));
    diags.extend(pre_decode::check(tree));
    diags.extend(panic_free::check(tree));
    diags.extend(config_drift::check(tree));
    diags.extend(lock_order::check(tree));
    apply_allowlist(tree, diags)
}

/// Drop diagnostics covered by a well-formed allowlist annotation and
/// return the rest sorted by (file, line, rule). [`source::ALLOWLIST_RULE`]
/// findings are never suppressible — a broken annotation must not hide
/// itself.
pub fn apply_allowlist(tree: &SourceTree, mut diags: Vec<Diagnostic>) -> Vec<Diagnostic> {
    diags.retain(|d| d.rule == source::ALLOWLIST_RULE || !tree.is_allowed(d));
    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    diags
}
