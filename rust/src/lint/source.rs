//! Lexical groundwork for the fedlint rules: source loading, comment and
//! string masking, function spans, test-module spans, line lookup, and the
//! inline allowlist annotations.
//!
//! The masking pass is the load-bearing trick: `masked` is a byte-for-byte
//! copy of the file where every comment, string literal, and char literal
//! is blanked to spaces (newlines kept, so offsets and line numbers agree
//! with the original). Rules scan `masked` for code tokens — a `.unwrap()`
//! inside a doc comment or an error-message string can never fire — and
//! scan `raw` only for things that *live* in comments or strings (the
//! allowlist annotations, quoted CLI flag names, serde keys).

use std::fs;
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};

/// The meta-rule name for malformed allowlist annotations.
pub const ALLOWLIST_RULE: &str = "allowlist-syntax";

/// One finding: where, which rule, and what is wrong.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Root-relative path, `/`-separated (e.g. `rust/src/transport/frame.rs`).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

/// One parsed inline allowlist annotation (`allow(<rule>) -- <reason>`
/// in a line comment after the `fedlint:` marker; full syntax in
/// `docs/LINTS.md`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allow {
    /// 1-based line the annotation sits on. A trailing annotation covers
    /// its own line; a standalone comment line covers the next line.
    pub line: usize,
    pub rule: String,
    pub has_reason: bool,
}

/// One function found by the span scanner.
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    /// Byte offset of the `fn` keyword.
    pub sig_start: usize,
    /// Byte offset of the body's opening `{`.
    pub body_start: usize,
    /// Byte offset of the body's closing `}` (inclusive end of the fn).
    pub body_end: usize,
    /// True when the fn lives inside a `#[cfg(test)] mod` block.
    pub in_test: bool,
}

/// One loaded file plus everything the rules need to scan it.
#[derive(Debug)]
pub struct SourceFile {
    pub path: String,
    pub raw: String,
    pub masked: String,
    line_starts: Vec<usize>,
    fns: Vec<FnSpan>,
    pub allows: Vec<Allow>,
}

impl SourceFile {
    fn load_rust(path: String, raw: String) -> SourceFile {
        let masked = mask_rust(&raw);
        let line_starts = line_starts(&raw);
        let test_spans = test_spans(&masked);
        let fns = fn_spans(&masked, &test_spans);
        let allows = parse_allows(&raw);
        SourceFile {
            path,
            raw,
            masked,
            line_starts,
            fns,
            allows,
        }
    }

    fn load_doc(path: String, raw: String) -> SourceFile {
        let masked = raw.clone();
        let line_starts = line_starts(&raw);
        SourceFile {
            path,
            raw,
            masked,
            line_starts,
            fns: Vec::new(),
            allows: Vec::new(),
        }
    }

    /// 1-based line number of a byte offset.
    pub fn line_of(&self, offset: usize) -> usize {
        self.line_starts.partition_point(|&s| s <= offset)
    }

    /// 1-based line of the first raw-text occurrence of `needle`.
    pub fn find_line(&self, needle: &str) -> Option<usize> {
        self.raw.find(needle).map(|off| self.line_of(off))
    }

    pub fn fns(&self) -> &[FnSpan] {
        &self.fns
    }

    /// Build a diagnostic anchored at a byte offset in this file.
    pub fn diag(&self, rule: &'static str, offset: usize, message: String) -> Diagnostic {
        Diagnostic {
            file: self.path.clone(),
            line: self.line_of(offset),
            rule,
            message,
        }
    }

    /// Build a diagnostic anchored at a 1-based line in this file.
    pub fn diag_line(&self, rule: &'static str, line: usize, message: String) -> Diagnostic {
        Diagnostic {
            file: self.path.clone(),
            line,
            rule,
            message,
        }
    }
}

/// Every source and doc file fedlint scans, loaded from a repo root.
#[derive(Debug)]
pub struct SourceTree {
    pub root: PathBuf,
    pub files: Vec<SourceFile>,
}

impl SourceTree {
    /// Load `rust/src/**/*.rs` and `rust/docs/**/*.md` under `root`.
    /// `rust/tests/` is deliberately not scanned: that is where the lint
    /// fixture corpus (seeded violations) lives.
    pub fn load(root: &Path) -> Result<SourceTree> {
        let src = root.join("rust/src");
        if !src.is_dir() {
            return Err(Error::invalid(format!(
                "{} does not look like a repo root (no rust/src)",
                root.display()
            )));
        }
        let mut paths = Vec::new();
        walk(&src, "rs", &mut paths)?;
        let docs = root.join("rust/docs");
        if docs.is_dir() {
            walk(&docs, "md", &mut paths)?;
        }
        let mut files = Vec::new();
        for p in paths {
            let raw = fs::read_to_string(&p)?;
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            if p.extension().and_then(|e| e.to_str()) == Some("rs") {
                files.push(SourceFile::load_rust(rel, raw));
            } else {
                files.push(SourceFile::load_doc(rel, raw));
            }
        }
        Ok(SourceTree {
            root: root.to_path_buf(),
            files,
        })
    }

    /// Look a file up by path suffix (e.g. `transport/frame.rs`).
    pub fn file(&self, suffix: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.path.ends_with(suffix))
    }

    /// True when `d` is covered by a well-formed allowlist annotation for
    /// its rule. A malformed annotation (unknown rule, missing reason)
    /// never suppresses — it fires [`ALLOWLIST_RULE`] instead.
    pub fn is_allowed(&self, d: &Diagnostic) -> bool {
        let Some(file) = self.files.iter().find(|f| f.path == d.file) else {
            return false;
        };
        file.allows.iter().any(|a| {
            a.rule == d.rule
                && a.has_reason
                && super::RULES.contains(&a.rule.as_str())
                && (a.line == d.line || a.line + 1 == d.line)
        })
    }
}

/// The meta-rule: every annotation must name a known rule and carry a
/// ` -- <reason>` tail. A broken annotation is a diagnostic of its own
/// (and never suppresses anything).
pub fn check_annotations(tree: &SourceTree) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for file in &tree.files {
        for a in &file.allows {
            if !super::RULES.contains(&a.rule.as_str()) {
                out.push(file.diag_line(
                    ALLOWLIST_RULE,
                    a.line,
                    format!("allow() names unknown rule '{}'", a.rule),
                ));
            }
            if !a.has_reason {
                out.push(file.diag_line(
                    ALLOWLIST_RULE,
                    a.line,
                    format!("allow({}) missing ` -- <reason>`", a.rule),
                ));
            }
        }
    }
    out
}

fn walk(dir: &Path, ext: &str, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries = Vec::new();
    for e in fs::read_dir(dir)? {
        entries.push(e?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, ext, out)?;
        } else if p.extension().and_then(|s| s.to_str()) == Some(ext) {
            out.push(p);
        }
    }
    Ok(())
}

fn line_starts(raw: &str) -> Vec<usize> {
    let mut starts = vec![0usize];
    for (i, b) in raw.bytes().enumerate() {
        if b == b'\n' {
            starts.push(i + 1);
        }
    }
    starts
}

pub(crate) fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Blank comments, string literals, and char literals to spaces, keeping
/// newlines (and therefore offsets and line numbers) intact.
fn mask_rust(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = b.to_vec();
    let mut i = 0usize;
    let blank = |out: &mut [u8], k: usize| {
        if let Some(c) = out.get_mut(k) {
            if *c != b'\n' {
                *c = b' ';
            }
        }
    };
    while i < b.len() {
        let c = b[i];
        let next = b.get(i + 1).copied().unwrap_or(0);
        // identifier prefix guard: `r`/`b` only start a literal when not
        // part of a longer identifier (e.g. `for r in ...`)
        let prev_ident = i > 0 && b.get(i - 1).is_some_and(|&p| is_ident(p));
        if c == b'/' && next == b'/' {
            while i < b.len() && b[i] != b'\n' {
                blank(&mut out, i);
                i += 1;
            }
        } else if c == b'/' && next == b'*' {
            let mut depth = 1usize;
            blank(&mut out, i);
            blank(&mut out, i + 1);
            i += 2;
            while i < b.len() && depth > 0 {
                let n2 = b.get(i + 1).copied().unwrap_or(0);
                if b[i] == b'/' && n2 == b'*' {
                    depth += 1;
                    blank(&mut out, i);
                    blank(&mut out, i + 1);
                    i += 2;
                } else if b[i] == b'*' && n2 == b'/' {
                    depth -= 1;
                    blank(&mut out, i);
                    blank(&mut out, i + 1);
                    i += 2;
                } else {
                    blank(&mut out, i);
                    i += 1;
                }
            }
        } else if c == b'"' {
            i = mask_string(b, &mut out, i);
        } else if (c == b'r' || c == b'b') && !prev_ident {
            // r"...", r#"..."#, b"...", br"...", b'x'
            let mut j = i + 1;
            if c == b'b' && b.get(j).copied() == Some(b'r') {
                j += 1;
            }
            let mut hashes = 0usize;
            // only the r-forms (r"..", r#".."#, br#".."#) take hashes
            while (c == b'r' || j > i + 1) && b.get(j).copied() == Some(b'#') {
                hashes += 1;
                j += 1;
            }
            if b.get(j).copied() == Some(b'"') && (c == b'r' || j > i + 1 || hashes == 0) {
                if c == b'b' && j == i + 1 && hashes == 0 {
                    // b"..." — plain string with escapes
                    i = mask_string(b, &mut out, j);
                } else if c == b'r' || j > i + 1 {
                    // raw string: no escapes, terminated by `"` + hashes
                    let mut k = j + 1;
                    'raw: while k < b.len() {
                        if b[k] == b'"' {
                            let mut h = 0usize;
                            while h < hashes && b.get(k + 1 + h).copied() == Some(b'#') {
                                h += 1;
                            }
                            if h == hashes {
                                for m in j..=k + hashes {
                                    blank(&mut out, m);
                                }
                                i = k + hashes + 1;
                                break 'raw;
                            }
                        }
                        blank(&mut out, k);
                        k += 1;
                    }
                    if k >= b.len() {
                        i = k;
                    }
                } else {
                    i += 1;
                }
            } else if c == b'b' && b.get(i + 1).copied() == Some(b'\'') {
                i = mask_char(b, &mut out, i + 1);
            } else {
                i += 1;
            }
        } else if c == b'\'' {
            i = mask_char(b, &mut out, i);
        } else {
            i += 1;
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Blank a `"`-delimited string starting at `open`; returns the offset
/// after the closing quote.
fn mask_string(b: &[u8], out: &mut [u8], open: usize) -> usize {
    let blank = |out: &mut [u8], k: usize| {
        if let Some(c) = out.get_mut(k) {
            if *c != b'\n' {
                *c = b' ';
            }
        }
    };
    blank(out, open);
    let mut i = open + 1;
    while i < b.len() {
        if b[i] == b'\\' {
            blank(out, i);
            blank(out, i + 1);
            i += 2;
        } else if b[i] == b'"' {
            blank(out, i);
            return i + 1;
        } else {
            blank(out, i);
            i += 1;
        }
    }
    i
}

/// Blank a char literal at `quote` if it is one (returns the offset past
/// it); a lifetime is left untouched (returns `quote + 1`).
fn mask_char(b: &[u8], out: &mut [u8], quote: usize) -> usize {
    let blank = |out: &mut [u8], k: usize| {
        if let Some(c) = out.get_mut(k) {
            if *c != b'\n' {
                *c = b' ';
            }
        }
    };
    let next = b.get(quote + 1).copied().unwrap_or(0);
    if next == b'\\' {
        // escaped char literal: blank to the closing quote
        let mut i = quote + 2;
        // the escape body itself ('\n', '\u{1f600}', '\'')
        while i < b.len() && b[i] != b'\'' {
            i += 1;
        }
        for k in quote..=i.min(b.len().saturating_sub(1)) {
            blank(out, k);
        }
        return i + 1;
    }
    if b.get(quote + 2).copied() == Some(b'\'') && next != b'\'' {
        // simple one-byte char literal 'x'
        for k in quote..=quote + 2 {
            blank(out, k);
        }
        return quote + 3;
    }
    if next >= 0x80 {
        // multi-byte char literal: the closing quote sits within 5 bytes
        for len in 2..=5usize {
            if b.get(quote + len).copied() == Some(b'\'') {
                for k in quote..=quote + len {
                    blank(out, k);
                }
                return quote + len + 1;
            }
        }
    }
    // lifetime ('a, 'static, '_) — leave it in the code channel
    quote + 1
}

/// Offset of the `}` matching the `{` at `open` in masked text.
pub(crate) fn match_brace(masked: &[u8], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (k, &c) in masked.iter().enumerate().skip(open) {
        match c {
            b'{' => depth += 1,
            b'}' => match depth {
                0 => return None,
                1 => return Some(k),
                _ => depth -= 1,
            },
            _ => {}
        }
    }
    None
}

/// Byte ranges of `#[cfg(test)] mod` blocks in masked text.
fn test_spans(masked: &str) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let needle = "#[cfg(test)]";
    let bytes = masked.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = masked.get(from..).and_then(|s| s.find(needle)) {
        let at = from + rel;
        from = at + needle.len();
        // the attribute must introduce a `mod` item (not a test fn inside
        // an already-recorded block — those are covered by their mod)
        let after = masked.get(from..from + 64).unwrap_or("").trim_start();
        let is_mod = after.starts_with("mod ") || after.starts_with("pub mod ");
        if !is_mod {
            continue;
        }
        if let Some(open_rel) = masked.get(from..).and_then(|s| s.find('{')) {
            let open = from + open_rel;
            if let Some(close) = match_brace(bytes, open) {
                spans.push((at, close + 1));
                from = close + 1;
            }
        }
    }
    spans
}

/// Every `fn name(...) { ... }` in masked text (fns without bodies are
/// skipped). Nested fns each get their own span.
fn fn_spans(masked: &str, test_spans: &[(usize, usize)]) -> Vec<FnSpan> {
    let b = masked.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(rel) = masked.get(from..).and_then(|s| s.find("fn")) {
        let at = from + rel;
        from = at + 2;
        let before_ok = at == 0 || b.get(at.wrapping_sub(1)).is_none_or(|&p| !is_ident(p));
        let after_ok = b.get(at + 2).is_none_or(|&n| !is_ident(n));
        if !before_ok || !after_ok {
            continue;
        }
        // name
        let mut i = at + 2;
        while b.get(i).is_some_and(|&c| c == b' ' || c == b'\n') {
            i += 1;
        }
        let name_start = i;
        while b.get(i).is_some_and(|&c| is_ident(c)) {
            i += 1;
        }
        if i == name_start {
            continue; // `fn(...)` pointer type, `Fn` trait, etc.
        }
        let name = masked.get(name_start..i).unwrap_or("").to_string();
        // first `{` or `;` at paren/bracket depth 0 ends the signature;
        // brackets matter because return types like `Result<[u8; N]>`
        // put a `;` outside any parens
        let mut depth = 0usize;
        let mut body_start = None;
        while let Some(&c) = b.get(i) {
            match c {
                b'(' | b'[' => depth += 1,
                b')' | b']' => depth = depth.saturating_sub(1),
                b'{' if depth == 0 => {
                    body_start = Some(i);
                    break;
                }
                b';' if depth == 0 => break,
                _ => {}
            }
            i += 1;
        }
        let Some(body_start) = body_start else {
            continue;
        };
        let Some(body_end) = match_brace(b, body_start) else {
            continue;
        };
        let in_test = test_spans.iter().any(|&(s, e)| at >= s && at < e);
        out.push(FnSpan {
            name,
            sig_start: at,
            body_start,
            body_end,
            in_test,
        });
        from = body_start + 1;
    }
    out
}

/// Parse every allowlist annotation in raw text. The needle is assembled
/// at runtime so this file's own string literals never read as one.
fn parse_allows(raw: &str) -> Vec<Allow> {
    let needle = concat!("fed", "lint: allow(");
    let mut out = Vec::new();
    for (k, line) in raw.lines().enumerate() {
        let Some(i) = line.find(needle) else {
            continue;
        };
        // annotations live in line comments
        if !line.get(..i).is_some_and(|head| head.contains("//")) {
            continue;
        }
        let rest = line.get(i + needle.len()..).unwrap_or("");
        let Some(close) = rest.find(')') else {
            out.push(Allow {
                line: k + 1,
                rule: rest.trim().to_string(),
                has_reason: false,
            });
            continue;
        };
        let rule = rest.get(..close).unwrap_or("").trim().to_string();
        let tail = rest.get(close + 1..).unwrap_or("").trim_start();
        let has_reason = tail
            .strip_prefix("--")
            .is_some_and(|r| !r.trim().is_empty());
        out.push(Allow {
            line: k + 1,
            rule,
            has_reason,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masking_blanks_comments_strings_and_chars() {
        let src = "let a = \"x.unwrap()\"; // y.unwrap()\nlet c = 'h'; let l: &'static str = s;\n";
        let m = mask_rust(src);
        assert_eq!(m.len(), src.len());
        assert!(!m.contains("unwrap"));
        assert!(!m.contains('h'));
        assert!(m.contains("'static")); // lifetimes stay in the code channel
        assert_eq!(m.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn fn_spans_find_bodies_and_skip_test_mods() {
        let src = "fn a() { b(); }\n#[cfg(test)]\nmod tests {\n    fn t() { x(); }\n}\nfn c() {}\n";
        let masked = mask_rust(src);
        let spans = test_spans(&masked);
        assert_eq!(spans.len(), 1);
        let fns = fn_spans(&masked, &spans);
        let names: Vec<_> = fns.iter().map(|f| (f.name.as_str(), f.in_test)).collect();
        assert_eq!(names, vec![("a", false), ("t", true), ("c", false)]);
    }

    #[test]
    fn allow_parsing_requires_reason() {
        let ann = concat!("// fed", "lint: allow(panic-free) -- bounded by construction\n");
        let bad = concat!("let x = 1; // fed", "lint: allow(panic-free)\n");
        let allows = parse_allows(&format!("{ann}{bad}"));
        assert_eq!(allows.len(), 2);
        assert!(allows[0].has_reason);
        assert!(!allows[1].has_reason);
        assert_eq!(allows[1].line, 2);
    }
}
