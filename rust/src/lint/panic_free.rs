//! Rule `panic-free`: the code paths that touch untrusted bytes must not
//! contain panicking constructs.
//!
//! A panic on the wire path is a remote denial of service: one malformed
//! peer takes down the reactor thread servicing everyone else. The frame
//! reader, the codec decode path, the quantizer decode helpers, and the
//! chaos harness's ingestion path (which feeds deliberately corrupted
//! bytes through the same code) must therefore reject with typed errors,
//! never panic. This rule forbids, inside the scoped functions:
//!
//! * `.unwrap()`, `.expect(...)` (the `_or` family is fine — it does not
//!   panic),
//! * `panic!`, `unreachable!`, `todo!`, `unimplemented!`,
//! * direct slice/array indexing `x[i]` — use `.get()`, pattern
//!   matching, or iterators; a genuinely bounds-proven index can carry
//!   an allowlist annotation with the proof as the reason.
//!
//! The scope is a fixed table ([`SCOPE`]) rather than an attribute so
//! that renaming or deleting a scoped fn is itself a diagnostic — the
//! protection cannot silently rot away with a refactor. Encode-side
//! helpers (which run on our own trusted tensors) and `#[cfg(test)]`
//! code are deliberately out of scope.

use super::source::{is_ident, Diagnostic, SourceFile, SourceTree};

pub const RULE: &str = "panic-free";

/// `(file suffix, scoped fn names)`; `None` scopes every non-test fn in
/// the file.
pub type Scope = &'static [(&'static str, Option<&'static [&'static str]>)];

/// The untrusted-input surface. Keep in step with `docs/LINTS.md`.
pub const SCOPE: Scope = &[
    // frame reader/writer: first code to touch peer bytes
    ("rust/src/transport/frame.rs", None),
    // codec decode path (encode side runs on trusted local tensors)
    (
        "rust/src/transport/codec.rs",
        Some(&[
            "peek_client",
            "peek_header",
            "decode_update",
            "decode_update_cached",
            "decode_update_view",
            "decode_update_view_cached",
            "decode_into",
            "take",
            "take1",
            "le_f32",
            "le_u32",
            "body",
            "read_varint",
            "read_delta_block",
            "merge_cached_indices",
            "check_q4_padding",
            "check_sparse_index",
        ]),
    ),
    // quantizer decode helpers (dequantize feeds on wire-supplied codes)
    (
        "rust/src/transport/quantize.rs",
        Some(&["rice_decode", "q4_code", "dequantize", "dequantize4"]),
    ),
    // chaos ingestion: the path that must survive the faults it injects
    (
        "rust/src/fl/chaos.rs",
        Some(&[
            "send",
            "send_downlink",
            "recv",
            "try_recv_for",
            "absorb",
            "flush_stash",
            "corrupt",
        ]),
    ),
    // the fused mask→stream pipeline: the per-client hot path every
    // worker runs every round — a panic here kills the worker thread and
    // with it the whole round
    ("rust/src/fl/pipeline.rs", None),
    // the shared payload-frame pool: sits on the same hot path on both
    // the encode (worker) and fold (drain) sides; a poisoned mutex must
    // degrade, never panic
    ("rust/src/runtime/bufpool.rs", None),
];

const MACRO_TOKENS: &[&str] = &["panic!", "unreachable!", "todo!", "unimplemented!"];

pub fn check(tree: &SourceTree) -> Vec<Diagnostic> {
    check_with(tree, SCOPE)
}

pub fn check_with(tree: &SourceTree, scope: Scope) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (suffix, fns) in scope {
        let Some(file) = tree.file(suffix) else {
            out.push(Diagnostic {
                file: (*suffix).to_string(),
                line: 1,
                rule: RULE,
                message: "panic-free scope file missing from the tree — \
                          update lint::panic_free::SCOPE"
                    .to_string(),
            });
            continue;
        };
        match fns {
            None => {
                for f in file.fns().iter().filter(|f| !f.in_test) {
                    scan_fn(file, &f.name, f.body_start, f.body_end, &mut out);
                }
            }
            Some(names) => {
                for name in *names {
                    let mut found = false;
                    for f in file.fns().iter().filter(|f| !f.in_test && f.name == *name) {
                        found = true;
                        scan_fn(file, &f.name, f.body_start, f.body_end, &mut out);
                    }
                    if !found {
                        out.push(file.diag_line(
                            RULE,
                            1,
                            format!(
                                "scoped fn `{name}` not found — update lint::panic_free::SCOPE"
                            ),
                        ));
                    }
                }
            }
        }
    }
    out
}

fn scan_fn(file: &SourceFile, name: &str, start: usize, end: usize, out: &mut Vec<Diagnostic>) {
    let body = file.masked.get(start..=end).unwrap_or("");
    let b = body.as_bytes();

    // method-style panics: exact `.unwrap()` (so `.unwrap_or(..)` passes)
    // and `.expect(` (so `.expect_err(` in result-shape tests passes)
    for (token, label) in [(".unwrap()", ".unwrap()"), (".expect(", ".expect(..)")] {
        let mut from = 0usize;
        while let Some(rel) = body.get(from..).and_then(|s| s.find(token)) {
            let at = from + rel;
            from = at + token.len();
            out.push(file.diag(
                RULE,
                start + at,
                format!("`{label}` in panic-free fn `{name}` — return a typed error instead"),
            ));
        }
    }

    for token in MACRO_TOKENS {
        let mut from = 0usize;
        while let Some(rel) = body.get(from..).and_then(|s| s.find(token)) {
            let at = from + rel;
            from = at + token.len();
            // word boundary on the left so an ident like `my_panic!` does
            // not count; a path-qualified `std::panic!` still does
            let before_ok = b.get(at.wrapping_sub(1)).is_none_or(|&p| !is_ident(p));
            if before_ok {
                out.push(file.diag(
                    RULE,
                    start + at,
                    format!(
                        "`{token}` in panic-free fn `{name}` — reject with a typed error instead"
                    ),
                ));
            }
        }
    }

    // direct indexing: a `[` is an index expression exactly when it is
    // postfix — glued to an expression tail. `vec![`, `#[attr]`,
    // `let [a, b] =`, `: [u8; 4]`, `= [0; n]` all have a non-expression
    // byte immediately before the bracket and pass.
    for (k, &c) in b.iter().enumerate() {
        if c != b'[' {
            continue;
        }
        let prev = k.checked_sub(1).and_then(|p| b.get(p)).copied().unwrap_or(0);
        if is_ident(prev) || prev == b')' || prev == b']' || prev == b'?' {
            out.push(file.diag(
                RULE,
                start + k,
                format!(
                    "direct indexing in panic-free fn `{name}` — use .get(), patterns, or iterators"
                ),
            ));
        }
    }
}
