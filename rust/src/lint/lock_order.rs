//! Rule `lock-order`: the socket reactor's mutex acquisition graph must
//! stay acyclic.
//!
//! `transport/socket.rs` is the one concurrent hot path in the repo: the
//! reactor thread, the drain loop, the downlink writer, and the client
//! handles all share state behind `Mutex`es (`io` per connection, the
//! session shards, the `conns` registry). Two threads taking the same
//! pair of locks in opposite orders is a deadlock that no test reliably
//! reproduces — exactly the class a static pass should own. This rule
//! extracts every `.lock()` call, classifies the guard as *held* (bound
//! by `let` / `if let` / `while let`, so it lives to the end of its
//! block) or *temporary* (a chained call like
//! `.lock().map_err(..)?.get(..)`, dropped at the end of the statement),
//! records an edge A→B whenever B is acquired inside A's hold extent,
//! and reports any cycle in the resulting graph. The reactor today
//! never holds two locks at once, which is the strongest order of all —
//! this rule keeps it that way.

use std::collections::BTreeMap;

use super::source::{is_ident, Diagnostic, SourceFile, SourceTree};

pub const RULE: &str = "lock-order";

const SOCKET_RS: &str = "rust/src/transport/socket.rs";

/// One lock acquisition: which mutex (last path segment of the receiver),
/// where, in which fn, and — when held — how far the guard lives.
struct Acquire {
    name: String,
    offset: usize,
    fn_name: String,
    hold_until: Option<usize>,
}

pub fn check(tree: &SourceTree) -> Vec<Diagnostic> {
    let Some(file) = tree.file("transport/socket.rs") else {
        return vec![Diagnostic {
            file: SOCKET_RS.to_string(),
            line: 1,
            rule: RULE,
            message: "lock-order scope file missing from the tree".to_string(),
        }];
    };
    let mut acquires: Vec<Acquire> = Vec::new();
    for f in file.fns().iter().filter(|f| !f.in_test) {
        collect(file, &f.name, f.body_start, f.body_end, &mut acquires);
    }

    // edge A -> B: B acquired while A's guard is held (same fn body, so
    // nested fns — which get their own spans — don't leak extents)
    let mut edges: BTreeMap<(String, String), (usize, String)> = BTreeMap::new();
    for a in &acquires {
        let Some(until) = a.hold_until else { continue };
        for b in &acquires {
            if b.offset > a.offset && b.offset < until && b.fn_name == a.fn_name {
                edges
                    .entry((a.name.clone(), b.name.clone()))
                    .or_insert((b.offset, b.fn_name.clone()));
            }
        }
    }

    // report every edge that closes a cycle (DFS back edge), at the
    // acquisition that completes the cycle
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (from, to) in edges.keys() {
        adj.entry(from.as_str()).or_default().push(to.as_str());
    }
    let mut out = Vec::new();
    for ((from, to), (offset, fn_name)) in &edges {
        if reaches(&adj, to, from) {
            out.push(file.diag(
                RULE,
                *offset,
                format!(
                    "cyclic lock order: `{to}` acquired while holding `{from}` (fn `{fn_name}`), \
                     and another path acquires `{from}` while holding `{to}`"
                ),
            ));
        }
    }
    out
}

/// Is `goal` reachable from `start` along held-while-acquiring edges?
fn reaches(adj: &BTreeMap<&str, Vec<&str>>, start: &str, goal: &str) -> bool {
    let mut stack = vec![start];
    let mut seen = vec![start];
    while let Some(n) = stack.pop() {
        if n == goal {
            return true;
        }
        for &next in adj.get(n).into_iter().flatten() {
            if !seen.contains(&next) {
                seen.push(next);
                stack.push(next);
            }
        }
    }
    false
}

fn collect(file: &SourceFile, fn_name: &str, start: usize, end: usize, out: &mut Vec<Acquire>) {
    let m = file.masked.as_bytes();
    let body = file.masked.get(start..=end).unwrap_or("");
    let mut from = 0usize;
    while let Some(rel) = body.find_at(from, ".lock()") {
        let at = start + rel;
        from = rel + 7;
        let Some(name) = receiver_name(m, at) else {
            continue;
        };
        let after = skip_adapters(m, at + 7);
        let hold_until = if m.get(after).copied() == Some(b'.') {
            // the guard is consumed by a further chained call and dropped
            // at the end of this statement — a temporary
            None
        } else {
            match statement_kind(m, at, start) {
                StmtKind::Let => Some(block_end(m, after, end)),
                StmtKind::CondLet => next_block_end(m, after, end),
                StmtKind::Other => None,
            }
        };
        out.push(Acquire {
            name,
            offset: at,
            fn_name: fn_name.to_string(),
            hold_until,
        });
    }
}

/// `str::find` from a byte offset; tiny shim so the scan above reads
/// linearly.
trait FindAt {
    fn find_at(&self, from: usize, needle: &str) -> Option<usize>;
}

impl FindAt for str {
    fn find_at(&self, from: usize, needle: &str) -> Option<usize> {
        self.get(from..)?.find(needle).map(|r| from + r)
    }
}

/// Last path segment of the receiver expression before `.lock()`:
/// `self.io.lock()` → `io`; `self.shard(client).lock()` → `shard`.
/// Whitespace between chain segments (rustfmt's multi-line chains) is
/// skipped.
fn receiver_name(m: &[u8], dot: usize) -> Option<String> {
    let mut k = dot;
    while k > 0 && matches!(m.get(k - 1), Some(b' ' | b'\n')) {
        k -= 1;
    }
    if k == 0 {
        return None;
    }
    if m.get(k - 1).copied() == Some(b')') {
        // a call: skip the balanced argument list, then read the callee
        let mut depth = 0usize;
        let mut j = k - 1;
        loop {
            match m.get(j).copied() {
                Some(b')') => depth += 1,
                Some(b'(') => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j = j.checked_sub(1)?;
        }
        k = j;
    }
    let end = k;
    let mut s = k;
    while s > 0 && m.get(s - 1).is_some_and(|&c| is_ident(c)) {
        s -= 1;
    }
    if s == end {
        return None;
    }
    std::str::from_utf8(m.get(s..end)?).ok().map(str::to_string)
}

/// Step past the adapter chain that unwraps a `LockResult` without
/// keeping a second handle: `?`, `.unwrap()`, `.expect(..)`,
/// `.map_err(..)`, `.unwrap_or_else(..)`, `.ok()`. Returns the offset of
/// the first byte after the chain (whitespace skipped).
fn skip_adapters(m: &[u8], mut i: usize) -> usize {
    loop {
        while matches!(m.get(i), Some(b' ' | b'\n')) {
            i += 1;
        }
        if m.get(i).copied() == Some(b'?') {
            i += 1;
            continue;
        }
        let mut matched = false;
        for adapter in [".unwrap", ".expect", ".map_err", ".unwrap_or_else", ".ok"] {
            let end = i + adapter.len();
            if m.get(i..end).is_some_and(|s| s == adapter.as_bytes())
                && m.get(end).copied() == Some(b'(')
            {
                // skip the balanced argument list
                let mut depth = 0usize;
                let mut j = end;
                while let Some(&c) = m.get(j) {
                    match c {
                        b'(' => depth += 1,
                        b')' => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                i = j + 1;
                matched = true;
                break;
            }
        }
        if !matched {
            return i;
        }
    }
}

enum StmtKind {
    Let,
    CondLet,
    Other,
}

/// Classify the statement containing the `.lock()` at `at` by scanning
/// back to the previous `;`, `{`, or `}` and reading its first tokens.
fn statement_kind(m: &[u8], at: usize, floor: usize) -> StmtKind {
    let mut s = at;
    while s > floor && !matches!(m.get(s - 1), Some(b';' | b'{' | b'}')) {
        s -= 1;
    }
    let head: String = m
        .get(s..at)
        .unwrap_or(&[])
        .iter()
        .map(|&c| c as char)
        .collect();
    let head = head.trim_start();
    if head.starts_with("if let ") || head.starts_with("while let ") {
        StmtKind::CondLet
    } else if head.starts_with("let ") {
        StmtKind::Let
    } else {
        StmtKind::Other
    }
}

/// End of the innermost block enclosing `from`: the first `}` that
/// closes a brace we never saw open. The guard of a `let` lives to here.
fn block_end(m: &[u8], from: usize, limit: usize) -> usize {
    let mut depth = 0usize;
    let mut i = from;
    while i <= limit {
        match m.get(i).copied() {
            Some(b'{') => depth += 1,
            Some(b'}') => {
                if depth == 0 {
                    return i;
                }
                depth -= 1;
            }
            _ => {}
        }
        i += 1;
    }
    limit
}

/// End of the block a conditional binding guards: the match of the next
/// `{` after the condition.
fn next_block_end(m: &[u8], from: usize, limit: usize) -> Option<usize> {
    let mut i = from;
    while i <= limit {
        if m.get(i).copied() == Some(b'{') {
            return Some(super::source::match_brace(m, i).unwrap_or(limit));
        }
        i += 1;
    }
    None
}
