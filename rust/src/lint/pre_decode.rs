//! Rule `pre-decode`: in any function that handles wire frames, a codec
//! decode must be dominated by the session check.
//!
//! WIRE.md §1b promises that an upload payload "never reaches the
//! aggregation loop" before `validate_upload` has matched the frame
//! token and the claimed client id against the session. The codec is
//! hardened, but hardened is not licensed: decoding an unvouched
//! payload spends budget on an unauthenticated peer and widens the
//! attack surface a PR at a time. This rule makes the discipline
//! mechanical: inside any fn whose signature mentions the [`Frame`]
//! type, every `decode_update*` / `decode_into` call must be textually
//! preceded by a `validate_upload(` call in the same body. (Textual
//! order approximates dominance; a guard in a dead branch is a code
//! smell this rule is allowed to miss — the reviewer is not.)
//!
//! [`Frame`]: ../../transport/frame/struct.Frame.html

use super::source::{is_ident, Diagnostic, SourceTree};

pub const RULE: &str = "pre-decode";

/// Calls that materialize an untrusted payload's body.
const DECODE_PREFIX: &str = "decode_update";
const DECODE_INTO: &str = "decode_into(";
/// The session check that must come first.
const GUARD: &str = "validate_upload(";

pub fn check(tree: &SourceTree) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for file in &tree.files {
        if !file.path.ends_with(".rs") {
            continue;
        }
        let m = file.masked.as_bytes();
        for f in file.fns() {
            if f.in_test {
                continue;
            }
            let sig = file.masked.get(f.sig_start..f.body_start).unwrap_or("");
            if !contains_word(sig, "Frame") {
                continue;
            }
            let body = file.masked.get(f.body_start..=f.body_end).unwrap_or("");
            let guard_at = body.find(GUARD).map(|r| f.body_start + r);
            for off in decode_calls(body, m, f.body_start) {
                if guard_at.is_none_or(|g| g > off) {
                    out.push(file.diag(
                        RULE,
                        off,
                        format!(
                            "fn `{}` handles a Frame but decodes the payload without a \
                             preceding validate_upload() (WIRE.md §1b pre-decode discipline)",
                            f.name
                        ),
                    ));
                }
            }
        }
    }
    out
}

/// Whole-word occurrence test (so `FrameKind` does not count as `Frame`).
fn contains_word(hay: &str, word: &str) -> bool {
    let b = hay.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = hay.get(from..).and_then(|s| s.find(word)) {
        let at = from + rel;
        from = at + word.len();
        let before = at == 0 || b.get(at.wrapping_sub(1)).is_none_or(|&p| !is_ident(p));
        let after = b.get(at + word.len()).is_none_or(|&n| !is_ident(n));
        if before && after {
            return true;
        }
    }
    false
}

/// File offsets of decode-call tokens inside `body` (which starts at
/// file offset `base`). `decode_update` is a prefix match so the
/// `_cached` / `_view` variants all count; both tokens require a word
/// boundary on the left so a local `redecode_update` cannot hide one.
fn decode_calls(body: &str, file_masked: &[u8], base: usize) -> Vec<usize> {
    let mut offs = Vec::new();
    for token in [DECODE_PREFIX, DECODE_INTO] {
        let mut from = 0usize;
        while let Some(rel) = body.get(from..).and_then(|s| s.find(token)) {
            let at = from + rel;
            from = at + token.len();
            let abs = base + at;
            let before_ok = file_masked.get(abs.wrapping_sub(1)).is_none_or(|&p| !is_ident(p));
            if before_ok {
                offs.push(abs);
            }
        }
    }
    offs.sort_unstable();
    offs.dedup();
    offs
}
