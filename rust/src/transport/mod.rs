//! Transport plane: what actually crosses the (simulated) wire — and since
//! the streaming refactor, the **only** path client updates travel.
//!
//! Division of labor around one round:
//!
//! * **Who encodes** — `fl::client::ClientJob::run` encodes its masked
//!   update into a [`codec::WireUpdate`] payload (sparse top-k, dense, or
//!   quantized per the experiment's `encoding`); with `downlink_delta`,
//!   `fl::server::Server` also encodes the broadcast as a delta against
//!   the previous round's global model.
//! * **Who decodes** — the server, once per arriving payload, into a
//!   borrowed sparse/dense view over a scratch buffer it holds across
//!   rounds ([`codec::decode_update_view`]), before folding it into the
//!   round's `fl::aggregate::Aggregator` — sparse bodies are never
//!   densified (and each client conceptually decodes the broadcast,
//!   modeled server-side). No dense `Vec<f32>` crosses the
//!   client->server boundary.
//! * **Where bytes are accounted** — the server records
//!   `payload.len()` per upload and per-broadcast bytes in
//!   [`cost::CostLedger`] (`record_upload` / `record_download_sparse`);
//!   [`network::NetworkModel`] turns those same byte counts into virtual
//!   transfer time.
//!
//! Modules:
//!
//! * [`codec`] — dense and sparse update encodings with auto-selection;
//!   masked updates ship as (index, value) pairs, which is where the
//!   paper's communication saving physically materializes.
//! * [`quantize`] — optional 8-bit linear quantization layered on either
//!   encoding (paper §1: the methods "can also be combined with
//!   cutting-edge compression algorithms").
//! * [`cost`] — Eq. 6 unit-cost model + the byte-accurate ledger every
//!   figure driver reports from.
//! * [`network`] — bandwidth/latency model mapping message bytes to
//!   virtual transfer time (the paper ignores this; we model it).

pub mod codec;
pub mod cost;
pub mod network;
pub mod quantize;

pub use codec::{
    decode_update, decode_update_view, encode_update, encode_update_with, BodyView, DecodeScratch,
    DecodedBody, EncodeScratch, Encoding, WireUpdate, WireView,
};
pub use cost::{eq6_cost, CostLedger};
pub use network::NetworkModel;
