//! Transport substrate: what actually crosses the (simulated) wire.
//!
//! * [`codec`] — dense and sparse update encodings with auto-selection;
//!   masked updates ship as (index, value) pairs, which is where the
//!   paper's communication saving physically materializes.
//! * [`quantize`] — optional 8-bit linear quantization layered on either
//!   encoding (paper §1: the methods "can also be combined with
//!   cutting-edge compression algorithms").
//! * [`cost`] — Eq. 6 unit-cost model + the byte-accurate ledger every
//!   figure driver reports from.
//! * [`network`] — bandwidth/latency model mapping message bytes to
//!   virtual transfer time (the paper ignores this; we model it).

pub mod codec;
pub mod cost;
pub mod network;
pub mod quantize;

pub use codec::{decode_update, encode_update, Encoding, WireUpdate};
pub use cost::{eq6_cost, CostLedger};
pub use network::NetworkModel;
