//! Transport plane: what actually crosses the wire — and since the socket
//! refactor, *which* wire it crosses is pluggable.
//!
//! ## Three transports, one byte stream
//!
//! Client jobs encode their masked update into a [`codec::WireUpdate`]
//! payload and push it through an [`UploadSink`]; the server's streaming
//! aggregation loop pulls payloads back out of the matching [`Transport`]
//! and folds them in completion order. The payload bytes are identical on
//! every path — only the carrier differs:
//!
//! * [`link::InProcess`] (`--transport inproc`, default) — an mpsc
//!   upload channel plus per-client downlink mailboxes. No socket, no
//!   syscalls; the bitwise reference.
//! * [`socket::Loopback`] (`--transport tcp|uds`) — real framed sockets:
//!   TCP on an ephemeral 127.0.0.1 port, or a unix-domain socket in the
//!   temp dir, served by a single-threaded nonblocking **reactor** (no
//!   thread-per-connection). One **persistent, token-authenticated duplex
//!   connection per registered client**: the round's encoded broadcast
//!   goes down and the upload comes back on the same kernel socket, and
//!   every upload is verified against its session (token + claimed client
//!   id) before any payload decode ([`session`]). Session and peer state
//!   is sharded by [`session::shard_of`]; admission is capped and idle
//!   pre-auth connections reaped per [`socket::ServerTuning`]. See
//!   `docs/SCALE.md`.
//! * [`link::Simulated`] (`network = "simulated"` wraps either of the
//!   above) — re-orders each round's upload deliveries by
//!   [`NetworkModel::upload_time`], so arrival order models link speed
//!   rather than thread-scheduler luck.
//!
//! Because the aggregation fold is order-independent and integer-exact,
//! all three produce **bitwise identical** global models — pinned by
//! `tests/socket_transport.rs`.
//!
//! ## Wire format (one page: `docs/WIRE.md`)
//!
//! Two layers, documented end to end in `docs/WIRE.md` — frame grammar,
//! every codec tag, varint canonicality rules, and the q4/q8 quantizer
//! grid contract. In brief:
//!
//! **Frame** ([`frame`]): one frame per message — `magic u16 (0x4c46
//! "FL") | version u8 (2) | kind u8 (hello/welcome/upload/broadcast) |
//! token u64 LE | length u32 LE | payload`. Declared lengths above the
//! hard cap ([`frame::MAX_FRAME_BYTES`], 64 MiB) are rejected on the
//! header, before any body allocation. Unknown kinds and versions are
//! typed errors ([`Error::Transport`](crate::util::error::Error)); the
//! token authenticates a session ([`session`]). The reader is an
//! incremental state machine tolerant of arbitrarily short reads and
//! pipelined frames; mid-frame disconnects are typed truncation errors,
//! and a malformed or spoofing peer is dropped at its connection without
//! disturbing the rest of the cohort.
//!
//! **Codec** ([`codec`]): seven body tags behind one 24-byte header —
//! dense/sparse f32, dense/sparse q8, delta+varint sparse f32,
//! dense q4, and delta+varint sparse q4. Sparse indices are strictly
//! increasing (delta-coded tags store LEB128 gaps, validated for
//! canonical form, monotonicity, and range on decode), and the auto
//! encodings pick the cheapest representation by exact encoded length.
//!
//! ## Division of labor around one round
//!
//! * **Who encodes** — `fl::client::ClientJob::run` encodes its masked
//!   update (sparse top-k, dense, or quantized per the experiment's
//!   `encoding`); the server-side job wrapper ships the payload through
//!   the round's sink. The round's broadcast is encoded once by
//!   `fl::driver::RoundDriver` (dense, or a delta against the previous
//!   round's global model under `downlink_delta`) and pushed through the
//!   transport's downlink half — client jobs decode it from the wire
//!   before training, so **both directions cross the socket** under
//!   `--transport tcp|uds`.
//! * **Who decodes** — the server, once per received payload, into a
//!   borrowed sparse/dense view over a scratch buffer held across rounds
//!   ([`codec::decode_update_view`]), before folding into the round's
//!   `fl::aggregate::Aggregator`. Sparse bodies are never densified. No
//!   dense `Vec<f32>` crosses the client->server boundary.
//! * **Where bytes are accounted** — the server records `payload.len()`
//!   per upload and per-broadcast bytes in [`cost::CostLedger`];
//!   [`network::NetworkModel`] turns those same byte counts into virtual
//!   transfer time. Framing overhead (8 bytes/frame) is transport detail,
//!   not protocol cost, and is excluded from the ledger.
//!
//! Modules:
//!
//! * [`codec`] — dense, sparse, and entropy-coded (delta+varint) update
//!   encodings with exact-size auto-selection; masked updates ship as
//!   (index, value) pairs, which is where the paper's communication
//!   saving physically materializes.
//! * [`frame`] — length-prefixed framing: header layout, size cap,
//!   incremental reader, adversarial-input rejection.
//! * [`link`] — the [`Transport`]/[`UploadSink`]/[`DownlinkSource`]
//!   abstraction (blocking and bounded-poll receives, per-client
//!   registration, downlink pushes), the in-process default, and the
//!   [`NetworkModel`]-timed wrapper.
//! * [`session`] — per-client session tokens: the registration
//!   handshake, upload verification that runs before any decode, and the
//!   client-id shard hash ([`session::shard_of`]) with the sharded
//!   session table ([`session::SessionShards`]).
//! * [`socket`] — the reactor-driven TCP/UDS server + the persistent
//!   per-client duplex connection ([`socket::ClientConn`]).
//! * [`quantize`] — optional 8-bit and 4-bit linear quantization layered
//!   on either encoding (paper §1: the methods "can also be combined with
//!   cutting-edge compression algorithms").
//! * [`cost`] — Eq. 6 unit-cost model + the byte-accurate ledger every
//!   figure driver reports from.
//! * [`network`] — bandwidth/latency model mapping message bytes to
//!   virtual transfer time (the paper ignores this; we model it).

pub mod codec;
pub mod cost;
pub mod frame;
pub mod link;
pub mod network;
pub mod quantize;
pub mod session;
pub mod socket;

pub use codec::{
    decode_update, decode_update_view, encode_update, encode_update_with, peek_client, BodyView,
    DecodeScratch, DecodedBody, EncodeScratch, Encoding, WireUpdate, WireView, BROADCAST_DELTA,
    BROADCAST_FULL, BROADCAST_SENDER,
};
pub use cost::{eq6_cost, CostLedger};
pub use frame::{
    frame_bytes, write_frame, Frame, FrameKind, FrameReader, FrameStream, MAX_FRAME_BYTES,
    NO_TOKEN,
};
pub use link::{DownlinkSource, InProcess, Simulated, Transport, TransportKind, UploadSink};
pub use network::NetworkModel;
pub use session::{
    hello_payload, shard_of, validate_upload, Session, SessionShards, SessionTable, TokenMint,
};
pub use socket::{ClientConn, Loopback, ServerTuning, WireAddr};
