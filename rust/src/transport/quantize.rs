//! 8-bit and 4-bit linear quantization (compression extension).
//!
//! The paper notes its methods "can also be combined with cutting-edge
//! compression algorithms for furthering communication efficiency" (§1).
//! This module provides the simplest respectable such algorithms —
//! per-tensor linear quantization with an f32 (min, scale) header — in two
//! widths sharing one fixed-point-grid contract:
//!
//! * **q8** — 256 levels, one byte per value, `scale = range / 255`;
//! * **q4** — 16 levels, two values per byte (low nibble first),
//!   `scale = range / 15`.
//!
//! Both dequantize as `min + scale * code`, so a decoded value lies within
//! half a step (`scale / 2`) of the original, zero-range inputs are exact
//! (`scale == 0`), and any consumer that folds dequantized values gets the
//! same bits whether the codes arrived dense or sparse. For odd-length q4
//! tensors the final byte's unused high nibble is zero — decoders treat a
//! non-zero padding nibble as a malformed message.

use crate::util::error::{Error, Result};

/// Quantized tensor: u8 codes + dequantization parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Quantized {
    pub min: f32,
    pub scale: f32,
    pub codes: Vec<u8>,
}

impl Quantized {
    /// Wire size in bytes.
    pub fn bytes(&self) -> usize {
        4 + 4 + self.codes.len()
    }
}

/// Grid step for values spanning `[min, max]` at `levels` quantization
/// steps (255.0 for q8, 15.0 for q4): `range / levels`, or `0.0` for
/// zero-range (degenerate, exact) inputs. Shared by the staged
/// quantizers below and the fused single-pass encoder
/// (`transport::codec::encode_masked`), so the two paths derive the
/// same grid bit for bit.
#[inline]
pub fn grid_scale(min: f32, max: f32, levels: f32) -> f32 {
    let range = max - min;
    if range > 0.0 {
        range / levels
    } else {
        0.0
    }
}

/// One value's linear code on the `(min, scale)` grid, clamped to
/// `[0, max_code]` — the single rounding formula every quantization
/// consumer shares (see [`grid_scale`]). `scale == 0.0` codes to 0.
#[inline]
pub fn grid_code(v: f32, min: f32, scale: f32, max_code: i64) -> u8 {
    if scale == 0.0 {
        0u8
    } else {
        (((v - min) / scale).round() as i64).clamp(0, max_code) as u8
    }
}

/// Quantize to 256 levels over [min, max]. Zero-range inputs get scale 0.
pub fn quantize(values: &[f32]) -> Result<Quantized> {
    if values.is_empty() {
        return Err(Error::invalid("cannot quantize empty tensor"));
    }
    if values.iter().any(|v| !v.is_finite()) {
        return Err(Error::invalid("cannot quantize non-finite values"));
    }
    let min = values.iter().copied().fold(f32::INFINITY, f32::min);
    let max = values.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let scale = grid_scale(min, max, 255.0);
    let codes = values.iter().map(|&v| grid_code(v, min, scale, 255)).collect();
    Ok(Quantized { min, scale, codes })
}

/// Inverse of [`quantize`].
pub fn dequantize(q: &Quantized) -> Vec<f32> {
    q.codes
        .iter()
        .map(|&c| q.min + q.scale * c as f32)
        .collect()
}

/// 4-bit quantized tensor: two codes per byte + dequantization parameters.
/// `n` is the logical value count; `packed.len() == n.div_ceil(2)` and the
/// unused high nibble of an odd-length tensor's last byte is zero.
#[derive(Debug, Clone, PartialEq)]
pub struct Quantized4 {
    pub min: f32,
    pub scale: f32,
    pub n: usize,
    pub packed: Vec<u8>,
}

impl Quantized4 {
    /// Wire size in bytes (header + packed codes).
    pub fn bytes(&self) -> usize {
        4 + 4 + self.packed.len()
    }
}

/// Extract the `k`-th 4-bit code from a packed nibble buffer (low nibble
/// of each byte first — the packing [`quantize4`] emits).
#[inline]
pub fn q4_code(packed: &[u8], k: usize) -> u8 {
    // fedlint: allow(panic-free) -- callers bound k < n with packed.len() == ceil(n/2) checked at decode entry
    (packed[k / 2] >> (4 * (k & 1))) & 0x0f
}

/// Quantize to 16 levels over [min, max], packed two codes per byte. The
/// same grid contract as [`quantize`] (zero-range inputs get scale 0 and
/// are exact), just a coarser step: `scale = range / 15`.
pub fn quantize4(values: &[f32]) -> Result<Quantized4> {
    if values.is_empty() {
        return Err(Error::invalid("cannot quantize empty tensor"));
    }
    if values.iter().any(|v| !v.is_finite()) {
        return Err(Error::invalid("cannot quantize non-finite values"));
    }
    let min = values.iter().copied().fold(f32::INFINITY, f32::min);
    let max = values.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let scale = grid_scale(min, max, 15.0);
    let mut packed = vec![0u8; values.len().div_ceil(2)];
    for (k, &v) in values.iter().enumerate() {
        packed[k / 2] |= grid_code(v, min, scale, 15) << (4 * (k & 1));
    }
    Ok(Quantized4 {
        min,
        scale,
        n: values.len(),
        packed,
    })
}

/// Inverse of [`quantize4`].
pub fn dequantize4(q: &Quantized4) -> Vec<f32> {
    (0..q.n)
        .map(|k| q.min + q.scale * q4_code(&q.packed, k) as f32)
        .collect()
}

// ----------------------------------------------------------------------
// Rice-Golomb coding over q8 codes (the wire's entropy-coded value arm)
// ----------------------------------------------------------------------
//
// Masked-update q8 code distributions are far from uniform (most codes
// cluster near the grid midpoint mapped from zero-ish deltas), so a
// Rice code — the power-of-two Golomb family — beats the flat byte per
// code: each code `c` is written as `c >> k` in unary (that many 1 bits
// then a terminating 0) followed by the `k` low bits verbatim, LSB-first
// within each byte, zero-padded to a byte boundary. The parameter `k` is
// chosen exactly (by total bit count over `k ∈ 0..=8`) per message;
// `k = 8` degenerates to one `0` marker bit plus the raw byte, so the
// coded stream is never catastrophically larger than the flat one.
//
// The decoder is strict in the same way the varint index block is: a
// stream that ends inside a code, a unary run longer than the largest
// representable quotient (`255 >> k`), a non-zero padding bit, or bytes
// left over after the padding are all typed parse errors.

/// Maximum Rice parameter: at `k = 8` every code is `0` + 8 raw bits.
pub const RICE_MAX_K: u8 = 8;

/// Exact bit count of the Rice-coded stream for `codes` at parameter `k`.
fn rice_bits(hist: &[usize; 256], k: u8) -> usize {
    hist.iter()
        .enumerate()
        .map(|(c, &n)| n * ((c >> k) + 1 + k as usize))
        .sum()
}

/// The exact-optimal Rice parameter for `codes` and the byte length of
/// the resulting stream: every `k ∈ 0..=8` is priced from one histogram
/// pass, ties break toward the smaller `k`.
pub fn rice_plan(codes: &[u8]) -> (u8, usize) {
    let mut hist = [0usize; 256];
    for &c in codes {
        hist[c as usize] += 1;
    }
    let mut best = (0u8, rice_bits(&hist, 0));
    for k in 1..=RICE_MAX_K {
        let bits = rice_bits(&hist, k);
        if bits < best.1 {
            best = (k, bits);
        }
    }
    (best.0, best.1.div_ceil(8))
}

/// Append the Rice-coded stream for `codes` at parameter `k` to `out`,
/// zero-padded to a byte boundary. Bits fill each byte LSB-first.
pub fn rice_encode(codes: &[u8], k: u8, out: &mut Vec<u8>) {
    debug_assert!(k <= RICE_MAX_K);
    let mut acc = 0u32;
    let mut nbits = 0u32;
    let mut push_bit = |bit: u32, acc: &mut u32, nbits: &mut u32, out: &mut Vec<u8>| {
        *acc |= bit << *nbits;
        *nbits += 1;
        if *nbits == 8 {
            out.push(*acc as u8);
            *acc = 0;
            *nbits = 0;
        }
    };
    for &c in codes {
        let q = c >> k;
        for _ in 0..q {
            push_bit(1, &mut acc, &mut nbits, out);
        }
        push_bit(0, &mut acc, &mut nbits, out);
        for b in 0..k {
            push_bit(((c >> b) & 1) as u32, &mut acc, &mut nbits, out);
        }
    }
    if nbits > 0 {
        out.push(acc as u8);
    }
}

/// Decode exactly `n` Rice codes at parameter `k` from `data`, appending
/// them to `out`. Strict: `data` must be exactly the coded stream — a
/// truncated stream, a unary quotient above the representable maximum,
/// a non-zero padding bit, or whole leftover bytes are all errors.
pub fn rice_decode(data: &[u8], n: usize, k: u8, out: &mut Vec<u8>) -> Result<()> {
    if k > RICE_MAX_K {
        return Err(Error::parse(format!("rice parameter {k} exceeds {RICE_MAX_K}")));
    }
    let total_bits = data.len() * 8;
    let mut pos = 0usize;
    let max_q = (255u32 >> k) as usize;
    // every use is guarded by `pos < total_bits`, so the fallback byte is
    // unreachable — it exists to keep this path free of indexing
    let bit_at = |pos: usize| (data.get(pos / 8).copied().unwrap_or(0) >> (pos % 8)) & 1;
    for i in 0..n {
        let mut q = 0usize;
        loop {
            if pos >= total_bits {
                return Err(Error::parse(format!("rice stream truncated in code {i}")));
            }
            let bit = bit_at(pos);
            pos += 1;
            if bit == 0 {
                break;
            }
            q += 1;
            if q > max_q {
                return Err(Error::parse(format!(
                    "rice quotient exceeds maximum {max_q} in code {i}"
                )));
            }
        }
        let mut rem = 0u32;
        for b in 0..k {
            if pos >= total_bits {
                return Err(Error::parse(format!("rice stream truncated in code {i}")));
            }
            rem |= ((bit_at(pos) as u32) << b) as u32;
            pos += 1;
        }
        out.push((((q as u32) << k) | rem) as u8);
    }
    // the stream must end exactly here: whole leftover bytes mean an
    // overlong stream, and the final byte's padding bits must be zero
    if total_bits - pos >= 8 {
        return Err(Error::parse(format!(
            "rice stream overlong: {} unread bytes after {n} codes",
            (total_bits - pos) / 8
        )));
    }
    while pos < total_bits {
        if bit_at(pos) != 0 {
            return Err(Error::parse("rice stream has non-zero padding bits"));
        }
        pos += 1;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        check("quantize error bound", 100, |g| {
            let n = g.usize_in(1, 3000);
            let vals = g.f32_vec(n, -3.0, 3.0);
            let q = quantize(&vals).unwrap();
            let back = dequantize(&q);
            let half_step = q.scale * 0.5 + 1e-6;
            for (a, b) in vals.iter().zip(&back) {
                assert!((a - b).abs() <= half_step, "err {} > {half_step}", (a - b).abs());
            }
        });
    }

    #[test]
    fn constant_tensor_is_exact() {
        let vals = vec![1.25f32; 100];
        let q = quantize(&vals).unwrap();
        assert_eq!(q.scale, 0.0);
        assert_eq!(dequantize(&q), vals);
    }

    #[test]
    fn compression_ratio_is_4x_minus_header() {
        let vals = vec![0.5f32; 10_000];
        let q = quantize(&vals).unwrap();
        assert_eq!(q.bytes(), 8 + 10_000);
        assert!(q.bytes() * 3 < 4 * 10_000);
    }

    #[test]
    fn rejects_empty_and_nonfinite() {
        assert!(quantize(&[]).is_err());
        assert!(quantize(&[f32::NAN]).is_err());
        assert!(quantize(&[f32::INFINITY, 0.0]).is_err());
    }

    #[test]
    fn extremes_map_to_extreme_codes() {
        let q = quantize(&[-1.0, 0.0, 1.0]).unwrap();
        assert_eq!(q.codes[0], 0);
        assert_eq!(q.codes[2], 255);
    }

    #[test]
    fn q4_roundtrip_error_bounded_by_half_step() {
        check("quantize4 error bound", 100, |g| {
            let n = g.usize_in(1, 3000);
            let vals = g.f32_vec(n, -3.0, 3.0);
            let q = quantize4(&vals).unwrap();
            let back = dequantize4(&q);
            assert_eq!(back.len(), n);
            let half_step = q.scale * 0.5 + 1e-6;
            for (a, b) in vals.iter().zip(&back) {
                assert!((a - b).abs() <= half_step, "err {} > {half_step}", (a - b).abs());
            }
        });
    }

    #[test]
    fn q4_constant_tensor_is_exact() {
        let vals = vec![-0.75f32; 33];
        let q = quantize4(&vals).unwrap();
        assert_eq!(q.scale, 0.0);
        assert_eq!(dequantize4(&q), vals);
    }

    #[test]
    fn q4_packs_two_codes_per_byte_with_zero_padding_nibble() {
        // even count: exactly n/2 bytes
        let q = quantize4(&[0.0, 1.0, 0.5, 0.25]).unwrap();
        assert_eq!(q.packed.len(), 2);
        // odd count: the last byte's high nibble is the zero pad
        let q = quantize4(&[0.0, 1.0, 1.0]).unwrap();
        assert_eq!(q.packed.len(), 2);
        assert_eq!(q.packed[1] >> 4, 0, "padding nibble must be zero");
        // extremes hit code 0 and 15
        let q = quantize4(&[-1.0, 1.0]).unwrap();
        assert_eq!(q4_code(&q.packed, 0), 0);
        assert_eq!(q4_code(&q.packed, 1), 15);
    }

    #[test]
    fn q4_compression_ratio_is_8x_minus_header() {
        let vals: Vec<f32> = (0..10_000).map(|i| (i % 7) as f32).collect();
        let q = quantize4(&vals).unwrap();
        assert_eq!(q.bytes(), 8 + 5_000);
        assert!(q.bytes() * 7 < 4 * 10_000);
    }

    #[test]
    fn q4_rejects_empty_and_nonfinite() {
        assert!(quantize4(&[]).is_err());
        assert!(quantize4(&[f32::NAN]).is_err());
        assert!(quantize4(&[0.0, f32::NEG_INFINITY]).is_err());
    }

    #[test]
    fn rice_roundtrips_any_codes_at_planned_length() {
        check("rice roundtrip + exact length", 120, |g| {
            let n = g.usize_in(0, 2000);
            // skew toward small codes (the masked-update shape) half the
            // time, uniform the other half — both must round-trip
            let skew = g.usize_in(0, 1) == 0;
            let codes: Vec<u8> = (0..n)
                .map(|_| {
                    let c = g.usize_in(0, 255) as u8;
                    if skew {
                        c & 0x0f
                    } else {
                        c
                    }
                })
                .collect();
            let (k, len) = rice_plan(&codes);
            let mut stream = Vec::new();
            rice_encode(&codes, k, &mut stream);
            assert_eq!(stream.len(), len, "planned length must be exact (k={k})");
            let mut back = Vec::new();
            rice_decode(&stream, n, k, &mut back).unwrap();
            assert_eq!(back, codes, "k={k} n={n} seed {:#x}", g.seed);
        });
    }

    #[test]
    fn rice_never_beats_itself_at_worse_k() {
        let codes: Vec<u8> = (0..512).map(|i| (i % 7) as u8).collect();
        let (k, len) = rice_plan(&codes);
        for other in 0..=RICE_MAX_K {
            let mut s = Vec::new();
            rice_encode(&codes, other, &mut s);
            assert!(s.len() >= len, "k={other} undercuts planned k={k}");
        }
    }

    #[test]
    fn rice_decode_rejects_malformed_streams() {
        let codes: Vec<u8> = vec![3, 0, 17, 250, 9, 9, 64];
        let (k, _) = rice_plan(&codes);
        let mut stream = Vec::new();
        rice_encode(&codes, k, &mut stream);
        let mut out = Vec::new();
        // truncated: lop off the final byte
        assert!(rice_decode(&stream[..stream.len() - 1], codes.len(), k, &mut out).is_err());
        // overlong: a whole extra byte survives past the padding window
        let mut long = stream.clone();
        long.push(0);
        out.clear();
        assert!(rice_decode(&long, codes.len(), k, &mut out).is_err());
        // non-zero padding bits in the final byte
        let mut dirty = stream.clone();
        *dirty.last_mut().unwrap() |= 0x80;
        out.clear();
        if rice_decode(&dirty, codes.len(), k, &mut out).is_ok() {
            // 0x80 may have been a real data bit; force a padded layout
            let mut s2 = Vec::new();
            rice_encode(&[1u8], 0, &mut s2); // 2 bits -> 6 padding bits
            assert_eq!(s2.len(), 1);
            s2[0] |= 0x80;
            out.clear();
            assert!(rice_decode(&s2, 1, 0, &mut out).is_err());
        }
        // unary run past the representable quotient: all-ones byte at k=4
        out.clear();
        assert!(rice_decode(&[0xff, 0xff, 0xff, 0xff], 1, 4, &mut out).is_err());
        // k out of range
        out.clear();
        assert!(rice_decode(&stream, codes.len(), RICE_MAX_K + 1, &mut out).is_err());
    }

    #[test]
    fn rice_empty_stream_is_zero_bytes() {
        let (k, len) = rice_plan(&[]);
        assert_eq!((k, len), (0, 0));
        let mut s = Vec::new();
        rice_encode(&[], k, &mut s);
        assert!(s.is_empty());
        let mut out = Vec::new();
        rice_decode(&s, 0, k, &mut out).unwrap();
        assert!(out.is_empty());
    }
}
