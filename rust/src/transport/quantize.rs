//! 8-bit linear quantization (compression extension).
//!
//! The paper notes its methods "can also be combined with cutting-edge
//! compression algorithms for furthering communication efficiency" (§1).
//! This module provides the simplest respectable such algorithm — per-tensor
//! linear u8 quantization with an f32 (min, scale) header — and the ablation
//! bench stacks it under masking to measure the combined saving.

use crate::util::error::{Error, Result};

/// Quantized tensor: u8 codes + dequantization parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Quantized {
    pub min: f32,
    pub scale: f32,
    pub codes: Vec<u8>,
}

impl Quantized {
    /// Wire size in bytes.
    pub fn bytes(&self) -> usize {
        4 + 4 + self.codes.len()
    }
}

/// Quantize to 256 levels over [min, max]. Zero-range inputs get scale 0.
pub fn quantize(values: &[f32]) -> Result<Quantized> {
    if values.is_empty() {
        return Err(Error::invalid("cannot quantize empty tensor"));
    }
    if values.iter().any(|v| !v.is_finite()) {
        return Err(Error::invalid("cannot quantize non-finite values"));
    }
    let min = values.iter().copied().fold(f32::INFINITY, f32::min);
    let max = values.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let range = max - min;
    let scale = if range > 0.0 { range / 255.0 } else { 0.0 };
    let codes = values
        .iter()
        .map(|&v| {
            if scale == 0.0 {
                0u8
            } else {
                (((v - min) / scale).round() as i64).clamp(0, 255) as u8
            }
        })
        .collect();
    Ok(Quantized { min, scale, codes })
}

/// Inverse of [`quantize`].
pub fn dequantize(q: &Quantized) -> Vec<f32> {
    q.codes
        .iter()
        .map(|&c| q.min + q.scale * c as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        check("quantize error bound", 100, |g| {
            let n = g.usize_in(1, 3000);
            let vals = g.f32_vec(n, -3.0, 3.0);
            let q = quantize(&vals).unwrap();
            let back = dequantize(&q);
            let half_step = q.scale * 0.5 + 1e-6;
            for (a, b) in vals.iter().zip(&back) {
                assert!((a - b).abs() <= half_step, "err {} > {half_step}", (a - b).abs());
            }
        });
    }

    #[test]
    fn constant_tensor_is_exact() {
        let vals = vec![1.25f32; 100];
        let q = quantize(&vals).unwrap();
        assert_eq!(q.scale, 0.0);
        assert_eq!(dequantize(&q), vals);
    }

    #[test]
    fn compression_ratio_is_4x_minus_header() {
        let vals = vec![0.5f32; 10_000];
        let q = quantize(&vals).unwrap();
        assert_eq!(q.bytes(), 8 + 10_000);
        assert!(q.bytes() * 3 < 4 * 10_000);
    }

    #[test]
    fn rejects_empty_and_nonfinite() {
        assert!(quantize(&[]).is_err());
        assert!(quantize(&[f32::NAN]).is_err());
        assert!(quantize(&[f32::INFINITY, 0.0]).is_err());
    }

    #[test]
    fn extremes_map_to_extreme_codes() {
        let q = quantize(&[-1.0, 0.0, 1.0]).unwrap();
        assert_eq!(q.codes[0], 0);
        assert_eq!(q.codes[2], 255);
    }
}
