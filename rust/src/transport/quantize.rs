//! 8-bit and 4-bit linear quantization (compression extension).
//!
//! The paper notes its methods "can also be combined with cutting-edge
//! compression algorithms for furthering communication efficiency" (§1).
//! This module provides the simplest respectable such algorithms —
//! per-tensor linear quantization with an f32 (min, scale) header — in two
//! widths sharing one fixed-point-grid contract:
//!
//! * **q8** — 256 levels, one byte per value, `scale = range / 255`;
//! * **q4** — 16 levels, two values per byte (low nibble first),
//!   `scale = range / 15`.
//!
//! Both dequantize as `min + scale * code`, so a decoded value lies within
//! half a step (`scale / 2`) of the original, zero-range inputs are exact
//! (`scale == 0`), and any consumer that folds dequantized values gets the
//! same bits whether the codes arrived dense or sparse. For odd-length q4
//! tensors the final byte's unused high nibble is zero — decoders treat a
//! non-zero padding nibble as a malformed message.

use crate::util::error::{Error, Result};

/// Quantized tensor: u8 codes + dequantization parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Quantized {
    pub min: f32,
    pub scale: f32,
    pub codes: Vec<u8>,
}

impl Quantized {
    /// Wire size in bytes.
    pub fn bytes(&self) -> usize {
        4 + 4 + self.codes.len()
    }
}

/// Quantize to 256 levels over [min, max]. Zero-range inputs get scale 0.
pub fn quantize(values: &[f32]) -> Result<Quantized> {
    if values.is_empty() {
        return Err(Error::invalid("cannot quantize empty tensor"));
    }
    if values.iter().any(|v| !v.is_finite()) {
        return Err(Error::invalid("cannot quantize non-finite values"));
    }
    let min = values.iter().copied().fold(f32::INFINITY, f32::min);
    let max = values.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let range = max - min;
    let scale = if range > 0.0 { range / 255.0 } else { 0.0 };
    let codes = values
        .iter()
        .map(|&v| {
            if scale == 0.0 {
                0u8
            } else {
                (((v - min) / scale).round() as i64).clamp(0, 255) as u8
            }
        })
        .collect();
    Ok(Quantized { min, scale, codes })
}

/// Inverse of [`quantize`].
pub fn dequantize(q: &Quantized) -> Vec<f32> {
    q.codes
        .iter()
        .map(|&c| q.min + q.scale * c as f32)
        .collect()
}

/// 4-bit quantized tensor: two codes per byte + dequantization parameters.
/// `n` is the logical value count; `packed.len() == n.div_ceil(2)` and the
/// unused high nibble of an odd-length tensor's last byte is zero.
#[derive(Debug, Clone, PartialEq)]
pub struct Quantized4 {
    pub min: f32,
    pub scale: f32,
    pub n: usize,
    pub packed: Vec<u8>,
}

impl Quantized4 {
    /// Wire size in bytes (header + packed codes).
    pub fn bytes(&self) -> usize {
        4 + 4 + self.packed.len()
    }
}

/// Extract the `k`-th 4-bit code from a packed nibble buffer (low nibble
/// of each byte first — the packing [`quantize4`] emits).
#[inline]
pub fn q4_code(packed: &[u8], k: usize) -> u8 {
    (packed[k / 2] >> (4 * (k & 1))) & 0x0f
}

/// Quantize to 16 levels over [min, max], packed two codes per byte. The
/// same grid contract as [`quantize`] (zero-range inputs get scale 0 and
/// are exact), just a coarser step: `scale = range / 15`.
pub fn quantize4(values: &[f32]) -> Result<Quantized4> {
    if values.is_empty() {
        return Err(Error::invalid("cannot quantize empty tensor"));
    }
    if values.iter().any(|v| !v.is_finite()) {
        return Err(Error::invalid("cannot quantize non-finite values"));
    }
    let min = values.iter().copied().fold(f32::INFINITY, f32::min);
    let max = values.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let range = max - min;
    let scale = if range > 0.0 { range / 15.0 } else { 0.0 };
    let mut packed = vec![0u8; values.len().div_ceil(2)];
    for (k, &v) in values.iter().enumerate() {
        let code = if scale == 0.0 {
            0u8
        } else {
            (((v - min) / scale).round() as i64).clamp(0, 15) as u8
        };
        packed[k / 2] |= code << (4 * (k & 1));
    }
    Ok(Quantized4 {
        min,
        scale,
        n: values.len(),
        packed,
    })
}

/// Inverse of [`quantize4`].
pub fn dequantize4(q: &Quantized4) -> Vec<f32> {
    (0..q.n)
        .map(|k| q.min + q.scale * q4_code(&q.packed, k) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        check("quantize error bound", 100, |g| {
            let n = g.usize_in(1, 3000);
            let vals = g.f32_vec(n, -3.0, 3.0);
            let q = quantize(&vals).unwrap();
            let back = dequantize(&q);
            let half_step = q.scale * 0.5 + 1e-6;
            for (a, b) in vals.iter().zip(&back) {
                assert!((a - b).abs() <= half_step, "err {} > {half_step}", (a - b).abs());
            }
        });
    }

    #[test]
    fn constant_tensor_is_exact() {
        let vals = vec![1.25f32; 100];
        let q = quantize(&vals).unwrap();
        assert_eq!(q.scale, 0.0);
        assert_eq!(dequantize(&q), vals);
    }

    #[test]
    fn compression_ratio_is_4x_minus_header() {
        let vals = vec![0.5f32; 10_000];
        let q = quantize(&vals).unwrap();
        assert_eq!(q.bytes(), 8 + 10_000);
        assert!(q.bytes() * 3 < 4 * 10_000);
    }

    #[test]
    fn rejects_empty_and_nonfinite() {
        assert!(quantize(&[]).is_err());
        assert!(quantize(&[f32::NAN]).is_err());
        assert!(quantize(&[f32::INFINITY, 0.0]).is_err());
    }

    #[test]
    fn extremes_map_to_extreme_codes() {
        let q = quantize(&[-1.0, 0.0, 1.0]).unwrap();
        assert_eq!(q.codes[0], 0);
        assert_eq!(q.codes[2], 255);
    }

    #[test]
    fn q4_roundtrip_error_bounded_by_half_step() {
        check("quantize4 error bound", 100, |g| {
            let n = g.usize_in(1, 3000);
            let vals = g.f32_vec(n, -3.0, 3.0);
            let q = quantize4(&vals).unwrap();
            let back = dequantize4(&q);
            assert_eq!(back.len(), n);
            let half_step = q.scale * 0.5 + 1e-6;
            for (a, b) in vals.iter().zip(&back) {
                assert!((a - b).abs() <= half_step, "err {} > {half_step}", (a - b).abs());
            }
        });
    }

    #[test]
    fn q4_constant_tensor_is_exact() {
        let vals = vec![-0.75f32; 33];
        let q = quantize4(&vals).unwrap();
        assert_eq!(q.scale, 0.0);
        assert_eq!(dequantize4(&q), vals);
    }

    #[test]
    fn q4_packs_two_codes_per_byte_with_zero_padding_nibble() {
        // even count: exactly n/2 bytes
        let q = quantize4(&[0.0, 1.0, 0.5, 0.25]).unwrap();
        assert_eq!(q.packed.len(), 2);
        // odd count: the last byte's high nibble is the zero pad
        let q = quantize4(&[0.0, 1.0, 1.0]).unwrap();
        assert_eq!(q.packed.len(), 2);
        assert_eq!(q.packed[1] >> 4, 0, "padding nibble must be zero");
        // extremes hit code 0 and 15
        let q = quantize4(&[-1.0, 1.0]).unwrap();
        assert_eq!(q4_code(&q.packed, 0), 0);
        assert_eq!(q4_code(&q.packed, 1), 15);
    }

    #[test]
    fn q4_compression_ratio_is_8x_minus_header() {
        let vals: Vec<f32> = (0..10_000).map(|i| (i % 7) as f32).collect();
        let q = quantize4(&vals).unwrap();
        assert_eq!(q.bytes(), 8 + 5_000);
        assert!(q.bytes() * 7 < 4 * 10_000);
    }

    #[test]
    fn q4_rejects_empty_and_nonfinite() {
        assert!(quantize4(&[]).is_err());
        assert!(quantize4(&[f32::NAN]).is_err());
        assert!(quantize4(&[0.0, f32::NEG_INFINITY]).is_err());
    }
}
