//! Real socket transport: framed TCP / unix-domain uploads on localhost.
//!
//! [`Loopback`] is the server half: it binds a listener, runs an accept
//! loop on a background thread, and spawns one reader thread per
//! connection that pumps [`crate::transport::frame`] frames into the
//! server's receive channel. The client half is [`SocketSink`]: each
//! upload opens a fresh connection, writes one frame, and closes — the
//! per-upload connect mirrors a cross-device fleet where clients come and
//! go, and keeps connection state out of the protocol.
//!
//! **Malformed peers cannot take the round down.** A connection that sends
//! a bad magic, an unsupported version, an over-cap length, or disconnects
//! mid-frame is dropped with a warning at the reader thread; only complete,
//! well-framed payloads reach [`Transport::recv`]. Payload *content* is
//! validated one layer up: the server's aggregation loop drops payloads
//! that fail codec decode or cohort matching on a bounded per-round
//! budget, and the queue between reader threads and that loop is bounded
//! (`UPLOAD_QUEUE_SLOTS` frames), so a flood of framing-valid garbage
//! backpressures the sender instead of growing frame memory. Connection
//! *count* is bounded only by the OS (one reader thread per accepted
//! connection, reaped by `PEER_READ_TIMEOUT` at the latest) — acceptable
//! for a loopback transport; a non-loopback server needs a connection cap
//! or reader pool (ROADMAP, with authentication).
//!
//! **Trust model.** The listener is an *unauthenticated* local endpoint
//! (ephemeral 127.0.0.1 port / user-owned socket file): any local process
//! that can connect can speak the protocol, and a well-formed payload
//! naming a selected client is indistinguishable from that client's own
//! upload (the genuine one then drops as a duplicate). That matches the
//! simulation's threat model — the transport exists to make framing,
//! partial reads, and backpressure real, not to authenticate clients.
//! Update authentication (per-client session tokens or MACs in the wire
//! header) is the documented next step before any non-loopback bind —
//! tracked in ROADMAP.md.
//!
//! The bytes on the wire are exactly the bytes [`InProcess`] would have
//! carried — the integration suite pins the aggregate bitwise identical
//! across all three transports.
//!
//! [`InProcess`]: crate::transport::link::InProcess

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::transport::frame::{pump_frames, write_frame};
use crate::transport::link::{poll_channel, recv_deadline, Transport, TransportKind, UploadSink};
use crate::util::error::{Error, Result};

#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};

/// Where a [`Loopback`] server listens / where its clients connect.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireAddr {
    Tcp(SocketAddr),
    Uds(PathBuf),
}

impl std::fmt::Display for WireAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireAddr::Tcp(a) => write!(f, "tcp://{a}"),
            WireAddr::Uds(p) => write!(f, "uds://{}", p.display()),
        }
    }
}

/// Read timeout on accepted connections: a peer that connects and stalls
/// forever must not pin a reader thread for the process lifetime.
const PEER_READ_TIMEOUT: Duration = Duration::from_secs(120);

/// Bound on queued-but-unconsumed uploads. Reader threads block (and the
/// peer's writes stall — natural backpressure) once this many frames sit
/// undrained, so a framing-valid flood cannot grow server memory without
/// limit; per-frame size is separately capped by the frame layer.
const UPLOAD_QUEUE_SLOTS: usize = 64;

/// Uniquifier for unix socket paths within one process.
static UDS_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Open one client connection and ship one framed payload.
pub fn send_payload(addr: &WireAddr, payload: &[u8]) -> Result<()> {
    match addr {
        WireAddr::Tcp(a) => {
            let mut stream = TcpStream::connect(a)
                .map_err(|e| Error::transport(format!("connect {addr}: {e}")))?;
            write_frame(&mut stream, payload)?;
            stream.flush()?;
        }
        WireAddr::Uds(path) => {
            #[cfg(unix)]
            {
                let mut stream = UnixStream::connect(path)
                    .map_err(|e| Error::transport(format!("connect {addr}: {e}")))?;
                write_frame(&mut stream, payload)?;
                stream.flush()?;
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(Error::transport(
                    "unix-domain sockets are unsupported on this platform",
                ));
            }
        }
    }
    Ok(())
}

/// Client half of [`Loopback`]: connect-per-upload framed sender.
pub struct SocketSink {
    addr: WireAddr,
}

impl UploadSink for SocketSink {
    fn send(&self, payload: Vec<u8>) -> Result<()> {
        send_payload(&self.addr, &payload)
    }
}

/// Per-connection reader: pump frames into the server channel until EOF,
/// dropping the connection (with a log line) on the first framing error.
fn serve_conn<R: std::io::Read>(peer: &str, conn: &mut R, tx: &SyncSender<Vec<u8>>) {
    let ok = pump_frames(conn, |payload| {
        // Receiver gone = server shut down mid-drain; nothing to do.
        let _ = tx.send(payload);
    });
    if let Err(e) = ok {
        log::warn!("transport: dropping malformed peer {peer}: {e}");
    }
}

/// Shared accept loop for both listener flavors: `accept` blocks for the
/// next connection (already read-timeout-armed) or errors; each accepted
/// stream gets its own reader thread. Exits once the shutdown flag is
/// observed after a wake-up connection (or an accept error).
fn spawn_accept_loop<S, A>(
    mut accept: A,
    tx: SyncSender<Vec<u8>>,
    shutdown: Arc<AtomicBool>,
) -> JoinHandle<()>
where
    S: std::io::Read + Send + 'static,
    A: FnMut() -> std::io::Result<(S, String)> + Send + 'static,
{
    std::thread::spawn(move || loop {
        match accept() {
            Ok((stream, peer)) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let tx = tx.clone();
                std::thread::spawn(move || {
                    let mut stream = stream;
                    serve_conn(&peer, &mut stream, &tx);
                });
            }
            Err(e) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                log::warn!("transport: accept failed: {e}");
                // Persistent accept errors (e.g. fd exhaustion) must not
                // busy-spin the loop and flood the log.
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    })
}

/// Socket-backed [`Transport`]: framed TCP on 127.0.0.1 or a unix-domain
/// socket in the temp dir. Binding picks an ephemeral port / unique path;
/// [`Loopback::addr`] is what clients (the [`SocketSink`]) connect to.
pub struct Loopback {
    addr: WireAddr,
    rx: Receiver<Vec<u8>>,
    accept: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    timeout: Duration,
    kind_label: &'static str,
}

impl Loopback {
    /// Bind the requested socket flavor. `TransportKind::InProcess` is not
    /// a socket and is rejected.
    pub fn bind(kind: TransportKind) -> Result<Loopback> {
        match kind {
            TransportKind::Tcp => Loopback::bind_tcp(),
            TransportKind::Uds => Loopback::bind_uds(),
            TransportKind::InProcess => Err(Error::invalid(
                "in-process transport has no socket to bind",
            )),
        }
    }

    /// Shared tail of both bind flavors: queue, shutdown flag, accept
    /// thread, struct assembly.
    fn from_accept<S, A>(accept: A, addr: WireAddr, kind_label: &'static str) -> Loopback
    where
        S: std::io::Read + Send + 'static,
        A: FnMut() -> std::io::Result<(S, String)> + Send + 'static,
    {
        let (tx, rx) = sync_channel(UPLOAD_QUEUE_SLOTS);
        let shutdown = Arc::new(AtomicBool::new(false));
        let accept = spawn_accept_loop(accept, tx, Arc::clone(&shutdown));
        Loopback {
            addr,
            rx,
            accept: Some(accept),
            shutdown,
            timeout: crate::transport::link::DEFAULT_UPLOAD_TIMEOUT,
            kind_label,
        }
    }

    /// Framed TCP on an ephemeral 127.0.0.1 port.
    pub fn bind_tcp() -> Result<Loopback> {
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| Error::transport(format!("bind tcp listener: {e}")))?;
        let addr = WireAddr::Tcp(
            listener
                .local_addr()
                .map_err(|e| Error::transport(format!("tcp local addr: {e}")))?,
        );
        Ok(Loopback::from_accept(
            move || {
                let (stream, peer) = listener.accept()?;
                let _ = stream.set_read_timeout(Some(PEER_READ_TIMEOUT));
                Ok((stream, peer.to_string()))
            },
            addr,
            "tcp",
        ))
    }

    /// Framed unix-domain socket on a unique temp path.
    pub fn bind_uds() -> Result<Loopback> {
        #[cfg(unix)]
        {
            let path = std::env::temp_dir().join(format!(
                "fedmask-{}-{}.sock",
                std::process::id(),
                UDS_COUNTER.fetch_add(1, Ordering::SeqCst)
            ));
            let _ = std::fs::remove_file(&path);
            let listener = UnixListener::bind(&path)
                .map_err(|e| Error::transport(format!("bind uds {}: {e}", path.display())))?;
            Ok(Loopback::from_accept(
                move || {
                    let (stream, _) = listener.accept()?;
                    let _ = stream.set_read_timeout(Some(PEER_READ_TIMEOUT));
                    Ok((stream, "uds-peer".to_string()))
                },
                WireAddr::Uds(path),
                "uds",
            ))
        }
        #[cfg(not(unix))]
        {
            Err(Error::transport(
                "unix-domain sockets are unsupported on this platform",
            ))
        }
    }

    /// Where clients connect.
    pub fn addr(&self) -> &WireAddr {
        &self.addr
    }

    /// Override the receive timeout (tests use short ones).
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }
}

impl Transport for Loopback {
    fn label(&self) -> &'static str {
        self.kind_label
    }

    fn accepts_foreign_peers(&self) -> bool {
        // An open local endpoint: any process that can connect can frame a
        // payload, so invalid ones are dropped as noise, not bugs.
        true
    }

    fn sink(&self) -> Arc<dyn UploadSink> {
        Arc::new(SocketSink {
            addr: self.addr.clone(),
        })
    }

    fn begin_round(&mut self, _expected: usize) {}

    fn recv(&mut self) -> Result<Vec<u8>> {
        recv_deadline(&self.rx, self.timeout)
    }

    fn try_recv_for(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        poll_channel(&self.rx, timeout)
    }
}

/// Poke a listening address with a throwaway connection so a blocked
/// `accept` observes the shutdown flag. Returns whether the poke landed.
fn wake_listener(addr: &WireAddr) -> bool {
    match addr {
        WireAddr::Tcp(a) => TcpStream::connect_timeout(a, Duration::from_millis(200)).is_ok(),
        #[cfg(unix)]
        WireAddr::Uds(path) => UnixStream::connect(path).is_ok(),
        #[cfg(not(unix))]
        WireAddr::Uds(_) => false,
    }
}

impl Drop for Loopback {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Only join the accept loop when the wake-up connection landed —
        // otherwise accept may never return and the join would hang; the
        // flagged thread is left to die with the process instead.
        if wake_listener(&self.addr) {
            if let Some(h) = self.accept.take() {
                let _ = h.join();
            }
        }
        if let WireAddr::Uds(path) = &self.addr {
            let _ = std::fs::remove_file(path);
        }
    }
}
