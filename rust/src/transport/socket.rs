//! Real socket transport: persistent, token-authenticated duplex TCP /
//! unix-domain sessions on localhost, served by a single-threaded
//! readiness reactor.
//!
//! [`Loopback`] is the server half: it binds a listener and runs **one**
//! background reactor thread that owns every connection. The pre-reactor
//! design gave each accepted connection its own blocking session thread —
//! fine at tens of clients, pathological at thousands (a 10k-client
//! fan-in means 10k stacks and a scheduler storm). The reactor instead
//! keeps every socket nonblocking and drives a per-connection
//! [`FrameReader`] state machine from a level-triggered scan loop:
//!
//! 1. the first frame must be a `hello` naming a registered client id —
//!    the server mints a per-client token ([`crate::transport::session`])
//!    and replies `welcome`;
//! 2. every later `upload` frame is verified against the session (token
//!    match + the payload's claimed client id, peeked without decoding)
//!    **before** the payload is forwarded to the aggregation loop;
//! 3. the server pushes each round's encoded `broadcast` frame down the
//!    same socket, so the downlink genuinely crosses the kernel —
//!    [`ClientConn::recv_broadcast`] is where a client job picks it up.
//!
//! Server-side state is sharded by [`shard_of`] — the same Fibonacci hash
//! that routes aggregation payloads — so session tables and peer maps
//! ([`SessionShards`], peer shards) never contend on one lock.
//!
//! **Admission control.** The reactor accepts at most
//! [`ServerTuning::max_conns`] live connections; a connection past the
//! cap is closed before any frame is read, which the connecting client
//! surfaces as a typed refusal ("registration refused?"). A connection
//! that completes TCP accept but never sends its `hello` is reaped after
//! [`ServerTuning::handshake_timeout`] — idle pre-auth sockets cannot
//! accumulate.
//!
//! **Malformed and spoofing peers cannot take the round down.** A
//! connection that sends a bad magic, an unsupported version, an over-cap
//! length, or disconnects mid-frame is torn down by the reactor with a
//! warning; a hello for an unregistered or already-active client, or an
//! upload whose token/claimed-id fails verification, is dropped the same
//! way with a typed [`Error::Auth`] logged — in every case before any
//! codec decode, and without disturbing the rest of the cohort. Payload
//! *content* is still validated one layer up (codec decode + cohort
//! matching, on a bounded per-round budget), and the queue between the
//! reactor and that loop is bounded ([`UPLOAD_QUEUE_SLOTS`]), so a flood
//! of framing-valid garbage backpressures the wire instead of growing
//! server memory.
//!
//! **Trust model.** The session token bounds *blind* spoofing: a local
//! process that merely knows the port can no longer forge a selected
//! client's upload (the pre-refactor hole). It does not bound an observer
//! — the token crosses the loopback in the clear, so a peer that can read
//! the traffic could replay it, and registration itself is first-come
//! within the (brief) registration window. Upgrading the credential to a
//! keyed MAC over the payload is the documented next step before any
//! non-loopback bind — tracked in ROADMAP.md.
//!
//! The payload bytes on the wire are exactly the bytes [`InProcess`]
//! would have carried, in both directions — the integration suite pins
//! the aggregate bitwise identical across all three transports. See
//! `docs/SCALE.md` for the reactor's event loop and the sharding
//! topology.
//!
//! [`InProcess`]: crate::transport::link::InProcess

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::transport::codec::peek_client;
use crate::transport::frame::{
    frame_bytes, write_frame, Frame, FrameKind, FrameReader, FrameStream, NO_TOKEN,
};
use crate::transport::link::{
    poll_channel, recv_deadline, DownlinkSource, Transport, TransportKind, UploadSink,
};
use crate::transport::session::{hello_payload, shard_of, validate_upload, SessionShards};
use crate::util::error::{Error, Result};

#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};

/// Where a [`Loopback`] server listens / where its clients connect.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireAddr {
    Tcp(SocketAddr),
    Uds(PathBuf),
}

impl std::fmt::Display for WireAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireAddr::Tcp(a) => write!(f, "tcp://{a}"),
            WireAddr::Uds(p) => write!(f, "uds://{}", p.display()),
        }
    }
}

/// How long a connecting client waits for the `welcome` reply; also the
/// default server-side pre-auth reap deadline.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Bound on queued-but-unconsumed uploads. The reactor stalls (and the
/// peers' writes stall — natural backpressure) once this many frames sit
/// undrained, so a framing-valid flood cannot grow server memory without
/// limit; per-frame size is separately capped by the frame layer.
const UPLOAD_QUEUE_SLOTS: usize = 64;

/// Per-connection read budget per reactor tick: a firehose peer yields to
/// the rest of the cohort after this many bytes and is revisited next
/// tick, so one fast writer cannot starve 10k slow ones.
const CONN_READ_BUDGET: usize = 256 * 1024;

/// Deadline for the nonblocking `welcome` write. The frame is 16 bytes
/// into an empty kernel buffer — missing this means the peer is gone.
const WELCOME_WRITE_DEADLINE: Duration = Duration::from_secs(1);

/// Deadline for one nonblocking downlink `broadcast` write. A client that
/// stops reading for this long has effectively disconnected; the failure
/// is logged and its job errors out client-side.
const DOWNLINK_WRITE_DEADLINE: Duration = Duration::from_secs(30);

/// Reactor sleep bounds for the idle backoff: 1 ms while traffic is
/// recent, doubling to 10 ms when the wire goes quiet.
const IDLE_SLEEP_MIN: Duration = Duration::from_millis(1);
const IDLE_SLEEP_MAX: Duration = Duration::from_millis(10);

/// Uniquifier for unix socket paths within one process.
static UDS_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Server knobs for [`Loopback::bind_with`]: admission cap, pre-auth reap
/// deadline, and how many ways the session/peer state is sharded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerTuning {
    /// Maximum live connections; over-cap accepts are closed before any
    /// frame is read. Size to the fleet — every registered client holds
    /// one persistent connection.
    pub max_conns: usize,
    /// How long an accepted connection may sit without completing its
    /// `hello` before the reactor reaps it.
    pub handshake_timeout: Duration,
    /// Shard count for the session table and peer map.
    pub session_shards: usize,
}

impl Default for ServerTuning {
    fn default() -> ServerTuning {
        ServerTuning {
            max_conns: 4096,
            handshake_timeout: HANDSHAKE_TIMEOUT,
            session_shards: 8,
        }
    }
}

/// One duplex byte stream, TCP or unix-domain.
#[derive(Debug)]
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn connect(addr: &WireAddr) -> Result<Stream> {
        match addr {
            WireAddr::Tcp(a) => Ok(Stream::Tcp(TcpStream::connect(a).map_err(|e| {
                Error::transport(format!("connect {addr}: {e}"))
            })?)),
            WireAddr::Uds(path) => {
                #[cfg(unix)]
                {
                    Ok(Stream::Unix(UnixStream::connect(path).map_err(|e| {
                        Error::transport(format!("connect {addr}: {e}"))
                    })?))
                }
                #[cfg(not(unix))]
                {
                    let _ = path;
                    Err(Error::transport(
                        "unix-domain sockets are unsupported on this platform",
                    ))
                }
            }
        }
    }

    fn try_clone(&self) -> Result<Stream> {
        match self {
            Stream::Tcp(s) => Ok(Stream::Tcp(
                s.try_clone().map_err(|e| Error::transport(format!("clone stream: {e}")))?,
            )),
            #[cfg(unix)]
            Stream::Unix(s) => Ok(Stream::Unix(
                s.try_clone().map_err(|e| Error::transport(format!("clone stream: {e}")))?,
            )),
        }
    }

    fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(timeout),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(timeout),
        }
        .map_err(|e| Error::transport(format!("set read timeout: {e}")))
    }

    fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(nb),
        }
        .map_err(|e| Error::transport(format!("set nonblocking: {e}")))
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// Write all of `bytes` to a **nonblocking** stream, spinning (briefly)
/// through `WouldBlock` until `deadline`. Server-side write halves are
/// clones of reactor-owned sockets and share their nonblocking mode, so a
/// plain `write_all` would error the moment a kernel buffer filled.
fn nb_write_all(stream: &mut Stream, bytes: &[u8], deadline: Duration) -> Result<()> {
    let start = Instant::now();
    let mut at = 0usize;
    while at < bytes.len() {
        match stream.write(&bytes[at..]) {
            Ok(0) => return Err(Error::transport("connection closed mid-write")),
            Ok(n) => at += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if start.elapsed() >= deadline {
                    return Err(Error::transport(format!(
                        "write stalled past {deadline:?} ({at}/{} bytes)",
                        bytes.len()
                    )));
                }
                std::thread::sleep(Duration::from_micros(500));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::transport(format!("write: {e}"))),
        }
    }
    Ok(())
}

/// The client half of one persistent duplex session: holds the socket and
/// the token the server issued at registration. One exists per registered
/// client for the lifetime of the run; a client job locks it to receive
/// the round's broadcast and again to push its upload — the same kernel
/// socket carries both directions.
pub struct ClientConn {
    client: u32,
    token: u64,
    io: Mutex<(Stream, FrameStream)>,
}

impl ClientConn {
    /// Connect and run the registration handshake: `hello(client)` out,
    /// `welcome(token)` back. Fails (typed) if the server refuses the
    /// registration — unregistered id, duplicate session, connection cap
    /// — or the reply does not arrive within [`HANDSHAKE_TIMEOUT`].
    pub fn connect(addr: &WireAddr, client: u32) -> Result<ClientConn> {
        let mut stream = Stream::connect(addr)?;
        write_frame(&mut stream, FrameKind::Hello, NO_TOKEN, &hello_payload(client))?;
        stream.flush()?;
        stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
        let mut frames = FrameStream::new();
        let welcome = frames.next(&mut stream)?.ok_or_else(|| {
            Error::auth(format!(
                "server closed the connection instead of welcoming client {client} \
                 (registration refused?)"
            ))
        })?;
        if welcome.kind != FrameKind::Welcome {
            return Err(Error::auth(format!(
                "client {client} expected a welcome, got {:?}",
                welcome.kind
            )));
        }
        if welcome.token == NO_TOKEN {
            return Err(Error::auth(format!("server issued client {client} an empty token")));
        }
        Ok(ClientConn {
            client,
            token: welcome.token,
            io: Mutex::new((stream, frames)),
        })
    }

    /// The registered client id this session belongs to.
    pub fn client(&self) -> u32 {
        self.client
    }

    /// Ship one encoded update, stamped with the session token.
    pub fn upload(&self, payload: &[u8]) -> Result<()> {
        let mut io = self.io.lock().map_err(|_| Error::transport("client conn poisoned"))?;
        write_frame(&mut io.0, FrameKind::Upload, self.token, payload)?;
        io.0.flush()?;
        Ok(())
    }

    /// Block until the next `broadcast` frame addressed to this session
    /// arrives (at most `timeout`), and hand back its payload. A frame
    /// whose token is not this session's is a typed [`Error::Auth`].
    pub fn recv_broadcast(&self, timeout: Duration) -> Result<Vec<u8>> {
        let mut io = self.io.lock().map_err(|_| Error::transport("client conn poisoned"))?;
        io.0.set_read_timeout(Some(timeout))?;
        let (stream, frames) = &mut *io;
        let frame = frames.expect_next(stream)?;
        if frame.kind != FrameKind::Broadcast {
            return Err(Error::transport(format!(
                "client {} expected a broadcast, got {:?}",
                self.client, frame.kind
            )));
        }
        if frame.token != self.token {
            return Err(Error::auth(format!(
                "broadcast token does not match client {}'s session",
                self.client
            )));
        }
        Ok(frame.payload)
    }
}

/// Server-side record of one live session: the token it speaks under and
/// the write half of its socket (for downlink pushes).
struct Peer {
    token: u64,
    writer: Stream,
}

/// Peer map sharded by the same client-id hash that routes sessions and
/// aggregation payloads: the reactor inserting one client's peer never
/// contends with the downlink writer pushing to another shard.
struct PeerShards {
    shards: Vec<Mutex<HashMap<u32, Peer>>>,
}

impl PeerShards {
    fn new(n: usize) -> PeerShards {
        PeerShards {
            shards: (0..n.max(1)).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, client: u32) -> &Mutex<HashMap<u32, Peer>> {
        &self.shards[shard_of(client, self.shards.len())]
    }

    fn insert(&self, client: u32, peer: Peer) {
        if let Ok(mut map) = self.shard(client).lock() {
            map.insert(client, peer);
        }
    }

    /// Evict `client`'s entry only if it still belongs to `token` — a
    /// successor session may have replaced it already.
    fn evict_if(&self, client: u32, token: u64) {
        if let Ok(mut map) = self.shard(client).lock() {
            if map.get(&client).map(|p| p.token) == Some(token) {
                map.remove(&client);
            }
        }
    }

    /// Clone `client`'s write half and its session token.
    fn writer_of(&self, client: u32) -> Option<(Result<Stream>, u64)> {
        self.shard(client)
            .lock()
            .ok()
            .and_then(|map| map.get(&client).map(|p| (p.writer.try_clone(), p.token)))
    }
}

/// Nonblocking listener, TCP or unix-domain.
enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn set_nonblocking(&self) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(true),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(true),
        }
    }

    fn accept(&self) -> std::io::Result<(Stream, String)> {
        match self {
            Listener::Tcp(l) => {
                let (stream, peer) = l.accept()?;
                Ok((Stream::Tcp(stream), peer.to_string()))
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (stream, _) = l.accept()?;
                Ok((Stream::Unix(stream), "uds-peer".to_string()))
            }
        }
    }
}

/// Where one reactor-owned connection is in its lifecycle.
enum ConnState {
    /// Accepted, no `hello` yet; reaped once `opened` is older than the
    /// handshake timeout.
    Handshaking { opened: Instant },
    /// Authenticated: uploads are verified against this session.
    Established(crate::transport::session::Session),
}

/// One connection under the reactor: its nonblocking socket, its
/// incremental frame decoder, and its lifecycle state.
struct Conn {
    stream: Stream,
    reader: FrameReader,
    state: ConnState,
    peer: String,
}

/// What the reactor should do with a connection after servicing it.
enum Fate {
    Keep,
    Close,
}

/// Deliver one verified upload to the drain loop's bounded queue,
/// retrying through `Full` so wire backpressure is preserved. Checking
/// the shutdown flag inside the retry loop is what keeps [`Loopback`]'s
/// `Drop` deadlock-free: a full queue during teardown (receiver alive but
/// nobody draining) would otherwise pin the reactor in `send` forever and
/// hang the join.
fn deliver_upload(tx: &SyncSender<Vec<u8>>, shutdown: &AtomicBool, payload: Vec<u8>) -> bool {
    let mut payload = payload;
    loop {
        match tx.try_send(payload) {
            Ok(()) => return true,
            Err(TrySendError::Full(p)) => {
                if shutdown.load(Ordering::SeqCst) {
                    return false;
                }
                payload = p;
                std::thread::sleep(IDLE_SLEEP_MIN);
            }
            Err(TrySendError::Disconnected(_)) => return false,
        }
    }
}

/// Handle one completed frame on `conn`. Returns the connection's fate;
/// every rejection path logs and drops *this* connection only.
fn on_frame(
    conn: &mut Conn,
    frame: Frame,
    sessions: &SessionShards,
    peers: &PeerShards,
    tx: &SyncSender<Vec<u8>>,
    shutdown: &AtomicBool,
) -> Fate {
    match conn.state {
        ConnState::Handshaking { .. } => {
            let session = match sessions.handshake(&frame) {
                Ok(s) => s,
                Err(e) => {
                    log::warn!("transport: refusing peer {}: {e}", conn.peer);
                    return Fate::Close;
                }
            };
            let end = |sessions: &SessionShards| {
                let _ = sessions.end(session);
            };
            let writer = match conn.stream.try_clone() {
                Ok(w) => w,
                Err(e) => {
                    log::warn!("transport: peer {}: {e}", conn.peer);
                    end(sessions);
                    return Fate::Close;
                }
            };
            // The peers entry must exist before the welcome goes out: the
            // moment the client reads it, registration returns and the
            // server may push a downlink.
            peers.insert(session.client, Peer { token: session.token, writer });
            let welcome = match frame_bytes(FrameKind::Welcome, session.token, &[]) {
                Ok(b) => b,
                Err(e) => {
                    log::warn!("transport: peer {}: welcome failed: {e}", conn.peer);
                    peers.evict_if(session.client, session.token);
                    end(sessions);
                    return Fate::Close;
                }
            };
            if let Err(e) = nb_write_all(&mut conn.stream, &welcome, WELCOME_WRITE_DEADLINE) {
                log::warn!("transport: peer {}: welcome failed: {e}", conn.peer);
                peers.evict_if(session.client, session.token);
                end(sessions);
                return Fate::Close;
            }
            conn.state = ConnState::Established(session);
            Fate::Keep
        }
        ConnState::Established(session) => {
            if let Err(e) = validate_upload(&frame, session) {
                log::warn!(
                    "transport: rejecting spoofed upload from peer {} (client {}): {e}",
                    conn.peer,
                    session.client
                );
                return Fate::Close;
            }
            if deliver_upload(tx, shutdown, frame.payload) {
                Fate::Keep
            } else {
                // Receiver gone = server shutting down; nothing to do.
                Fate::Close
            }
        }
    }
}

/// Service one connection: read until `WouldBlock` (or the per-tick
/// budget), feeding the frame decoder and handling completed frames.
fn service_conn(
    conn: &mut Conn,
    buf: &mut [u8],
    sessions: &SessionShards,
    peers: &PeerShards,
    tx: &SyncSender<Vec<u8>>,
    shutdown: &AtomicBool,
    activity: &mut bool,
) -> Fate {
    let mut budget = CONN_READ_BUDGET;
    loop {
        match conn.stream.read(buf) {
            Ok(0) => {
                if conn.reader.mid_frame() {
                    log::warn!("transport: peer {} disconnected mid-frame", conn.peer);
                }
                return Fate::Close; // EOF: clean disconnect
            }
            Ok(n) => {
                *activity = true;
                let mut chunk = &buf[..n];
                while !chunk.is_empty() {
                    match conn.reader.feed(chunk) {
                        Ok((used, done)) => {
                            chunk = &chunk[used..];
                            if let Some(frame) = done {
                                if let Fate::Close =
                                    on_frame(conn, frame, sessions, peers, tx, shutdown)
                                {
                                    return Fate::Close;
                                }
                            }
                        }
                        Err(e) => {
                            log::warn!(
                                "transport: dropping malformed peer {}: {e}",
                                conn.peer
                            );
                            return Fate::Close;
                        }
                    }
                }
                budget = budget.saturating_sub(n);
                if budget == 0 {
                    return Fate::Keep; // firehose: revisit next tick
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Fate::Keep,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => {
                log::warn!("transport: dropping peer {}: {e}", conn.peer);
                return Fate::Close;
            }
        }
    }
}

/// End an authenticated connection's session and evict its peer entry.
fn teardown(conn: Conn, sessions: &SessionShards, peers: &PeerShards) {
    if let ConnState::Established(session) = conn.state {
        let _ = sessions.end(session);
        peers.evict_if(session.client, session.token);
    }
}

/// The reactor: one thread, every connection. Per tick it drains pending
/// accepts (enforcing the admission cap), reads each connection to
/// `WouldBlock` through its frame decoder, reaps stale pre-auth
/// connections, and sleeps with a short backoff when the wire is idle.
/// Exits when the shutdown flag is raised — no wake-up poke needed, the
/// listener never blocks.
fn run_reactor(
    listener: Listener,
    sessions: Arc<SessionShards>,
    peers: Arc<PeerShards>,
    tx: SyncSender<Vec<u8>>,
    shutdown: Arc<AtomicBool>,
    tuning: ServerTuning,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut buf = vec![0u8; 64 * 1024];
    let mut idle = IDLE_SLEEP_MIN;
    while !shutdown.load(Ordering::SeqCst) {
        let mut activity = false;
        // --- admit ---
        loop {
            match listener.accept() {
                Ok((stream, peer)) => {
                    activity = true;
                    if conns.len() >= tuning.max_conns {
                        log::warn!(
                            "transport: refusing peer {peer}: connection cap {} reached",
                            tuning.max_conns
                        );
                        continue; // stream drops here: peer sees EOF
                    }
                    if let Err(e) = stream.set_nonblocking(true) {
                        log::warn!("transport: peer {peer}: {e}");
                        continue;
                    }
                    conns.push(Conn {
                        stream,
                        reader: FrameReader::new(),
                        state: ConnState::Handshaking { opened: Instant::now() },
                        peer,
                    });
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::warn!("transport: accept failed: {e}");
                    break; // backoff below paces retries (e.g. fd exhaustion)
                }
            }
        }
        // --- service + reap ---
        let mut i = 0;
        while i < conns.len() {
            let reap = matches!(
                conns[i].state,
                ConnState::Handshaking { opened } if opened.elapsed() > tuning.handshake_timeout
            );
            if reap {
                log::warn!(
                    "transport: reaping peer {} (no hello within {:?})",
                    conns[i].peer,
                    tuning.handshake_timeout
                );
                teardown(conns.swap_remove(i), &sessions, &peers);
                continue;
            }
            match service_conn(
                &mut conns[i],
                &mut buf,
                &sessions,
                &peers,
                &tx,
                &shutdown,
                &mut activity,
            ) {
                Fate::Keep => i += 1,
                Fate::Close => teardown(conns.swap_remove(i), &sessions, &peers),
            }
        }
        // --- pace ---
        if activity {
            idle = IDLE_SLEEP_MIN;
        } else {
            std::thread::sleep(idle);
            idle = (idle * 2).min(IDLE_SLEEP_MAX);
        }
    }
}

/// Dedicated downlink writer: drains (client, payload) sends and writes
/// each as a `broadcast` frame on that client's session. A write that
/// stalls on a full kernel buffer stalls only this thread (bounded by
/// [`DOWNLINK_WRITE_DEADLINE`]) — the server's round loop keeps draining
/// uploads, which is what eventually frees the blocked reader and the
/// buffer (no deadlock by construction).
///
/// Failures here are logged, not returned: there is no caller to return
/// them to. The round still fails *fast*, client-side — a session this
/// thread cannot write to is one the reactor has torn down, which closed
/// the socket, so the waiting client job's `recv_broadcast` sees EOF (a
/// typed error) immediately and the job error surfaces through the pool
/// within one drain poll tick.
fn spawn_downlink_writer(
    peers: Arc<PeerShards>,
    rx: Receiver<(u32, Arc<Vec<u8>>)>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        for (client, payload) in rx {
            match peers.writer_of(client) {
                Some((Ok(mut writer), token)) => {
                    let res = frame_bytes(FrameKind::Broadcast, token, &payload).and_then(
                        |bytes| nb_write_all(&mut writer, &bytes, DOWNLINK_WRITE_DEADLINE),
                    );
                    if let Err(e) = res {
                        log::warn!("transport: downlink to client {client} failed: {e}");
                    }
                }
                Some((Err(e), _)) => {
                    log::warn!("transport: downlink to client {client} failed: {e}");
                }
                None => {
                    log::warn!("transport: downlink to client {client} with no live session");
                }
            }
        }
    })
}

/// Upload sink over the persistent sessions: routes each payload to its
/// client's connection by the claimed sender id (bytes the session layer
/// re-verifies server-side against the connection's token).
struct SocketSink {
    conns: Arc<Mutex<HashMap<u32, Arc<ClientConn>>>>,
}

impl UploadSink for SocketSink {
    fn send(&self, payload: Vec<u8>) -> Result<()> {
        let client = peek_client(&payload)
            .ok_or_else(|| Error::invalid("upload payload too short to name a client"))?;
        let conn = self
            .conns
            .lock()
            .map_err(|_| Error::transport("socket sink poisoned"))?
            .get(&client)
            .cloned()
            .ok_or_else(|| {
                Error::invalid(format!("client {client} has no registered session"))
            })?;
        conn.upload(&payload)
    }
}

/// Downlink handle over the persistent sessions: a client job blocks on
/// its own connection for the round's broadcast frame.
struct SocketDownlink {
    conns: Arc<Mutex<HashMap<u32, Arc<ClientConn>>>>,
}

impl DownlinkSource for SocketDownlink {
    fn recv(&self, client: u32, timeout: Duration) -> Result<Arc<Vec<u8>>> {
        let conn = self
            .conns
            .lock()
            .map_err(|_| Error::transport("socket downlink poisoned"))?
            .get(&client)
            .cloned()
            .ok_or_else(|| {
                Error::invalid(format!("client {client} has no registered session"))
            })?;
        // Bytes come off this client's own wire, so the Arc wraps a fresh
        // read — sharing happens transport-side only where it is real
        // (the in-process mailboxes).
        conn.recv_broadcast(timeout).map(Arc::new)
    }
}

/// Socket-backed [`Transport`]: framed TCP on 127.0.0.1 or a unix-domain
/// socket in the temp dir, served by the reactor. Binding picks an
/// ephemeral port / unique path; [`Loopback::addr`] is what clients
/// connect to.
pub struct Loopback {
    addr: WireAddr,
    rx: Receiver<Vec<u8>>,
    reactor: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    timeout: Duration,
    kind_label: &'static str,
    sessions: Arc<SessionShards>,
    /// Client halves of the persistent sessions, by client id.
    conns: Arc<Mutex<HashMap<u32, Arc<ClientConn>>>>,
    dl_tx: Option<Sender<(u32, Arc<Vec<u8>>)>>,
    dl_writer: Option<JoinHandle<()>>,
}

impl Loopback {
    /// Bind the requested socket flavor with default [`ServerTuning`].
    /// `TransportKind::InProcess` is not a socket and is rejected.
    pub fn bind(kind: TransportKind) -> Result<Loopback> {
        Loopback::bind_with(kind, ServerTuning::default())
    }

    /// Bind with explicit server tuning (admission cap, handshake reap
    /// deadline, shard count).
    pub fn bind_with(kind: TransportKind, tuning: ServerTuning) -> Result<Loopback> {
        match kind {
            TransportKind::Tcp => Loopback::bind_tcp_with(tuning),
            TransportKind::Uds => Loopback::bind_uds_with(tuning),
            TransportKind::InProcess => Err(Error::invalid(
                "in-process transport has no socket to bind",
            )),
        }
    }

    /// Shared tail of both bind flavors: queues, sharded session/peer
    /// state, the reactor and downlink-writer threads, struct assembly.
    fn from_listener(
        listener: Listener,
        addr: WireAddr,
        kind_label: &'static str,
        tuning: ServerTuning,
    ) -> Result<Loopback> {
        listener
            .set_nonblocking()
            .map_err(|e| Error::transport(format!("set listener nonblocking: {e}")))?;
        let (tx, rx) = sync_channel(UPLOAD_QUEUE_SLOTS);
        let shutdown = Arc::new(AtomicBool::new(false));
        let sessions = Arc::new(SessionShards::new(tuning.session_shards));
        let peers = Arc::new(PeerShards::new(tuning.session_shards));
        let reactor = {
            let sessions = Arc::clone(&sessions);
            let peers = Arc::clone(&peers);
            let shutdown = Arc::clone(&shutdown);
            std::thread::Builder::new()
                .name("fedmask-reactor".into())
                .spawn(move || run_reactor(listener, sessions, peers, tx, shutdown, tuning))
                .map_err(|e| Error::transport(format!("spawn reactor: {e}")))?
        };
        let (dl_tx, dl_rx) = channel();
        let dl_writer = spawn_downlink_writer(peers, dl_rx);
        Ok(Loopback {
            addr,
            rx,
            reactor: Some(reactor),
            shutdown,
            timeout: crate::transport::link::DEFAULT_UPLOAD_TIMEOUT,
            kind_label,
            sessions,
            conns: Arc::new(Mutex::new(HashMap::new())),
            dl_tx: Some(dl_tx),
            dl_writer: Some(dl_writer),
        })
    }

    /// Framed TCP on an ephemeral 127.0.0.1 port.
    pub fn bind_tcp() -> Result<Loopback> {
        Loopback::bind_tcp_with(ServerTuning::default())
    }

    /// Framed TCP with explicit tuning.
    pub fn bind_tcp_with(tuning: ServerTuning) -> Result<Loopback> {
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| Error::transport(format!("bind tcp listener: {e}")))?;
        let addr = WireAddr::Tcp(
            listener
                .local_addr()
                .map_err(|e| Error::transport(format!("tcp local addr: {e}")))?,
        );
        Loopback::from_listener(Listener::Tcp(listener), addr, "tcp", tuning)
    }

    /// Framed unix-domain socket on a unique temp path.
    pub fn bind_uds() -> Result<Loopback> {
        Loopback::bind_uds_with(ServerTuning::default())
    }

    /// Framed unix-domain socket with explicit tuning.
    pub fn bind_uds_with(tuning: ServerTuning) -> Result<Loopback> {
        #[cfg(unix)]
        {
            let path = std::env::temp_dir().join(format!(
                "fedmask-{}-{}.sock",
                std::process::id(),
                UDS_COUNTER.fetch_add(1, Ordering::SeqCst)
            ));
            let _ = std::fs::remove_file(&path);
            let listener = UnixListener::bind(&path)
                .map_err(|e| Error::transport(format!("bind uds {}: {e}", path.display())))?;
            Loopback::from_listener(Listener::Unix(listener), WireAddr::Uds(path), "uds", tuning)
        }
        #[cfg(not(unix))]
        {
            let _ = tuning;
            Err(Error::transport(
                "unix-domain sockets are unsupported on this platform",
            ))
        }
    }

    /// Where clients connect.
    pub fn addr(&self) -> &WireAddr {
        &self.addr
    }

    /// Override the receive timeout (tests use short ones).
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// A registered client's persistent session, if any — test and bench
    /// access to the raw connection (e.g. to measure per-upload cost or
    /// craft a cross-client spoof attempt against the server's verifier).
    pub fn client_conn(&self, client: u32) -> Option<Arc<ClientConn>> {
        self.conns.lock().ok()?.get(&client).cloned()
    }

    /// Open the registration window for `clients` **without** opening
    /// their connections — for tests and benches that drive raw
    /// [`ClientConn`]s (e.g. the session-per-upload fan-in measurement).
    /// Production callers use [`Transport::register_clients`], which both
    /// allows and connects.
    pub fn allow_clients(&self, clients: &[u32]) -> Result<()> {
        self.sessions.allow(clients)
    }
}

impl Transport for Loopback {
    fn label(&self) -> &'static str {
        self.kind_label
    }

    fn accepts_foreign_peers(&self) -> bool {
        // An open local endpoint: any process that can connect can frame a
        // payload (sessions bound who can *upload*, not who can connect),
        // so an invalid payload that somehow clears the session layer is
        // dropped as noise, not treated as an internal bug.
        true
    }

    fn register_clients(&mut self, clients: &[u32]) -> Result<()> {
        self.sessions.allow(clients)?;
        let mut conns = self
            .conns
            .lock()
            .map_err(|_| Error::transport("socket conns poisoned"))?;
        for &c in clients {
            if conns.contains_key(&c) {
                continue;
            }
            conns.insert(c, Arc::new(ClientConn::connect(&self.addr, c)?));
        }
        Ok(())
    }

    fn sink(&self) -> Arc<dyn UploadSink> {
        Arc::new(SocketSink {
            conns: Arc::clone(&self.conns),
        })
    }

    fn send_downlink(&mut self, client: u32, payload: Arc<Vec<u8>>) -> Result<()> {
        if !self
            .conns
            .lock()
            .map_err(|_| Error::transport("socket conns poisoned"))?
            .contains_key(&client)
        {
            return Err(Error::invalid(format!(
                "downlink to client {client}, which was never registered"
            )));
        }
        self.dl_tx
            .as_ref()
            .expect("downlink writer alive while the transport is")
            .send((client, payload))
            .map_err(|_| Error::transport("downlink writer gone"))
    }

    fn downlink(&self) -> Arc<dyn DownlinkSource> {
        Arc::new(SocketDownlink {
            conns: Arc::clone(&self.conns),
        })
    }

    fn begin_round(&mut self, _expected: usize) {}

    fn recv(&mut self) -> Result<Vec<u8>> {
        recv_deadline(&self.rx, self.timeout)
    }

    fn try_recv_for(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        poll_channel(&self.rx, timeout)
    }
}

impl Drop for Loopback {
    fn drop(&mut self) {
        // 1) Close the client halves first: the reactor observes EOFs and
        //    tears those sessions down, and any downlink write stalled on
        //    a dead client's full buffer fails instead of hanging.
        if let Ok(mut conns) = self.conns.lock() {
            conns.clear();
        }
        // 2) Retire the downlink writer (its channel closes when the
        //    sender drops).
        drop(self.dl_tx.take());
        if let Some(h) = self.dl_writer.take() {
            let _ = h.join();
        }
        // 3) Raise the shutdown flag and join the reactor: its listener
        //    never blocks, so it observes the flag within one idle sleep
        //    (≤ 10 ms) — no wake-up connection needed.
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.reactor.take() {
            let _ = h.join();
        }
        if let WireAddr::Uds(path) = &self.addr {
            let _ = std::fs::remove_file(path);
        }
    }
}
