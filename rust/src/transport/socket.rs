//! Real socket transport: persistent, token-authenticated duplex TCP /
//! unix-domain sessions on localhost.
//!
//! [`Loopback`] is the server half: it binds a listener, runs an accept
//! loop on a background thread, and gives every accepted connection its
//! own session thread. Since the full-duplex refactor a connection is a
//! **session**, not a drop box:
//!
//! 1. the first frame must be a `hello` naming a registered client id —
//!    the server mints a per-client token ([`crate::transport::session`])
//!    and replies `welcome`;
//! 2. every later `upload` frame is verified against the session (token
//!    match + the payload's claimed client id, peeked without decoding)
//!    **before** the payload is forwarded to the aggregation loop;
//! 3. the server pushes each round's encoded `broadcast` frame down the
//!    same socket, so the downlink genuinely crosses the kernel —
//!    [`ClientConn::recv_broadcast`] is where a client job picks it up.
//!
//! The client half is [`ClientConn`]: one persistent connection per
//! registered client, created by [`Transport::register_clients`] and held
//! for the run — replacing the old connect-per-upload sender, which both
//! made every upload anonymous and paid a connect per message.
//!
//! **Malformed and spoofing peers cannot take the round down.** A
//! connection that sends a bad magic, an unsupported version, an over-cap
//! length, or disconnects mid-frame is dropped with a warning at its own
//! session thread; a hello for an unregistered or already-active client,
//! or an upload whose token/claimed-id fails verification, is dropped the
//! same way with a typed [`Error::Auth`] logged — in every case before
//! any codec decode, and without disturbing the rest of the cohort.
//! Payload *content* is still validated one layer up (codec decode +
//! cohort matching, on a bounded per-round budget), and the queue between
//! session threads and that loop is bounded ([`UPLOAD_QUEUE_SLOTS`]), so
//! a flood of framing-valid garbage backpressures the sender instead of
//! growing server memory. Connection *count* is bounded only by the OS —
//! acceptable for a loopback transport; a non-loopback server needs a
//! connection cap or reader pool (ROADMAP).
//!
//! **Trust model.** The session token bounds *blind* spoofing: a local
//! process that merely knows the port can no longer forge a selected
//! client's upload (the pre-refactor hole). It does not bound an observer
//! — the token crosses the loopback in the clear, so a peer that can read
//! the traffic could replay it, and registration itself is first-come
//! within the (brief) `register_clients` window. Upgrading the credential
//! to a keyed MAC over the payload is the documented next step before any
//! non-loopback bind — tracked in ROADMAP.md.
//!
//! The payload bytes on the wire are exactly the bytes [`InProcess`]
//! would have carried, in both directions — the integration suite pins
//! the aggregate bitwise identical across all three transports.
//!
//! [`InProcess`]: crate::transport::link::InProcess

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::transport::codec::peek_client;
use crate::transport::frame::{write_frame, Frame, FrameKind, FrameStream, NO_TOKEN};
use crate::transport::link::{
    poll_channel, recv_deadline, DownlinkSource, Transport, TransportKind, UploadSink,
};
use crate::transport::session::{hello_payload, validate_upload, Session, SessionTable};
use crate::util::error::{Error, Result};

#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};

/// Where a [`Loopback`] server listens / where its clients connect.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireAddr {
    Tcp(SocketAddr),
    Uds(PathBuf),
}

impl std::fmt::Display for WireAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireAddr::Tcp(a) => write!(f, "tcp://{a}"),
            WireAddr::Uds(p) => write!(f, "uds://{}", p.display()),
        }
    }
}

/// How long a connecting client waits for the `welcome` reply.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Bound on queued-but-unconsumed uploads. Session threads block (and the
/// peer's writes stall — natural backpressure) once this many frames sit
/// undrained, so a framing-valid flood cannot grow server memory without
/// limit; per-frame size is separately capped by the frame layer.
const UPLOAD_QUEUE_SLOTS: usize = 64;

/// Uniquifier for unix socket paths within one process.
static UDS_COUNTER: AtomicU64 = AtomicU64::new(0);

/// One duplex byte stream, TCP or unix-domain.
#[derive(Debug)]
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn connect(addr: &WireAddr) -> Result<Stream> {
        match addr {
            WireAddr::Tcp(a) => Ok(Stream::Tcp(TcpStream::connect(a).map_err(|e| {
                Error::transport(format!("connect {addr}: {e}"))
            })?)),
            WireAddr::Uds(path) => {
                #[cfg(unix)]
                {
                    Ok(Stream::Unix(UnixStream::connect(path).map_err(|e| {
                        Error::transport(format!("connect {addr}: {e}"))
                    })?))
                }
                #[cfg(not(unix))]
                {
                    let _ = path;
                    Err(Error::transport(
                        "unix-domain sockets are unsupported on this platform",
                    ))
                }
            }
        }
    }

    fn try_clone(&self) -> Result<Stream> {
        match self {
            Stream::Tcp(s) => Ok(Stream::Tcp(
                s.try_clone().map_err(|e| Error::transport(format!("clone stream: {e}")))?,
            )),
            #[cfg(unix)]
            Stream::Unix(s) => Ok(Stream::Unix(
                s.try_clone().map_err(|e| Error::transport(format!("clone stream: {e}")))?,
            )),
        }
    }

    fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(timeout),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(timeout),
        }
        .map_err(|e| Error::transport(format!("set read timeout: {e}")))
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// The client half of one persistent duplex session: holds the socket and
/// the token the server issued at registration. One exists per registered
/// client for the lifetime of the run; a client job locks it to receive
/// the round's broadcast and again to push its upload — the same kernel
/// socket carries both directions.
pub struct ClientConn {
    client: u32,
    token: u64,
    io: Mutex<(Stream, FrameStream)>,
}

impl ClientConn {
    /// Connect and run the registration handshake: `hello(client)` out,
    /// `welcome(token)` back. Fails (typed) if the server refuses the
    /// registration — unregistered id, duplicate session — or the reply
    /// does not arrive within [`HANDSHAKE_TIMEOUT`].
    pub fn connect(addr: &WireAddr, client: u32) -> Result<ClientConn> {
        let mut stream = Stream::connect(addr)?;
        write_frame(&mut stream, FrameKind::Hello, NO_TOKEN, &hello_payload(client))?;
        stream.flush()?;
        stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
        let mut frames = FrameStream::new();
        let welcome = frames.next(&mut stream)?.ok_or_else(|| {
            Error::auth(format!(
                "server closed the connection instead of welcoming client {client} \
                 (registration refused?)"
            ))
        })?;
        if welcome.kind != FrameKind::Welcome {
            return Err(Error::auth(format!(
                "client {client} expected a welcome, got {:?}",
                welcome.kind
            )));
        }
        if welcome.token == NO_TOKEN {
            return Err(Error::auth(format!("server issued client {client} an empty token")));
        }
        Ok(ClientConn {
            client,
            token: welcome.token,
            io: Mutex::new((stream, frames)),
        })
    }

    /// The registered client id this session belongs to.
    pub fn client(&self) -> u32 {
        self.client
    }

    /// Ship one encoded update, stamped with the session token.
    pub fn upload(&self, payload: &[u8]) -> Result<()> {
        let mut io = self.io.lock().map_err(|_| Error::transport("client conn poisoned"))?;
        write_frame(&mut io.0, FrameKind::Upload, self.token, payload)?;
        io.0.flush()?;
        Ok(())
    }

    /// Block until the next `broadcast` frame addressed to this session
    /// arrives (at most `timeout`), and hand back its payload. A frame
    /// whose token is not this session's is a typed [`Error::Auth`].
    pub fn recv_broadcast(&self, timeout: Duration) -> Result<Vec<u8>> {
        let mut io = self.io.lock().map_err(|_| Error::transport("client conn poisoned"))?;
        io.0.set_read_timeout(Some(timeout))?;
        let (stream, frames) = &mut *io;
        let frame = frames.expect_next(stream)?;
        if frame.kind != FrameKind::Broadcast {
            return Err(Error::transport(format!(
                "client {} expected a broadcast, got {:?}",
                self.client, frame.kind
            )));
        }
        if frame.token != self.token {
            return Err(Error::auth(format!(
                "broadcast token does not match client {}'s session",
                self.client
            )));
        }
        Ok(frame.payload)
    }
}

/// Server-side record of one live session: the token it speaks under and
/// the write half of its socket (for downlink pushes).
struct Peer {
    token: u64,
    writer: Stream,
}

type Peers = Arc<Mutex<HashMap<u32, Peer>>>;

/// Run one accepted connection as a session: handshake, then verify and
/// forward uploads until disconnect. Every rejection path logs and drops
/// *this* connection only.
fn serve_conn(
    peer_name: &str,
    mut stream: Stream,
    sessions: &Arc<Mutex<SessionTable>>,
    peers: &Peers,
    tx: &SyncSender<Vec<u8>>,
) {
    let mut frames = FrameStream::new();
    // --- handshake (bounded: a peer that connects and stalls before
    // registering must not pin this thread forever) ---
    let _ = stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
    let hello = match frames.next(&mut stream) {
        Ok(Some(f)) => f,
        // A clean immediate close (e.g. the shutdown wake-up poke) is not
        // worth a log line.
        Ok(None) => return,
        Err(e) => {
            log::warn!("transport: dropping malformed peer {peer_name}: {e}");
            return;
        }
    };
    let session: Session = {
        let Ok(mut table) = sessions.lock() else { return };
        match table.handshake(&hello) {
            Ok(s) => s,
            Err(e) => {
                log::warn!("transport: refusing peer {peer_name}: {e}");
                return;
            }
        }
    };
    let cleanup = |sessions: &Arc<Mutex<SessionTable>>, peers: &Peers| {
        if let Ok(mut table) = sessions.lock() {
            table.end(session);
        }
        if let Ok(mut map) = peers.lock() {
            // only evict our own entry — a successor session may have
            // replaced it already
            if map.get(&session.client).map(|p| p.token) == Some(session.token) {
                map.remove(&session.client);
            }
        }
    };
    let writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            log::warn!("transport: peer {peer_name}: {e}");
            cleanup(sessions, peers);
            return;
        }
    };
    if let Ok(mut map) = peers.lock() {
        map.insert(session.client, Peer { token: session.token, writer });
    }
    // The peers entry must exist before the welcome goes out: the moment
    // the client reads it, registration returns and the server may push a
    // downlink.
    if let Err(e) = write_frame(&mut stream, FrameKind::Welcome, session.token, &[])
        .and_then(|_| stream.flush().map_err(Into::into))
    {
        log::warn!("transport: peer {peer_name}: welcome failed: {e}");
        cleanup(sessions, peers);
        return;
    }
    // --- session loop: verified uploads only. A registered session may
    // sit idle for many rounds (not every client is sampled every round),
    // so reads block without a timeout from here on; EOF is the
    // disconnect signal. ---
    let _ = stream.set_read_timeout(None);
    loop {
        match frames.next(&mut stream) {
            Ok(Some(frame)) => {
                if let Err(e) = validate_upload(&frame, session) {
                    log::warn!(
                        "transport: rejecting spoofed upload from peer {peer_name} \
                         (client {}): {e}",
                        session.client
                    );
                    break;
                }
                // Receiver gone = server shut down mid-drain; nothing to do.
                let _ = tx.send(frame.payload);
            }
            Ok(None) => break, // clean disconnect
            Err(e) => {
                log::warn!("transport: dropping malformed peer {peer_name}: {e}");
                break;
            }
        }
    }
    cleanup(sessions, peers);
}

/// Shared accept loop for both listener flavors: `accept` blocks for the
/// next connection or errors; each accepted stream gets its own session
/// thread. Exits once the shutdown flag is observed after a wake-up
/// connection (or an accept error).
fn spawn_accept_loop<A>(
    mut accept: A,
    sessions: Arc<Mutex<SessionTable>>,
    peers: Peers,
    tx: SyncSender<Vec<u8>>,
    shutdown: Arc<AtomicBool>,
) -> JoinHandle<()>
where
    A: FnMut() -> std::io::Result<(Stream, String)> + Send + 'static,
{
    std::thread::spawn(move || loop {
        match accept() {
            Ok((stream, peer)) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let sessions = Arc::clone(&sessions);
                let peers = Arc::clone(&peers);
                let tx = tx.clone();
                std::thread::spawn(move || serve_conn(&peer, stream, &sessions, &peers, &tx));
            }
            Err(e) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                log::warn!("transport: accept failed: {e}");
                // Persistent accept errors (e.g. fd exhaustion) must not
                // busy-spin the loop and flood the log.
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    })
}

/// Dedicated downlink writer: drains (client, payload) sends and writes
/// each as a `broadcast` frame on that client's session. A write that
/// blocks on a full kernel buffer stalls only this thread — the server's
/// round loop keeps draining uploads, which is what eventually frees the
/// blocked reader and the buffer (no deadlock by construction).
///
/// Failures here are logged, not returned: there is no caller to return
/// them to. The round still fails *fast*, client-side — a session this
/// thread cannot write to is one `serve_conn` has torn down, which closed
/// the socket, so the waiting client job's `recv_broadcast` sees EOF (a
/// typed error) immediately and the job error surfaces through the pool
/// within one drain poll tick.
fn spawn_downlink_writer(peers: Peers, rx: Receiver<(u32, Arc<Vec<u8>>)>) -> JoinHandle<()> {
    std::thread::spawn(move || {
        for (client, payload) in rx {
            let target = peers
                .lock()
                .ok()
                .and_then(|map| {
                    map.get(&client).map(|p| (p.writer.try_clone(), p.token))
                });
            match target {
                Some((Ok(mut writer), token)) => {
                    if let Err(e) = write_frame(&mut writer, FrameKind::Broadcast, token, &payload)
                        .and_then(|_| writer.flush().map_err(Into::into))
                    {
                        log::warn!("transport: downlink to client {client} failed: {e}");
                    }
                }
                Some((Err(e), _)) => {
                    log::warn!("transport: downlink to client {client} failed: {e}");
                }
                None => {
                    log::warn!("transport: downlink to client {client} with no live session");
                }
            }
        }
    })
}

/// Upload sink over the persistent sessions: routes each payload to its
/// client's connection by the claimed sender id (bytes the session layer
/// re-verifies server-side against the connection's token).
struct SocketSink {
    conns: Arc<Mutex<HashMap<u32, Arc<ClientConn>>>>,
}

impl UploadSink for SocketSink {
    fn send(&self, payload: Vec<u8>) -> Result<()> {
        let client = peek_client(&payload)
            .ok_or_else(|| Error::invalid("upload payload too short to name a client"))?;
        let conn = self
            .conns
            .lock()
            .map_err(|_| Error::transport("socket sink poisoned"))?
            .get(&client)
            .cloned()
            .ok_or_else(|| {
                Error::invalid(format!("client {client} has no registered session"))
            })?;
        conn.upload(&payload)
    }
}

/// Downlink handle over the persistent sessions: a client job blocks on
/// its own connection for the round's broadcast frame.
struct SocketDownlink {
    conns: Arc<Mutex<HashMap<u32, Arc<ClientConn>>>>,
}

impl DownlinkSource for SocketDownlink {
    fn recv(&self, client: u32, timeout: Duration) -> Result<Arc<Vec<u8>>> {
        let conn = self
            .conns
            .lock()
            .map_err(|_| Error::transport("socket downlink poisoned"))?
            .get(&client)
            .cloned()
            .ok_or_else(|| {
                Error::invalid(format!("client {client} has no registered session"))
            })?;
        // Bytes come off this client's own wire, so the Arc wraps a fresh
        // read — sharing happens transport-side only where it is real
        // (the in-process mailboxes).
        conn.recv_broadcast(timeout).map(Arc::new)
    }
}

/// Socket-backed [`Transport`]: framed TCP on 127.0.0.1 or a unix-domain
/// socket in the temp dir. Binding picks an ephemeral port / unique path;
/// [`Loopback::addr`] is what clients connect to.
pub struct Loopback {
    addr: WireAddr,
    rx: Receiver<Vec<u8>>,
    accept: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    timeout: Duration,
    kind_label: &'static str,
    sessions: Arc<Mutex<SessionTable>>,
    peers: Peers,
    /// Client halves of the persistent sessions, by client id.
    conns: Arc<Mutex<HashMap<u32, Arc<ClientConn>>>>,
    dl_tx: Option<Sender<(u32, Arc<Vec<u8>>)>>,
    dl_writer: Option<JoinHandle<()>>,
}

impl Loopback {
    /// Bind the requested socket flavor. `TransportKind::InProcess` is not
    /// a socket and is rejected.
    pub fn bind(kind: TransportKind) -> Result<Loopback> {
        match kind {
            TransportKind::Tcp => Loopback::bind_tcp(),
            TransportKind::Uds => Loopback::bind_uds(),
            TransportKind::InProcess => Err(Error::invalid(
                "in-process transport has no socket to bind",
            )),
        }
    }

    /// Shared tail of both bind flavors: queues, session table, accept and
    /// downlink-writer threads, struct assembly.
    fn from_accept<A>(accept: A, addr: WireAddr, kind_label: &'static str) -> Loopback
    where
        A: FnMut() -> std::io::Result<(Stream, String)> + Send + 'static,
    {
        let (tx, rx) = sync_channel(UPLOAD_QUEUE_SLOTS);
        let shutdown = Arc::new(AtomicBool::new(false));
        let sessions = Arc::new(Mutex::new(SessionTable::new()));
        let peers: Peers = Arc::new(Mutex::new(HashMap::new()));
        let accept = spawn_accept_loop(
            accept,
            Arc::clone(&sessions),
            Arc::clone(&peers),
            tx,
            Arc::clone(&shutdown),
        );
        let (dl_tx, dl_rx) = channel();
        let dl_writer = spawn_downlink_writer(Arc::clone(&peers), dl_rx);
        Loopback {
            addr,
            rx,
            accept: Some(accept),
            shutdown,
            timeout: crate::transport::link::DEFAULT_UPLOAD_TIMEOUT,
            kind_label,
            sessions,
            peers,
            conns: Arc::new(Mutex::new(HashMap::new())),
            dl_tx: Some(dl_tx),
            dl_writer: Some(dl_writer),
        }
    }

    /// Framed TCP on an ephemeral 127.0.0.1 port.
    pub fn bind_tcp() -> Result<Loopback> {
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| Error::transport(format!("bind tcp listener: {e}")))?;
        let addr = WireAddr::Tcp(
            listener
                .local_addr()
                .map_err(|e| Error::transport(format!("tcp local addr: {e}")))?,
        );
        Ok(Loopback::from_accept(
            move || {
                let (stream, peer) = listener.accept()?;
                Ok((Stream::Tcp(stream), peer.to_string()))
            },
            addr,
            "tcp",
        ))
    }

    /// Framed unix-domain socket on a unique temp path.
    pub fn bind_uds() -> Result<Loopback> {
        #[cfg(unix)]
        {
            let path = std::env::temp_dir().join(format!(
                "fedmask-{}-{}.sock",
                std::process::id(),
                UDS_COUNTER.fetch_add(1, Ordering::SeqCst)
            ));
            let _ = std::fs::remove_file(&path);
            let listener = UnixListener::bind(&path)
                .map_err(|e| Error::transport(format!("bind uds {}: {e}", path.display())))?;
            Ok(Loopback::from_accept(
                move || {
                    let (stream, _) = listener.accept()?;
                    Ok((Stream::Unix(stream), "uds-peer".to_string()))
                },
                WireAddr::Uds(path),
                "uds",
            ))
        }
        #[cfg(not(unix))]
        {
            Err(Error::transport(
                "unix-domain sockets are unsupported on this platform",
            ))
        }
    }

    /// Where clients connect.
    pub fn addr(&self) -> &WireAddr {
        &self.addr
    }

    /// Override the receive timeout (tests use short ones).
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// A registered client's persistent session, if any — test and bench
    /// access to the raw connection (e.g. to measure per-upload cost or
    /// craft a cross-client spoof attempt against the server's verifier).
    pub fn client_conn(&self, client: u32) -> Option<Arc<ClientConn>> {
        self.conns.lock().ok()?.get(&client).cloned()
    }

    /// Open the registration window for `clients` **without** opening
    /// their connections — for tests and benches that drive raw
    /// [`ClientConn`]s (e.g. the session-per-upload fan-in measurement).
    /// Production callers use [`Transport::register_clients`], which both
    /// allows and connects.
    pub fn allow_clients(&self, clients: &[u32]) -> Result<()> {
        self.sessions
            .lock()
            .map_err(|_| Error::transport("session table poisoned"))?
            .allow(clients);
        Ok(())
    }
}

impl Transport for Loopback {
    fn label(&self) -> &'static str {
        self.kind_label
    }

    fn accepts_foreign_peers(&self) -> bool {
        // An open local endpoint: any process that can connect can frame a
        // payload (sessions bound who can *upload*, not who can connect),
        // so an invalid payload that somehow clears the session layer is
        // dropped as noise, not treated as an internal bug.
        true
    }

    fn register_clients(&mut self, clients: &[u32]) -> Result<()> {
        self.sessions
            .lock()
            .map_err(|_| Error::transport("session table poisoned"))?
            .allow(clients);
        let mut conns = self
            .conns
            .lock()
            .map_err(|_| Error::transport("socket conns poisoned"))?;
        for &c in clients {
            if conns.contains_key(&c) {
                continue;
            }
            conns.insert(c, Arc::new(ClientConn::connect(&self.addr, c)?));
        }
        Ok(())
    }

    fn sink(&self) -> Arc<dyn UploadSink> {
        Arc::new(SocketSink {
            conns: Arc::clone(&self.conns),
        })
    }

    fn send_downlink(&mut self, client: u32, payload: Arc<Vec<u8>>) -> Result<()> {
        if !self
            .conns
            .lock()
            .map_err(|_| Error::transport("socket conns poisoned"))?
            .contains_key(&client)
        {
            return Err(Error::invalid(format!(
                "downlink to client {client}, which was never registered"
            )));
        }
        self.dl_tx
            .as_ref()
            .expect("downlink writer alive while the transport is")
            .send((client, payload))
            .map_err(|_| Error::transport("downlink writer gone"))
    }

    fn downlink(&self) -> Arc<dyn DownlinkSource> {
        Arc::new(SocketDownlink {
            conns: Arc::clone(&self.conns),
        })
    }

    fn begin_round(&mut self, _expected: usize) {}

    fn recv(&mut self) -> Result<Vec<u8>> {
        recv_deadline(&self.rx, self.timeout)
    }

    fn try_recv_for(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        poll_channel(&self.rx, timeout)
    }
}

/// Poke a listening address with a throwaway connection so a blocked
/// `accept` observes the shutdown flag. Returns whether the poke landed.
fn wake_listener(addr: &WireAddr) -> bool {
    match addr {
        WireAddr::Tcp(a) => TcpStream::connect_timeout(a, Duration::from_millis(200)).is_ok(),
        #[cfg(unix)]
        WireAddr::Uds(path) => UnixStream::connect(path).is_ok(),
        #[cfg(not(unix))]
        WireAddr::Uds(_) => false,
    }
}

impl Drop for Loopback {
    fn drop(&mut self) {
        // 1) Close the client halves first: session threads observe EOF
        //    and exit, and any downlink write blocked on a dead client's
        //    full buffer fails instead of hanging.
        if let Ok(mut conns) = self.conns.lock() {
            conns.clear();
        }
        // 2) Retire the downlink writer (its channel closes when the
        //    sender drops).
        drop(self.dl_tx.take());
        if let Some(h) = self.dl_writer.take() {
            let _ = h.join();
        }
        // 3) Stop accepting. Only join the accept loop when the wake-up
        //    connection landed — otherwise accept may never return and the
        //    join would hang; the flagged thread is left to die with the
        //    process instead.
        self.shutdown.store(true, Ordering::SeqCst);
        if wake_listener(&self.addr) {
            if let Some(h) = self.accept.take() {
                let _ = h.join();
            }
        }
        if let WireAddr::Uds(path) = &self.addr {
            let _ = std::fs::remove_file(path);
        }
    }
}
