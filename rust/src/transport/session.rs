//! Per-client session authentication for the socket wire.
//!
//! Before this layer the loopback listener was an anonymous drop box: any
//! local process could frame a well-formed upload naming a selected
//! client, and the server could not tell it from the genuine article. A
//! session fixes the *identity* half of the trust model:
//!
//! 1. **Registration window** — the server [`SessionTable::allow`]s the
//!    run's client ids before any connection is made.
//! 2. **Handshake** — each client opens one persistent duplex connection
//!    and sends a `hello` frame carrying its client id;
//!    [`SessionTable::handshake`] verifies the id is registered and not
//!    already active, mints a random non-zero `u64` token, and the server
//!    replies `welcome` with the token in the frame header.
//! 3. **Uploads** — every subsequent `upload` frame must carry the
//!    session token, and the payload's *claimed* client id (peeked at a
//!    fixed header offset, no codec decode) must equal the session's —
//!    [`validate_upload`] runs both checks **before any payload decode**
//!    and returns a typed [`Error::Auth`] on failure, so a spoofed upload
//!    is rejected at the connection instead of reaching the aggregator.
//!
//! What this deliberately does *not* provide: the token crosses the wire
//! in the clear, so a peer that can observe loopback traffic (or a MITM
//! on a future non-loopback bind) can replay it. The tokens bound
//! *blind* spoofing — the pre-refactor hole — and pin the protocol shape
//! (registration, per-frame credential, verify-before-decode); upgrading
//! the credential to a keyed MAC over the payload is the documented next
//! step before any non-loopback bind (ROADMAP).

use std::collections::HashMap;
use std::hash::{BuildHasher, Hasher};
use std::sync::Mutex;

use crate::transport::codec::peek_client;
use crate::transport::frame::{Frame, FrameKind, NO_TOKEN};
use crate::util::error::{Error, Result};

/// Which of `shards` slots owns `client` — a Fibonacci multiplicative hash
/// of the id, not `id % shards`, so the common sequentially-numbered fleet
/// spreads across shards even when `shards` divides the id stride. The
/// same function routes session lookups, peer-writer lookups, and
/// tree-aggregation payloads, so one client's state always lives in one
/// shard everywhere. Deterministic by construction: shard *assignment*
/// may never affect results (the merge property tests pin that), but a
/// stable mapping keeps logs and tests reproducible.
pub fn shard_of(client: u32, shards: usize) -> usize {
    debug_assert!(shards > 0, "shard count must be positive");
    ((client as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15) >> 33) as usize % shards.max(1)
}

/// Mints per-session tokens: random non-zero u64s seeded from OS process
/// entropy (`RandomState`), never from the experiment seed — tokens must
/// not be predictable from a config file, and they carry no effect on
/// experiment results (payload bytes and the ledger never see them), so
/// run determinism is preserved.
#[derive(Debug, Default)]
pub struct TokenMint {
    counter: u64,
}

impl TokenMint {
    pub fn new() -> TokenMint {
        TokenMint::default()
    }

    /// Next token: never [`NO_TOKEN`], vanishingly unlikely to collide.
    pub fn issue(&mut self) -> u64 {
        loop {
            self.counter = self.counter.wrapping_add(1);
            let mut h = std::collections::hash_map::RandomState::new().build_hasher();
            h.write_u64(self.counter);
            let token = h.finish();
            if token != NO_TOKEN {
                return token;
            }
        }
    }
}

/// One authenticated connection: which client it is, under which token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Session {
    pub client: u32,
    pub token: u64,
}

/// Cross-round index cache: the previous round's **accepted** top-k index
/// set for one client session, as both ends remember it. The codec's
/// `SparseCached` arm (WIRE.md §3b) encodes only the set-delta against
/// `indices`, keyed by `epoch` — the epoch is echoed in the payload and a
/// mismatch is a typed parse error, so a desynced cache can never decode
/// to the wrong index set, only to a rejection.
///
/// Lifecycle (owned by the round driver, mirrored to the client at
/// broadcast): the epoch advances only when a round's upload was accepted
/// into the fold; any drop, disconnect, duplicate rejection, or round
/// skip leaves the client without a cache next round, forcing a full
/// (stateless) index send. The cache is immutable once built and shared
/// by `Arc`, so a rejected decode cannot partially mutate it even in
/// principle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexCache {
    /// Cache generation, 1-based; echoed verbatim in `SparseCached`
    /// payloads and matched exactly on decode.
    pub epoch: u32,
    /// The cached index set, strictly increasing.
    pub indices: Vec<u32>,
}

impl IndexCache {
    /// A first-generation cache over `indices` (must be strictly
    /// increasing — callers hand in decoded sparse supports, which are).
    pub fn first(indices: Vec<u32>) -> IndexCache {
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
        IndexCache { epoch: 1, indices }
    }

    /// The successor cache: next epoch, new accepted index set.
    pub fn advance(&self, indices: Vec<u32>) -> IndexCache {
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
        IndexCache { epoch: self.epoch.wrapping_add(1).max(1), indices }
    }
}

/// The server's registry of allowed clients and live sessions. Shared
/// behind a mutex by the accept-loop's per-connection threads.
#[derive(Debug, Default)]
pub struct SessionTable {
    /// Ids registered for this run; hellos naming anyone else are refused.
    allowed: Vec<u32>,
    /// client id -> live session token.
    active: HashMap<u32, u64>,
    mint: TokenMint,
}

impl SessionTable {
    pub fn new() -> SessionTable {
        SessionTable::default()
    }

    /// Open the registration window for `clients` (sorted, deduped).
    pub fn allow(&mut self, clients: &[u32]) {
        self.allowed.extend_from_slice(clients);
        self.allowed.sort_unstable();
        self.allowed.dedup();
    }

    /// Registered client ids, sorted.
    pub fn registered(&self) -> &[u32] {
        &self.allowed
    }

    /// Validate a `hello` frame and open a session. Rejections (all typed
    /// [`Error::Auth`]): non-hello kind, a non-zero token (there is no
    /// session to present yet), a malformed id payload, an unregistered
    /// id, or an id whose session is already active (first-come holds the
    /// session; a later claimant is a spoofer or a bug).
    pub fn handshake(&mut self, frame: &Frame) -> Result<Session> {
        if frame.kind != FrameKind::Hello {
            return Err(Error::auth(format!(
                "expected a hello frame to open a session, got {:?}",
                frame.kind
            )));
        }
        if frame.token != NO_TOKEN {
            return Err(Error::auth("hello carries a token but no session exists yet"));
        }
        let id: [u8; 4] = frame
            .payload
            .as_slice()
            .try_into()
            .map_err(|_| Error::auth("hello payload must be exactly a 4-byte client id"))?;
        let client = u32::from_le_bytes(id);
        if self.allowed.binary_search(&client).is_err() {
            return Err(Error::auth(format!("client {client} is not registered for this run")));
        }
        if self.active.contains_key(&client) {
            return Err(Error::auth(format!("client {client} already holds a live session")));
        }
        let token = self.mint.issue();
        self.active.insert(client, token);
        Ok(Session { client, token })
    }

    /// Close a session — but only if `session` still owns it (a stale
    /// closer must not evict a successor's session).
    pub fn end(&mut self, session: Session) {
        if self.active.get(&session.client) == Some(&session.token) {
            self.active.remove(&session.client);
        }
    }

    /// Token of a live session, if any (tests / the downlink writer).
    pub fn token_of(&self, client: u32) -> Option<u64> {
        self.active.get(&client).copied()
    }
}

/// [`SessionTable`] sharded by client-id hash: `N` independent locks, so
/// the reactor thread, the downlink writer, and registration calls only
/// contend when they touch the *same* shard. Each shard is a complete
/// `SessionTable`; a client's whole lifecycle (allow → handshake → end)
/// stays inside [`shard_of`]`(client)`'s shard.
///
/// Shared-state synchronization note: every method takes `&self` and locks
/// exactly one shard, so no lock ordering exists to get wrong. A poisoned
/// shard (a panic while holding the lock) is returned as a typed error
/// rather than unwound into the caller.
#[derive(Debug)]
pub struct SessionShards {
    shards: Vec<Mutex<SessionTable>>,
}

impl SessionShards {
    /// `n` independent shards (clamped to at least 1).
    pub fn new(n: usize) -> SessionShards {
        SessionShards {
            shards: (0..n.max(1)).map(|_| Mutex::new(SessionTable::new())).collect(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn shard(&self, client: u32) -> Result<std::sync::MutexGuard<'_, SessionTable>> {
        self.shards[shard_of(client, self.shards.len())]
            .lock()
            .map_err(|_| Error::transport("session shard poisoned"))
    }

    /// Open the registration window for `clients`, each in its own shard.
    pub fn allow(&self, clients: &[u32]) -> Result<()> {
        for &c in clients {
            self.shard(c)?.allow(&[c]);
        }
        Ok(())
    }

    /// Route a hello to its client's shard and run the handshake there.
    /// A hello too malformed to even name a client falls to shard 0, whose
    /// `SessionTable` produces the same typed rejection a flat table would.
    pub fn handshake(&self, frame: &Frame) -> Result<Session> {
        let client = frame
            .payload
            .as_slice()
            .try_into()
            .map(u32::from_le_bytes)
            .unwrap_or(0);
        self.shard(client)?.handshake(frame)
    }

    /// Close `session` in its owner's shard (owner-checked, like
    /// [`SessionTable::end`]).
    pub fn end(&self, session: Session) -> Result<()> {
        self.shard(session.client)?.end(session);
        Ok(())
    }

    /// Token of a live session, if any.
    pub fn token_of(&self, client: u32) -> Result<Option<u64>> {
        Ok(self.shard(client)?.token_of(client))
    }

    /// Total registered ids across all shards.
    pub fn registered_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().map(|t| t.registered().len()).unwrap_or(0))
            .sum()
    }
}

/// The hello payload for `client` (the 4-byte LE id).
pub fn hello_payload(client: u32) -> Vec<u8> {
    client.to_le_bytes().to_vec()
}

/// Verify one `upload` frame against its connection's session, **before
/// any codec decode**: the frame kind, the session token, and the
/// payload's claimed client id (peeked at a fixed offset) must all line
/// up. Returns a typed [`Error::Auth`] naming the first mismatch.
pub fn validate_upload(frame: &Frame, session: Session) -> Result<()> {
    if frame.kind != FrameKind::Upload {
        return Err(Error::auth(format!(
            "client {}'s session may only send uploads, got {:?}",
            session.client, frame.kind
        )));
    }
    if frame.token == NO_TOKEN {
        return Err(Error::auth(format!(
            "upload for client {} carries no session token",
            session.client
        )));
    }
    if frame.token != session.token {
        return Err(Error::auth(format!(
            "upload token does not match client {}'s session",
            session.client
        )));
    }
    match peek_client(&frame.payload) {
        None => Err(Error::auth("upload payload too short to name a client")),
        Some(claimed) if claimed != session.client => Err(Error::auth(format!(
            "upload claims client {claimed} but the session belongs to client {}",
            session.client
        ))),
        Some(_) => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::codec::{encode_update, Encoding};

    fn hello(client: u32) -> Frame {
        Frame {
            kind: FrameKind::Hello,
            token: NO_TOKEN,
            payload: hello_payload(client),
        }
    }

    fn upload(client: u32, token: u64) -> Frame {
        Frame {
            kind: FrameKind::Upload,
            token,
            payload: encode_update(client, 1, 10, &[1.0, 0.0, 2.0], Encoding::Auto),
        }
    }

    #[test]
    fn handshake_issues_distinct_nonzero_tokens() {
        let mut table = SessionTable::new();
        table.allow(&[0, 1, 2]);
        let a = table.handshake(&hello(0)).unwrap();
        let b = table.handshake(&hello(1)).unwrap();
        assert_ne!(a.token, NO_TOKEN);
        assert_ne!(b.token, NO_TOKEN);
        assert_ne!(a.token, b.token);
        assert_eq!(table.token_of(0), Some(a.token));
        assert_eq!(table.registered(), &[0, 1, 2]);
    }

    #[test]
    fn unregistered_and_duplicate_hellos_are_auth_errors() {
        let mut table = SessionTable::new();
        table.allow(&[3, 4]);
        let err = table.handshake(&hello(99)).unwrap_err();
        assert!(matches!(err, Error::Auth(_)), "{err}");
        assert!(err.to_string().contains("not registered"), "{err}");

        table.handshake(&hello(3)).unwrap();
        let err = table.handshake(&hello(3)).unwrap_err();
        assert!(matches!(err, Error::Auth(_)), "{err}");
        assert!(err.to_string().contains("already holds"), "{err}");
    }

    #[test]
    fn malformed_hellos_are_auth_errors() {
        let mut table = SessionTable::new();
        table.allow(&[1]);
        // wrong kind
        let err = table.handshake(&upload(1, 5)).unwrap_err();
        assert!(matches!(err, Error::Auth(_)), "{err}");
        // premature token
        let mut f = hello(1);
        f.token = 7;
        assert!(table.handshake(&f).is_err());
        // short payload
        let mut f = hello(1);
        f.payload = vec![1, 2];
        assert!(table.handshake(&f).is_err());
    }

    #[test]
    fn ending_a_session_frees_the_id_but_only_for_its_owner() {
        let mut table = SessionTable::new();
        table.allow(&[8]);
        let first = table.handshake(&hello(8)).unwrap();
        table.end(first);
        let second = table.handshake(&hello(8)).unwrap();
        // a stale end (the first session's credentials) must not evict
        // the live successor
        table.end(first);
        assert_eq!(table.token_of(8), Some(second.token));
        table.end(second);
        assert_eq!(table.token_of(8), None);
    }

    #[test]
    fn shard_of_is_stable_in_range_and_spreads_sequential_ids() {
        for shards in [1usize, 2, 8, 13] {
            let mut hit = vec![false; shards];
            for c in 0..256u32 {
                let s = shard_of(c, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(c, shards), "must be deterministic");
                hit[s] = true;
            }
            assert!(hit.iter().all(|&h| h), "sequential ids must reach every one of {shards} shards");
        }
    }

    #[test]
    fn sharded_sessions_behave_like_one_table() {
        let shards = SessionShards::new(4);
        assert_eq!(shards.shard_count(), 4);
        let ids: Vec<u32> = (0..16).collect();
        shards.allow(&ids).unwrap();
        assert_eq!(shards.registered_count(), 16);
        // handshakes route to their shard and mint distinct tokens
        let a = shards.handshake(&hello(3)).unwrap();
        let b = shards.handshake(&hello(7)).unwrap();
        assert_ne!(a.token, NO_TOKEN);
        assert_ne!(a.token, b.token);
        assert_eq!(shards.token_of(3).unwrap(), Some(a.token));
        // the duplicate-hello and unregistered rejections survive sharding
        assert!(shards.handshake(&hello(3)).is_err());
        assert!(shards.handshake(&hello(99)).is_err());
        // a malformed hello (no parseable id) is the same typed rejection
        let mut bad = hello(3);
        bad.payload = vec![1, 2];
        let err = shards.handshake(&bad).unwrap_err();
        assert!(matches!(err, Error::Auth(_)), "{err}");
        // end is owner-checked per shard
        shards.end(a).unwrap();
        assert_eq!(shards.token_of(3).unwrap(), None);
        let again = shards.handshake(&hello(3)).unwrap();
        shards.end(a).unwrap(); // stale closer: must not evict the successor
        assert_eq!(shards.token_of(3).unwrap(), Some(again.token));
    }

    #[test]
    fn validate_upload_accepts_the_genuine_article() {
        let session = Session { client: 5, token: 0xfeed };
        validate_upload(&upload(5, 0xfeed), session).unwrap();
    }

    #[test]
    fn missing_wrong_and_cross_client_tokens_are_rejected_before_decode() {
        let session = Session { client: 5, token: 0xfeed };
        // missing token
        let err = validate_upload(&upload(5, NO_TOKEN), session).unwrap_err();
        assert!(matches!(err, Error::Auth(_)), "{err}");
        assert!(err.to_string().contains("no session token"), "{err}");
        // wrong token
        let err = validate_upload(&upload(5, 0xbad), session).unwrap_err();
        assert!(matches!(err, Error::Auth(_)), "{err}");
        // valid token, payload claims another client
        let err = validate_upload(&upload(3, 0xfeed), session).unwrap_err();
        assert!(matches!(err, Error::Auth(_)), "{err}");
        assert!(err.to_string().contains("claims client 3"), "{err}");
        // payload too short to even carry the claimed id — note the
        // payload here is NOT codec-decoded at any point
        let f = Frame {
            kind: FrameKind::Upload,
            token: 0xfeed,
            payload: vec![1, 2, 3],
        };
        let err = validate_upload(&f, session).unwrap_err();
        assert!(matches!(err, Error::Auth(_)), "{err}");
        // non-upload kinds cannot ride an upload session
        let f = Frame {
            kind: FrameKind::Broadcast,
            token: 0xfeed,
            payload: encode_update(5, 1, 10, &[1.0], Encoding::Dense),
        };
        assert!(validate_upload(&f, session).is_err());
    }
}
