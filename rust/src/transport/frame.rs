//! Length-prefixed frame layer for the socket transport.
//!
//! The codec ([`crate::transport::codec`]) defines *what* an update looks
//! like; a stream socket only hands back byte runs of arbitrary length, so
//! this module defines *where one message ends and the next begins*. One
//! frame carries one opaque payload (for us: one encoded
//! [`crate::transport::codec::WireUpdate`]).
//!
//! ## Wire format (all integers little-endian)
//!
//! ```text
//! offset  size  field
//! 0       2     magic   0x4c46 ("FL")
//! 2       1     version 1
//! 3       1     reserved, must be 0 (future flags; nonzero is rejected)
//! 4       4     payload length in bytes (u32)
//! 8       len   payload
//! ```
//!
//! Versioning rules: the header layout through the length field is frozen
//! for all versions; an incompatible payload change bumps `version` and old
//! readers reject it with a typed error. The reserved byte must be written
//! as zero and is rejected when nonzero, so it can become a flags field
//! later without silently misreading old peers.
//!
//! A declared length above the hard cap ([`MAX_FRAME_BYTES`], or the custom
//! cap of [`FrameReader::with_cap`]) is rejected **before any allocation**:
//! a malicious 4 GiB length header costs the server nothing.
//!
//! ## Incremental reading
//!
//! [`FrameReader`] is a push-style state machine: feed it whatever chunk
//! the socket produced — a single byte, half a header, three frames at
//! once — and it hands back completed payloads without ever over-consuming
//! into the next frame. [`pump_frames`] wraps it around any [`Read`] and is
//! what the socket server's per-connection threads run; a connection that
//! closes mid-frame is a typed truncation error, while EOF on a frame
//! boundary is a clean end of stream.

use std::io::{Read, Write};

use crate::util::error::{Error, Result};

/// Frame magic: "FL" as a little-endian u16 (bytes `46 4c` on the wire).
pub const FRAME_MAGIC: u16 = 0x4c46;

/// Current frame version.
pub const FRAME_VERSION: u8 = 1;

/// Fixed frame header size: magic(2) version(1) reserved(1) length(4).
pub const FRAME_HEADER_BYTES: usize = 8;

/// Hard cap on a frame payload (64 MiB). Our largest real message is a
/// dense f32 model (a few MB); anything near the cap is a malformed or
/// hostile peer, and the reader rejects the declared length before
/// allocating a byte for the body.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Incremental frame decoder tolerant of arbitrarily short reads.
///
/// `feed` consumes bytes from the caller's chunk and returns how many it
/// used plus a completed payload when one finishes. It never consumes past
/// the end of a frame, so pipelined frames in one chunk survive: call it in
/// a loop, advancing by the consumed count.
#[derive(Debug)]
pub struct FrameReader {
    max_len: usize,
    /// Partial header bytes accumulated so far (valid up to `have`).
    header: [u8; FRAME_HEADER_BYTES],
    have: usize,
    /// Body length once the header parsed; `None` while reading the header.
    need: Option<usize>,
    body: Vec<u8>,
}

impl Default for FrameReader {
    fn default() -> Self {
        FrameReader::new()
    }
}

impl FrameReader {
    /// Reader with the standard [`MAX_FRAME_BYTES`] cap.
    pub fn new() -> FrameReader {
        FrameReader::with_cap(MAX_FRAME_BYTES)
    }

    /// Reader with a custom payload cap (tests use tiny caps to exercise
    /// the rejection path cheaply).
    pub fn with_cap(max_len: usize) -> FrameReader {
        FrameReader {
            max_len,
            header: [0u8; FRAME_HEADER_BYTES],
            have: 0,
            need: None,
            body: Vec::new(),
        }
    }

    /// True while a frame is partially read — a disconnect now is a
    /// truncation, not a clean end of stream.
    pub fn mid_frame(&self) -> bool {
        self.have > 0 || self.need.is_some()
    }

    /// Consume bytes from `chunk`. Returns `(consumed, Some(payload))` when
    /// a frame completes, `(consumed, None)` when more input is needed.
    /// After a completed frame the reader is reset and ready for the next
    /// header; unconsumed chunk bytes belong to the caller.
    pub fn feed(&mut self, chunk: &[u8]) -> Result<(usize, Option<Vec<u8>>)> {
        let mut used = 0usize;
        if self.need.is_none() {
            let take = (FRAME_HEADER_BYTES - self.have).min(chunk.len());
            self.header[self.have..self.have + take].copy_from_slice(&chunk[..take]);
            self.have += take;
            used += take;
            if self.have < FRAME_HEADER_BYTES {
                return Ok((used, None));
            }
            let magic = u16::from_le_bytes([self.header[0], self.header[1]]);
            if magic != FRAME_MAGIC {
                return Err(Error::transport(format!("frame: bad magic {magic:#06x}")));
            }
            let version = self.header[2];
            if version != FRAME_VERSION {
                return Err(Error::transport(format!(
                    "frame: unsupported version {version} (expected {FRAME_VERSION})"
                )));
            }
            if self.header[3] != 0 {
                return Err(Error::transport(format!(
                    "frame: nonzero reserved byte {:#04x}",
                    self.header[3]
                )));
            }
            let len = u32::from_le_bytes(self.header[4..8].try_into().unwrap()) as usize;
            if len > self.max_len {
                return Err(Error::transport(format!(
                    "frame: declared length {len} exceeds cap {}",
                    self.max_len
                )));
            }
            // Safe to reserve: len is bounded by the cap.
            self.need = Some(len);
            self.body.clear();
            self.body.reserve(len);
        }
        let need = self.need.expect("header parsed");
        let take = (need - self.body.len()).min(chunk.len() - used);
        self.body.extend_from_slice(&chunk[used..used + take]);
        used += take;
        if self.body.len() == need {
            self.need = None;
            self.have = 0;
            return Ok((used, Some(std::mem::take(&mut self.body))));
        }
        Ok((used, None))
    }
}

/// Write one frame (header + payload) to `w`. Fails without writing when
/// the payload exceeds [`MAX_FRAME_BYTES`].
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME_BYTES {
        return Err(Error::transport(format!(
            "frame: payload {} exceeds cap {MAX_FRAME_BYTES}",
            payload.len()
        )));
    }
    let mut header = [0u8; FRAME_HEADER_BYTES];
    header[..2].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    header[2] = FRAME_VERSION;
    header[3] = 0;
    header[4..8].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    Ok(())
}

/// One frame as an owned byte vector (tests and in-memory paths).
pub fn frame_bytes(payload: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + payload.len());
    write_frame(&mut out, payload)?;
    Ok(out)
}

/// Drain `r` frame by frame, handing each completed payload to `deliver`,
/// until EOF. Tolerates arbitrarily short reads and multiple frames per
/// read. EOF on a frame boundary returns `Ok(())`; EOF mid-frame is a
/// typed truncation error; a malformed header aborts immediately.
pub fn pump_frames<R: Read>(r: &mut R, mut deliver: impl FnMut(Vec<u8>)) -> Result<()> {
    let mut reader = FrameReader::new();
    let mut buf = [0u8; 16 * 1024];
    loop {
        let n = match r.read(&mut buf) {
            Ok(n) => n,
            // EINTR (a signal landed mid-read) is not a peer failure:
            // retry instead of dropping a healthy connection.
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        };
        if n == 0 {
            return if reader.mid_frame() {
                Err(Error::transport("frame: connection closed mid-frame"))
            } else {
                Ok(())
            };
        }
        let mut at = 0usize;
        while at < n {
            let (used, frame) = reader.feed(&buf[at..n])?;
            at += used;
            if let Some(payload) = frame {
                deliver(payload);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::codec::{decode_update, encode_update, Encoding};
    use crate::util::prop::{check, Gen};

    /// Read adapter yielding at most `chunk` bytes per read (short-read
    /// torture for `pump_frames`).
    struct ShortReader<'a> {
        data: &'a [u8],
        at: usize,
        chunk: usize,
    }

    impl<'a> Read for ShortReader<'a> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = self.chunk.min(buf.len()).min(self.data.len() - self.at);
            buf[..n].copy_from_slice(&self.data[self.at..self.at + n]);
            self.at += n;
            Ok(n)
        }
    }

    fn masked_params(g: &mut Gen, p: usize, density: f32) -> Vec<f32> {
        (0..p)
            .map(|_| {
                if g.f32_in(0.0, 1.0) < density {
                    g.f32_in(-2.0, 2.0)
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Decode a whole stream via FrameReader fed in `splits`-sized pieces.
    fn feed_in_pieces(stream: &[u8], piece: usize) -> Result<Vec<Vec<u8>>> {
        let mut reader = FrameReader::new();
        let mut out = Vec::new();
        for chunk in stream.chunks(piece.max(1)) {
            let mut at = 0;
            while at < chunk.len() {
                let (used, frame) = reader.feed(&chunk[at..])?;
                at += used;
                if let Some(f) = frame {
                    out.push(f);
                }
            }
        }
        if reader.mid_frame() {
            return Err(Error::transport("frame: stream ended mid-frame"));
        }
        Ok(out)
    }

    #[test]
    fn roundtrip_split_at_every_byte_boundary() {
        // Every codec encoding, including empty and all-zero payloads; the
        // framed stream is split at every possible byte boundary and the
        // recovered payload must be bitwise identical to the direct codec
        // path (satellite: header splits covered because the boundary sweep
        // includes offsets 0..=8).
        let mut g = Gen::new(0xf4a3e);
        let cases: Vec<Vec<f32>> = vec![
            vec![],                       // empty model (p = 0)
            vec![0.0; 57],                // all-zero upload
            masked_params(&mut g, 64, 0.2),
            masked_params(&mut g, 33, 1.0),
        ];
        for params in &cases {
            for &enc in Encoding::ALL {
                let payload = encode_update(7, 3, 11, params, enc);
                let framed = frame_bytes(&payload).unwrap();
                for split in 0..=framed.len() {
                    let mut reader = FrameReader::new();
                    let mut got = None;
                    for part in [&framed[..split], &framed[split..]] {
                        let mut at = 0;
                        while at < part.len() {
                            let (used, frame) = reader.feed(&part[at..]).unwrap();
                            at += used;
                            if let Some(f) = frame {
                                got = Some(f);
                            }
                        }
                    }
                    let got = got.unwrap_or_else(|| panic!("no frame at split {split}"));
                    assert_eq!(&got, &payload, "enc {enc:?} split {split}");
                    // decoded update identical to the direct codec path
                    assert_eq!(decode_update(&got).unwrap(), decode_update(&payload).unwrap());
                }
            }
        }
    }

    #[test]
    fn prop_roundtrip_random_piece_sizes() {
        check("frame roundtrip, random splits", 60, |g| {
            let k = g.usize_in(1, 5);
            let payloads: Vec<Vec<u8>> = (0..k)
                .map(|c| {
                    let p = g.usize_in(0, 300);
                    let density = g.f32_in(0.0, 1.0);
                    let params = masked_params(g, p, density);
                    let enc = *g.choose(Encoding::ALL);
                    encode_update(c as u32, 1, 2, &params, enc)
                })
                .collect();
            let mut stream = Vec::new();
            for p in &payloads {
                write_frame(&mut stream, p).unwrap();
            }
            // random body offsets: pieces of random size, incl. size 1
            let piece = g.usize_in(1, stream.len().max(1));
            let got = feed_in_pieces(&stream, piece).unwrap();
            assert_eq!(got, payloads, "piece {piece} seed {:#x}", g.seed);
            // and the byte-at-a-time pump over a Read
            let mut r = ShortReader { data: &stream, at: 0, chunk: 1 };
            let mut pumped = Vec::new();
            pump_frames(&mut r, |f| pumped.push(f)).unwrap();
            assert_eq!(pumped, payloads);
        });
    }

    #[test]
    fn zero_length_payload_is_a_valid_frame() {
        let framed = frame_bytes(&[]).unwrap();
        assert_eq!(framed.len(), FRAME_HEADER_BYTES);
        let mut reader = FrameReader::new();
        let (used, frame) = reader.feed(&framed).unwrap();
        assert_eq!(used, FRAME_HEADER_BYTES);
        assert_eq!(frame, Some(vec![]));
        assert!(!reader.mid_frame());
    }

    #[test]
    fn pipelined_frames_in_one_chunk_do_not_bleed() {
        let a = frame_bytes(b"alpha").unwrap();
        let b = frame_bytes(b"bee").unwrap();
        let mut stream = a.clone();
        stream.extend_from_slice(&b);
        let got = feed_in_pieces(&stream, stream.len()).unwrap();
        assert_eq!(got, vec![b"alpha".to_vec(), b"bee".to_vec()]);
    }

    #[test]
    fn bad_magic_is_a_typed_error() {
        let mut framed = frame_bytes(b"x").unwrap();
        framed[0] ^= 0xff;
        let err = FrameReader::new().feed(&framed).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(err.to_string().contains("bad magic"), "{err}");
    }

    #[test]
    fn unsupported_version_is_a_typed_error() {
        let mut framed = frame_bytes(b"x").unwrap();
        framed[2] = FRAME_VERSION + 1;
        let err = FrameReader::new().feed(&framed).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn nonzero_reserved_byte_is_a_typed_error() {
        let mut framed = frame_bytes(b"x").unwrap();
        framed[3] = 0x80;
        let err = FrameReader::new().feed(&framed).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(err.to_string().contains("reserved"), "{err}");
    }

    #[test]
    fn oversized_declared_length_rejected_before_any_body_byte() {
        // header-only chunk declaring a length over the cap: the reader
        // must reject on the header alone, so a hostile peer cannot make
        // the server allocate
        let mut header = [0u8; FRAME_HEADER_BYTES];
        header[..2].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        header[2] = FRAME_VERSION;
        header[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = FrameReader::new().feed(&header).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(err.to_string().contains("exceeds cap"), "{err}");
        // custom caps enforce the same bound
        let mut small = [0u8; FRAME_HEADER_BYTES];
        small[..2].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        small[2] = FRAME_VERSION;
        small[4..8].copy_from_slice(&9u32.to_le_bytes());
        assert!(FrameReader::with_cap(8).feed(&small).is_err());
        assert!(FrameReader::with_cap(9).feed(&small).unwrap().1.is_none());
    }

    #[test]
    fn truncated_body_and_mid_frame_disconnect_are_typed_errors() {
        let framed = frame_bytes(b"hello world").unwrap();
        // EOF inside the body
        let mut r = ShortReader { data: &framed[..framed.len() - 3], at: 0, chunk: 4 };
        let err = pump_frames(&mut r, |_| {}).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(err.to_string().contains("mid-frame"), "{err}");
        // EOF inside the header
        let mut r = ShortReader { data: &framed[..3], at: 0, chunk: 2 };
        assert!(pump_frames(&mut r, |_| {}).is_err());
        // EOF on a clean boundary after one full frame is fine
        let mut r = ShortReader { data: &framed, at: 0, chunk: 5 };
        let mut n = 0;
        pump_frames(&mut r, |_| n += 1).unwrap();
        assert_eq!(n, 1);
    }

    #[test]
    fn write_frame_rejects_oversized_payload_without_io() {
        // construct a reader-side cap violation via the writer's own guard:
        // the writer refuses before touching the sink
        struct NoWrite;
        impl Write for NoWrite {
            fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
                panic!("writer must not be touched");
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let big = vec![0u8; MAX_FRAME_BYTES + 1];
        let err = write_frame(&mut NoWrite, &big).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
    }
}
