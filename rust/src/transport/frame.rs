//! Length-prefixed, session-aware frame layer for the socket transport.
//!
//! The codec ([`crate::transport::codec`]) defines *what* an update looks
//! like; a stream socket only hands back byte runs of arbitrary length, so
//! this module defines *where one message ends and the next begins* — and,
//! since the full-duplex session refactor, *who* is speaking and *which
//! direction* a frame travels. One frame carries one opaque payload (for
//! us: one encoded [`crate::transport::codec::WireUpdate`], or the 4-byte
//! client id of a registration hello).
//!
//! ## Wire format v2 (all integers little-endian)
//!
//! ```text
//! offset  size  field
//! 0       2     magic   0x4c46 ("FL")
//! 2       1     version 2
//! 3       1     kind    0 hello | 1 welcome | 2 upload | 3 broadcast
//! 4       8     session token (u64); 0 = "no session" (hello only)
//! 12      4     payload length in bytes (u32)
//! 16      len   payload
//! ```
//!
//! v1 (8-byte header, no kind/token) is gone: the wire is now a duplex
//! *session*, and an unauthenticated upload is a protocol error rather
//! than a valid message, so old peers are rejected on the version byte
//! with a typed error. The frame kinds:
//!
//! * **hello** (client→server) — registration: payload is the claimant's
//!   client id (4 bytes LE), token must be 0 (there is no session yet).
//! * **welcome** (server→client) — the handshake reply: the header token
//!   is the issued per-client session token; empty payload.
//! * **upload** (client→server) — one encoded update; the header token
//!   must match the connection's session and the payload's claimed client
//!   id must match the session's (verified *before* any codec decode —
//!   see [`crate::transport::session`]).
//! * **broadcast** (server→client) — the round's encoded downlink; the
//!   header token echoes the recipient's session token so a client can
//!   reject a frame that was not addressed to its session.
//!
//! Versioning rules: the layout through the magic/version bytes is frozen
//! for all versions; an incompatible change bumps `version` and old
//! readers reject it with a typed error. Unknown `kind` values are
//! rejected the same way, so the field can grow without silently
//! misreading old peers.
//!
//! A declared length above the hard cap ([`MAX_FRAME_BYTES`], or the custom
//! cap of [`FrameReader::with_cap`]) is rejected **before any allocation**:
//! a malicious 4 GiB length header costs the server nothing.
//!
//! ## Incremental reading
//!
//! [`FrameReader`] is a push-style state machine: feed it whatever chunk
//! the socket produced — a single byte, half a header, three frames at
//! once — and it hands back completed frames without ever over-consuming
//! into the next one. [`FrameStream`] is the pull-style counterpart the
//! duplex connections run: it wraps any [`Read`], yields one frame per
//! call, and keeps bytes read past a frame boundary for the next call.
//! [`pump_frames`] drains a whole stream through a callback. A connection
//! that closes mid-frame is a typed truncation error, while EOF on a
//! frame boundary is a clean end of stream.

use std::io::{Read, Write};

use crate::util::error::{Error, Result};

/// Frame magic: "FL" as a little-endian u16 (bytes `46 4c` on the wire).
pub const FRAME_MAGIC: u16 = 0x4c46;

/// Current frame version (2: session kind + token in the header).
pub const FRAME_VERSION: u8 = 2;

/// Fixed frame header size: magic(2) version(1) kind(1) token(8) length(4).
pub const FRAME_HEADER_BYTES: usize = 16;

/// Hard cap on a frame payload (64 MiB). Our largest real message is a
/// dense f32 model (a few MB); anything near the cap is a malformed or
/// hostile peer, and the reader rejects the declared length before
/// allocating a byte for the body.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// The "no session" token: the only value a hello may carry, and never a
/// value the server issues.
pub const NO_TOKEN: u64 = 0;

/// What a frame *is* — the four message types of the duplex session
/// protocol. The discriminants are the wire byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Client→server registration request (payload: client id, u32 LE).
    Hello = 0,
    /// Server→client handshake reply (token in header, empty payload).
    Welcome = 1,
    /// Client→server encoded update (token-authenticated).
    Upload = 2,
    /// Server→client encoded round broadcast.
    Broadcast = 3,
}

impl FrameKind {
    fn from_wire(b: u8) -> Result<FrameKind> {
        match b {
            0 => Ok(FrameKind::Hello),
            1 => Ok(FrameKind::Welcome),
            2 => Ok(FrameKind::Upload),
            3 => Ok(FrameKind::Broadcast),
            other => Err(Error::transport(format!("frame: unknown kind {other:#04x}"))),
        }
    }
}

/// One completed frame: kind + session token from the header, plus the
/// owned payload bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub kind: FrameKind,
    pub token: u64,
    pub payload: Vec<u8>,
}

/// Incremental frame decoder tolerant of arbitrarily short reads.
///
/// `feed` consumes bytes from the caller's chunk and returns how many it
/// used plus a completed frame when one finishes. It never consumes past
/// the end of a frame, so pipelined frames in one chunk survive: call it in
/// a loop, advancing by the consumed count.
#[derive(Debug)]
pub struct FrameReader {
    max_len: usize,
    /// Partial header bytes accumulated so far (valid up to `have`).
    header: [u8; FRAME_HEADER_BYTES],
    have: usize,
    /// Parsed (kind, token, body length) once the header completed;
    /// `None` while reading the header.
    need: Option<(FrameKind, u64, usize)>,
    body: Vec<u8>,
}

impl Default for FrameReader {
    fn default() -> Self {
        FrameReader::new()
    }
}

impl FrameReader {
    /// Reader with the standard [`MAX_FRAME_BYTES`] cap.
    pub fn new() -> FrameReader {
        FrameReader::with_cap(MAX_FRAME_BYTES)
    }

    /// Reader with a custom payload cap (tests use tiny caps to exercise
    /// the rejection path cheaply).
    pub fn with_cap(max_len: usize) -> FrameReader {
        FrameReader {
            max_len,
            header: [0u8; FRAME_HEADER_BYTES],
            have: 0,
            need: None,
            body: Vec::new(),
        }
    }

    /// True while a frame is partially read — a disconnect now is a
    /// truncation, not a clean end of stream.
    pub fn mid_frame(&self) -> bool {
        self.have > 0 || self.need.is_some()
    }

    /// Consume bytes from `chunk`. Returns `(consumed, Some(frame))` when
    /// a frame completes, `(consumed, None)` when more input is needed.
    /// After a completed frame the reader is reset and ready for the next
    /// header; unconsumed chunk bytes belong to the caller.
    pub fn feed(&mut self, chunk: &[u8]) -> Result<(usize, Option<Frame>)> {
        let mut used = 0usize;
        if self.need.is_none() {
            let take = (FRAME_HEADER_BYTES - self.have).min(chunk.len());
            // fedlint: allow(panic-free) -- take = min(header space left, chunk len) bounds both ranges
            self.header[self.have..self.have + take].copy_from_slice(&chunk[..take]);
            self.have += take;
            used += take;
            if self.have < FRAME_HEADER_BYTES {
                return Ok((used, None));
            }
            let [m0, m1, version, kind_b, tok @ .., l0, l1, l2, l3] = self.header;
            let magic = u16::from_le_bytes([m0, m1]);
            if magic != FRAME_MAGIC {
                return Err(Error::transport(format!("frame: bad magic {magic:#06x}")));
            }
            if version != FRAME_VERSION {
                return Err(Error::transport(format!(
                    "frame: unsupported version {version} (expected {FRAME_VERSION})"
                )));
            }
            let kind = FrameKind::from_wire(kind_b)?;
            let token = u64::from_le_bytes(tok);
            let len = u32::from_le_bytes([l0, l1, l2, l3]) as usize;
            if len > self.max_len {
                return Err(Error::transport(format!(
                    "frame: declared length {len} exceeds cap {}",
                    self.max_len
                )));
            }
            // Safe to reserve: len is bounded by the cap.
            self.need = Some((kind, token, len));
            self.body.clear();
            self.body.reserve(len);
        }
        let (kind, token, need) = match self.need {
            Some(t) => t,
            None => return Ok((used, None)),
        };
        let take = (need - self.body.len()).min(chunk.len() - used);
        if let Some(src) = chunk.get(used..used + take) {
            self.body.extend_from_slice(src);
            used += take;
        }
        if self.body.len() == need {
            self.need = None;
            self.have = 0;
            return Ok((
                used,
                Some(Frame {
                    kind,
                    token,
                    payload: std::mem::take(&mut self.body),
                }),
            ));
        }
        Ok((used, None))
    }
}

/// Write one frame (header + payload) to `w`. Fails without writing when
/// the payload exceeds [`MAX_FRAME_BYTES`].
pub fn write_frame<W: Write>(w: &mut W, kind: FrameKind, token: u64, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME_BYTES {
        return Err(Error::transport(format!(
            "frame: payload {} exceeds cap {MAX_FRAME_BYTES}",
            payload.len()
        )));
    }
    let mut header = [0u8; FRAME_HEADER_BYTES];
    {
        // `Write for &mut [u8]` fills from the front; the four fields sum
        // to exactly FRAME_HEADER_BYTES, so none of these can fail.
        let mut h: &mut [u8] = &mut header;
        h.write_all(&FRAME_MAGIC.to_le_bytes())?;
        h.write_all(&[FRAME_VERSION, kind as u8])?;
        h.write_all(&token.to_le_bytes())?;
        h.write_all(&(payload.len() as u32).to_le_bytes())?;
    }
    w.write_all(&header)?;
    w.write_all(payload)?;
    Ok(())
}

/// One frame as an owned byte vector (tests and in-memory paths).
pub fn frame_bytes(kind: FrameKind, token: u64, payload: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + payload.len());
    write_frame(&mut out, kind, token, payload)?;
    Ok(out)
}

/// Pull-style frame source over any [`Read`] — what each side of a
/// persistent duplex connection runs. One [`FrameStream::next`] call
/// yields one frame; bytes read past the frame boundary (pipelined
/// frames) are kept for the next call, so interleaving `next` with writes
/// on the same connection never loses input.
#[derive(Debug, Default)]
pub struct FrameStream {
    reader: FrameReader,
    /// Bytes read off the stream but not yet fed (valid in `start..end`).
    buf: Vec<u8>,
    start: usize,
    end: usize,
}

impl FrameStream {
    pub fn new() -> FrameStream {
        FrameStream {
            reader: FrameReader::new(),
            buf: vec![0u8; 16 * 1024],
            start: 0,
            end: 0,
        }
    }

    /// Read until one frame completes. `Ok(None)` is a clean EOF (the
    /// peer closed on a frame boundary with no bytes pending); EOF
    /// mid-frame is a typed truncation error; a read timeout (the caller
    /// armed `set_read_timeout`) is a typed transport error naming it.
    pub fn next<R: Read>(&mut self, r: &mut R) -> Result<Option<Frame>> {
        if self.buf.is_empty() {
            self.buf = vec![0u8; 16 * 1024];
        }
        loop {
            while self.start < self.end {
                let pending = self.buf.get(self.start..self.end).unwrap_or(&[]);
                let (used, frame) = self.reader.feed(pending)?;
                self.start += used;
                if let Some(f) = frame {
                    return Ok(Some(f));
                }
            }
            let n = match r.read(&mut self.buf) {
                Ok(n) => n,
                // EINTR (a signal landed mid-read) is not a peer failure:
                // retry instead of dropping a healthy connection.
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Err(Error::transport("frame: timed out waiting for a frame"))
                }
                Err(e) => return Err(e.into()),
            };
            if n == 0 {
                return if self.reader.mid_frame() {
                    Err(Error::transport("frame: connection closed mid-frame"))
                } else {
                    Ok(None)
                };
            }
            self.start = 0;
            self.end = n;
        }
    }

    /// Like [`FrameStream::next`] but a clean EOF is an error too — for
    /// callers that are owed a reply (handshake, downlink receive).
    pub fn expect_next<R: Read>(&mut self, r: &mut R) -> Result<Frame> {
        self.next(r)?
            .ok_or_else(|| Error::transport("frame: connection closed before a frame arrived"))
    }
}

/// Drain `r` frame by frame, handing each completed frame to `deliver`,
/// until EOF. Tolerates arbitrarily short reads and multiple frames per
/// read. EOF on a frame boundary returns `Ok(())`; EOF mid-frame is a
/// typed truncation error; a malformed header aborts immediately.
pub fn pump_frames<R: Read>(r: &mut R, mut deliver: impl FnMut(Frame)) -> Result<()> {
    let mut stream = FrameStream::new();
    while let Some(frame) = stream.next(r)? {
        deliver(frame);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::codec::{decode_update, encode_update, Encoding};
    use crate::util::prop::{check, Gen};

    /// Read adapter yielding at most `chunk` bytes per read (short-read
    /// torture for `pump_frames`).
    struct ShortReader<'a> {
        data: &'a [u8],
        at: usize,
        chunk: usize,
    }

    impl<'a> Read for ShortReader<'a> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = self.chunk.min(buf.len()).min(self.data.len() - self.at);
            buf[..n].copy_from_slice(&self.data[self.at..self.at + n]);
            self.at += n;
            Ok(n)
        }
    }

    fn masked_params(g: &mut Gen, p: usize, density: f32) -> Vec<f32> {
        (0..p)
            .map(|_| {
                if g.f32_in(0.0, 1.0) < density {
                    g.f32_in(-2.0, 2.0)
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Decode a whole stream via FrameReader fed in `piece`-sized chunks.
    fn feed_in_pieces(stream: &[u8], piece: usize) -> Result<Vec<Frame>> {
        let mut reader = FrameReader::new();
        let mut out = Vec::new();
        for chunk in stream.chunks(piece.max(1)) {
            let mut at = 0;
            while at < chunk.len() {
                let (used, frame) = reader.feed(&chunk[at..])?;
                at += used;
                if let Some(f) = frame {
                    out.push(f);
                }
            }
        }
        if reader.mid_frame() {
            return Err(Error::transport("frame: stream ended mid-frame"));
        }
        Ok(out)
    }

    #[test]
    fn roundtrip_split_at_every_byte_boundary() {
        // Every codec encoding, including empty and all-zero payloads; the
        // framed stream is split at every possible byte boundary and the
        // recovered frame (kind, token, payload) must be identical to what
        // was written. The boundary sweep includes every header offset
        // 0..=16, so partial kind/token/length reads are all covered.
        let mut g = Gen::new(0xf4a3e);
        let cases: Vec<Vec<f32>> = vec![
            vec![],                       // empty model (p = 0)
            vec![0.0; 57],                // all-zero upload
            masked_params(&mut g, 64, 0.2),
            masked_params(&mut g, 33, 1.0),
        ];
        let token = 0x1122_3344_5566_7788u64;
        for params in &cases {
            for &enc in Encoding::ALL {
                let payload = encode_update(7, 3, 11, params, enc);
                let framed = frame_bytes(FrameKind::Upload, token, &payload).unwrap();
                for split in 0..=framed.len() {
                    let mut reader = FrameReader::new();
                    let mut got = None;
                    for part in [&framed[..split], &framed[split..]] {
                        let mut at = 0;
                        while at < part.len() {
                            let (used, frame) = reader.feed(&part[at..]).unwrap();
                            at += used;
                            if let Some(f) = frame {
                                got = Some(f);
                            }
                        }
                    }
                    let got = got.unwrap_or_else(|| panic!("no frame at split {split}"));
                    assert_eq!(got.kind, FrameKind::Upload, "enc {enc:?} split {split}");
                    assert_eq!(got.token, token, "enc {enc:?} split {split}");
                    assert_eq!(&got.payload, &payload, "enc {enc:?} split {split}");
                    // decoded update identical to the direct codec path
                    assert_eq!(
                        decode_update(&got.payload).unwrap(),
                        decode_update(&payload).unwrap()
                    );
                }
            }
        }
    }

    #[test]
    fn prop_roundtrip_random_piece_sizes() {
        check("frame roundtrip, random splits", 60, |g| {
            let k = g.usize_in(1, 5);
            let kinds = [
                FrameKind::Hello,
                FrameKind::Welcome,
                FrameKind::Upload,
                FrameKind::Broadcast,
            ];
            let frames: Vec<Frame> = (0..k)
                .map(|c| {
                    let p = g.usize_in(0, 300);
                    let density = g.f32_in(0.0, 1.0);
                    let params = masked_params(g, p, density);
                    let enc = *g.choose(Encoding::ALL);
                    Frame {
                        kind: kinds[g.usize_in(0, kinds.len() - 1)],
                        token: g.usize_in(0, u32::MAX as usize) as u64,
                        payload: encode_update(c as u32, 1, 2, &params, enc),
                    }
                })
                .collect();
            let mut stream = Vec::new();
            for f in &frames {
                write_frame(&mut stream, f.kind, f.token, &f.payload).unwrap();
            }
            // random body offsets: pieces of random size, incl. size 1
            let piece = g.usize_in(1, stream.len().max(1));
            let got = feed_in_pieces(&stream, piece).unwrap();
            assert_eq!(got, frames, "piece {piece} seed {:#x}", g.seed);
            // and the byte-at-a-time pump over a Read
            let mut r = ShortReader { data: &stream, at: 0, chunk: 1 };
            let mut pumped = Vec::new();
            pump_frames(&mut r, |f| pumped.push(f)).unwrap();
            assert_eq!(pumped, frames);
        });
    }

    #[test]
    fn zero_length_payload_is_a_valid_frame() {
        // the welcome frame is exactly this: header-only, token payload-free
        let framed = frame_bytes(FrameKind::Welcome, 99, &[]).unwrap();
        assert_eq!(framed.len(), FRAME_HEADER_BYTES);
        let mut reader = FrameReader::new();
        let (used, frame) = reader.feed(&framed).unwrap();
        assert_eq!(used, FRAME_HEADER_BYTES);
        let frame = frame.unwrap();
        assert_eq!(frame.kind, FrameKind::Welcome);
        assert_eq!(frame.token, 99);
        assert!(frame.payload.is_empty());
        assert!(!reader.mid_frame());
    }

    #[test]
    fn pipelined_frames_in_one_chunk_do_not_bleed() {
        let a = frame_bytes(FrameKind::Upload, 1, b"alpha").unwrap();
        let b = frame_bytes(FrameKind::Broadcast, 2, b"bee").unwrap();
        let mut stream = a.clone();
        stream.extend_from_slice(&b);
        let got = feed_in_pieces(&stream, stream.len()).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].payload, b"alpha");
        assert_eq!(got[1].kind, FrameKind::Broadcast);
        assert_eq!(got[1].token, 2);
        assert_eq!(got[1].payload, b"bee");
    }

    #[test]
    fn bad_magic_is_a_typed_error() {
        let mut framed = frame_bytes(FrameKind::Upload, 1, b"x").unwrap();
        framed[0] ^= 0xff;
        let err = FrameReader::new().feed(&framed).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(err.to_string().contains("bad magic"), "{err}");
    }

    #[test]
    fn unsupported_version_is_a_typed_error() {
        // both the future (v3) and the dead v1 wire are rejected by byte 2
        for bad in [FRAME_VERSION + 1, 1] {
            let mut framed = frame_bytes(FrameKind::Upload, 1, b"x").unwrap();
            framed[2] = bad;
            let err = FrameReader::new().feed(&framed).unwrap_err();
            assert!(matches!(err, Error::Transport(_)), "{err}");
            assert!(err.to_string().contains("version"), "{err}");
        }
    }

    #[test]
    fn unknown_kind_is_a_typed_error() {
        let mut framed = frame_bytes(FrameKind::Upload, 1, b"x").unwrap();
        framed[3] = 0x80;
        let err = FrameReader::new().feed(&framed).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(err.to_string().contains("unknown kind"), "{err}");
    }

    #[test]
    fn oversized_declared_length_rejected_before_any_body_byte() {
        // header-only chunk declaring a length over the cap: the reader
        // must reject on the header alone, so a hostile peer cannot make
        // the server allocate
        let mut header = [0u8; FRAME_HEADER_BYTES];
        header[..2].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        header[2] = FRAME_VERSION;
        header[3] = FrameKind::Upload as u8;
        header[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = FrameReader::new().feed(&header).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(err.to_string().contains("exceeds cap"), "{err}");
        // custom caps enforce the same bound
        let mut small = [0u8; FRAME_HEADER_BYTES];
        small[..2].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        small[2] = FRAME_VERSION;
        small[3] = FrameKind::Upload as u8;
        small[12..16].copy_from_slice(&9u32.to_le_bytes());
        assert!(FrameReader::with_cap(8).feed(&small).is_err());
        assert!(FrameReader::with_cap(9).feed(&small).unwrap().1.is_none());
    }

    #[test]
    fn truncated_body_and_mid_frame_disconnect_are_typed_errors() {
        let framed = frame_bytes(FrameKind::Upload, 5, b"hello world").unwrap();
        // EOF inside the body
        let mut r = ShortReader { data: &framed[..framed.len() - 3], at: 0, chunk: 4 };
        let err = pump_frames(&mut r, |_| {}).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(err.to_string().contains("mid-frame"), "{err}");
        // EOF inside the header
        let mut r = ShortReader { data: &framed[..3], at: 0, chunk: 2 };
        assert!(pump_frames(&mut r, |_| {}).is_err());
        // EOF on a clean boundary after one full frame is fine
        let mut r = ShortReader { data: &framed, at: 0, chunk: 5 };
        let mut n = 0;
        pump_frames(&mut r, |_| n += 1).unwrap();
        assert_eq!(n, 1);
    }

    #[test]
    fn frame_stream_interleaves_with_leftover_bytes() {
        // two pipelined frames arrive in one read; a FrameStream must hand
        // them back across two next() calls without losing the leftover
        let a = frame_bytes(FrameKind::Broadcast, 7, b"round-1").unwrap();
        let b = frame_bytes(FrameKind::Broadcast, 7, b"round-2").unwrap();
        let mut stream = a;
        stream.extend_from_slice(&b);
        let mut r = ShortReader { data: &stream, at: 0, chunk: stream.len() };
        let mut fs = FrameStream::new();
        assert_eq!(fs.next(&mut r).unwrap().unwrap().payload, b"round-1");
        assert_eq!(fs.next(&mut r).unwrap().unwrap().payload, b"round-2");
        assert!(fs.next(&mut r).unwrap().is_none(), "clean EOF after the last frame");
        // expect_next turns the clean EOF into a typed error
        let mut r = ShortReader { data: &[], at: 0, chunk: 1 };
        let err = FrameStream::new().expect_next(&mut r).unwrap_err();
        assert!(err.to_string().contains("closed"), "{err}");
    }

    #[test]
    fn write_frame_rejects_oversized_payload_without_io() {
        // construct a reader-side cap violation via the writer's own guard:
        // the writer refuses before touching the sink
        struct NoWrite;
        impl Write for NoWrite {
            fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
                panic!("writer must not be touched");
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let big = vec![0u8; MAX_FRAME_BYTES + 1];
        let err = write_frame(&mut NoWrite, FrameKind::Upload, 0, &big).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
    }
}
