//! Simulated network model.
//!
//! Maps message byte counts to virtual transfer times over a shared-uplink
//! star topology (clients -> server), the usual cross-device FL shape: the
//! server's downlink broadcast is per-client parallel, the uplink is
//! bandwidth-shared. The paper explicitly ignores these effects; modeling
//! them lets the figure drivers also report virtual round latency and lets
//! failure-injection tests reason about deadlines.

/// Star-topology network model.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// Per-client link bandwidth, bytes/second.
    pub client_bw: f64,
    /// Server aggregate uplink capacity, bytes/second.
    pub server_bw: f64,
    /// Per-message fixed latency, seconds.
    pub latency_s: f64,
}

impl Default for NetworkModel {
    /// 20 Mbit/s clients, 1 Gbit/s server, 30 ms RTT-ish latency — a
    /// plausible mobile-fleet profile.
    fn default() -> Self {
        NetworkModel {
            client_bw: 20e6 / 8.0,
            server_bw: 1e9 / 8.0,
            latency_s: 0.03,
        }
    }
}

impl NetworkModel {
    /// Idealized network: everything instantaneous (the paper's setting).
    pub fn ideal() -> NetworkModel {
        NetworkModel {
            client_bw: f64::INFINITY,
            server_bw: f64::INFINITY,
            latency_s: 0.0,
        }
    }

    /// One message over one client link: latency + serialization. Both
    /// directions share this today (symmetric client links); asymmetric
    /// profiles would split it.
    fn client_link_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.client_bw
    }

    /// Time for one client to receive `bytes` (downlink broadcast leg).
    pub fn download_time(&self, bytes: usize) -> f64 {
        self.client_link_time(bytes)
    }

    /// Time for a single client's upload of `bytes`, alone on its link (no
    /// server-side sharing). The `Simulated` transport orders per-round
    /// deliveries by this; zero-byte messages are well-defined and cost
    /// exactly the fixed latency.
    pub fn upload_time(&self, bytes: usize) -> f64 {
        self.client_link_time(bytes)
    }

    /// Time for `uploads` concurrent client uploads of `bytes` each to all
    /// complete: each client is limited by its own link, and the server
    /// uplink is shared fairly across the concurrent transfers.
    pub fn upload_round_time(&self, bytes_each: &[usize]) -> f64 {
        if bytes_each.is_empty() {
            return 0.0;
        }
        let total: usize = bytes_each.iter().sum();
        let max_each = *bytes_each.iter().max().unwrap();
        let client_limited = max_each as f64 / self.client_bw;
        let server_limited = total as f64 / self.server_bw;
        self.latency_s + client_limited.max(server_limited)
    }

    /// Full round trip for one round: broadcast + slowest upload.
    pub fn round_time(&self, download_bytes: usize, upload_bytes: &[usize]) -> f64 {
        self.download_time(download_bytes) + self.upload_round_time(upload_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_network_is_free() {
        let n = NetworkModel::ideal();
        assert_eq!(n.download_time(1 << 30), 0.0);
        assert_eq!(n.upload_round_time(&[1 << 30; 100]), 0.0);
    }

    #[test]
    fn ideal_times_are_exactly_zero_including_zero_bytes() {
        // infinite bandwidth + zero latency: every leg is exactly 0.0 —
        // not epsilon, not NaN (0 / inf == 0.0 in IEEE 754)
        let n = NetworkModel::ideal();
        assert_eq!(n.download_time(0), 0.0);
        assert_eq!(n.upload_time(0), 0.0);
        assert_eq!(n.upload_time(usize::MAX / 2), 0.0);
        assert_eq!(n.upload_round_time(&[0, 0, 0]), 0.0);
        assert_eq!(n.round_time(0, &[0]), 0.0);
        // empty upload set: no leg at all
        assert_eq!(n.upload_round_time(&[]), 0.0);
    }

    #[test]
    fn latency_only_when_bandwidth_is_infinite() {
        // infinite bandwidth with nonzero latency: every message, including
        // a zero-byte one, costs exactly the fixed latency
        let n = NetworkModel {
            client_bw: f64::INFINITY,
            server_bw: f64::INFINITY,
            latency_s: 0.25,
        };
        assert_eq!(n.download_time(0), 0.25);
        assert_eq!(n.upload_time(0), 0.25);
        assert_eq!(n.upload_time(1 << 20), 0.25);
        assert_eq!(n.upload_round_time(&[0]), 0.25);
    }

    #[test]
    fn zero_byte_messages_are_well_defined_at_finite_bandwidth() {
        let n = NetworkModel::default();
        assert_eq!(n.download_time(0), n.latency_s);
        assert_eq!(n.upload_time(0), n.latency_s);
        assert_eq!(n.upload_round_time(&[0, 0]), n.latency_s);
        assert!(n.round_time(0, &[0]).is_finite());
    }

    #[test]
    fn upload_time_is_monotone_in_bytes() {
        let n = NetworkModel::default();
        assert!(n.upload_time(10) < n.upload_time(11));
        assert!(n.upload_time(0) < n.upload_time(1));
    }

    #[test]
    fn client_link_dominates_small_cohorts() {
        let n = NetworkModel {
            client_bw: 1e6,
            server_bw: 1e9,
            latency_s: 0.0,
        };
        // one 1 MB upload: 1 second on the client link
        let t = n.upload_round_time(&[1_000_000]);
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn server_link_dominates_large_cohorts() {
        let n = NetworkModel {
            client_bw: 1e9,
            server_bw: 1e6,
            latency_s: 0.0,
        };
        // 100 x 10 KB = 1 MB through a 1 MB/s server pipe
        let t = n.upload_round_time(&vec![10_000; 100]);
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn masked_uploads_are_faster() {
        let n = NetworkModel::default();
        let dense = n.upload_round_time(&vec![4 * 200_000; 10]);
        let masked = n.upload_round_time(&vec![4 * 20_000; 10]);
        assert!(masked < dense);
    }

    #[test]
    fn latency_adds_once_per_leg() {
        let n = NetworkModel {
            client_bw: f64::INFINITY,
            server_bw: f64::INFINITY,
            latency_s: 0.5,
        };
        assert!((n.round_time(1000, &[1000, 1000]) - 1.0).abs() < 1e-9);
    }
}
