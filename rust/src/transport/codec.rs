//! Wire encoding of model updates — the **load-bearing** client->server
//! (and optionally server->client) data path, not just byte accounting.
//!
//! A masked update is mostly zeros; shipping it densely would throw the
//! paper's saving away. The codec chooses between:
//!
//! * **dense**  — header + P * 4 bytes of f32;
//! * **sparse** — header + nnz * (4-byte index + 4-byte value);
//! * **sparse-delta** — header + nnz varint-coded index deltas + nnz * 4
//!   value bytes. Because decoded indices are strictly increasing, each
//!   index is stored as its gap from the previous one in LEB128 varint
//!   form — for the clustered / low-gamma index sets masking produces,
//!   most gaps fit one byte, cutting the 4-byte flat index cost toward
//!   the entropy floor (paper §1's "cutting-edge compression" remark);
//! * **q8 / q4 value quantization** — 8-bit (one byte per value) or 4-bit
//!   (two values per byte) linear codes on the shared fixed-point grid
//!   `min + scale * code` (see [`crate::transport::quantize`]), stacked
//!   under the dense/sparse choice;
//! * **wire v3 arms** (tags 7–10) — cross-round *cached* index coding
//!   (tag 7 ships only the added/removed indices against the session's
//!   [`IndexCache`], keyed by its epoch), per-group q8 quantizer grids
//!   (tags 8/9, [`GQ8_GROUP`]-wide groups for outlier robustness), and a
//!   Rice/Golomb entropy-coded q8 value stream (tag 10). The cached arm
//!   is stateful — encode and decode must agree on the cache epoch, and
//!   the round driver invalidates the cache on any drop, disconnect, or
//!   round skip so a desynced delta is a typed parse error, never a
//!   silent corruption.
//!
//! All integers are little-endian; the header carries (client id, round,
//! sample count) for the aggregator — `ClientJob::run` encodes,
//! `Server::run_round` decodes and folds, and nothing else ever sees the
//! raw parameter vector in between. The complete wire grammar (tag table,
//! varint canonicality rules, nibble packing) lives in `docs/WIRE.md`.
//!
//! ## Size selection
//!
//! [`Encoding::Auto`] (lossless) and [`Encoding::AutoQ4`]/[`Encoding::AutoQ8`]
//! (lossy) pick the cheapest representation **by exact encoded length**,
//! computed up front from the payload (varint totals included) — never by a
//! shape-only heuristic — so an auto encoding never emits more bytes than
//! the best fixed encoding at its loss level. [`wire_bytes`] stays exact
//! for the fixed-size encodings and returns a documented upper bound for
//! the payload-dependent ones.
//!
//! ## Sparse-native decoding
//!
//! Since the O(nnz) aggregation refactor the decoder no longer densifies:
//! a sparse body decodes to its `(indices, values)` pairs
//! ([`DecodedBody::Sparse`] / [`BodyView::Sparse`]) and flows into the
//! aggregator's sparse fold untouched, so a masked upload costs
//! O(nnz) — not O(p) — from the first wire byte to the accumulator. Two
//! entry points:
//!
//! * [`decode_update`] — owned [`WireUpdate`]; allocates per call.
//! * [`decode_update_view`] — borrows a caller-held [`DecodeScratch`], so a
//!   server decoding a whole cohort (or many rounds) reuses the same
//!   buffers and steady-state decoding performs no heap allocation.
//!
//! Sparse bodies are validated strictly: indices must be in-range **and
//! strictly increasing** (the encoder always emits them sorted), which
//! rejects duplicate and shuffled indices that would otherwise make the
//! fold order-dependent. Byte-to-float conversion is bulk
//! (`chunks_exact` over the body slice) rather than per-element cursor
//! reads.

use crate::transport::quantize::{
    grid_code, grid_scale, q4_code, quantize, quantize4, rice_decode, rice_encode, rice_plan,
    Quantized, Quantized4, RICE_MAX_K,
};
use crate::transport::session::IndexCache;
use crate::util::error::{Error, Result};

/// Magic + version guard ("FM" + v1).
const MAGIC: u16 = 0x464d;
const VERSION: u8 = 1;

pub const TAG_DENSE: u8 = 0;
pub const TAG_SPARSE: u8 = 1;
pub const TAG_DENSE_Q8: u8 = 2;
pub const TAG_SPARSE_Q8: u8 = 3;
pub const TAG_SPARSE_DELTA: u8 = 4;
pub const TAG_DENSE_Q4: u8 = 5;
pub const TAG_SPARSE_DELTA_Q4: u8 = 6;
// --- wire v3 tags: cross-round caching + entropy-coded values ---
pub const TAG_SPARSE_CACHED: u8 = 7;
pub const TAG_DENSE_GQ8: u8 = 8;
pub const TAG_SPARSE_GQ8: u8 = 9;
pub const TAG_SPARSE_RICE8: u8 = 10;

/// Grouped-quantizer group width (tags 8/9): each run of this many
/// positions (dense) or carried values (sparse) gets its own
/// `(min, scale)` grid, so one outlier coordinate only coarsens its own
/// group instead of the whole tensor.
pub const GQ8_GROUP: usize = 256;

/// Fixed header: magic(2) version(1) tag(1) client(4) round(4)
/// n_samples(4) p(4) count(4).
const HEADER_BYTES: usize = 24;

/// Sentinel "client" id in downlink broadcast headers: the server itself.
pub const BROADCAST_SENDER: u32 = u32::MAX;

/// Broadcast semantics flag, carried in the (otherwise unused) `n_samples`
/// header field of a downlink message: the payload is the full model —
/// decode and use directly.
pub const BROADCAST_FULL: u32 = 0;

/// Broadcast semantics flag: the payload is `w_t - w_{t-1}` — the client
/// reconstructs `w_{t-1} + delta` against the broadcast it already holds.
/// Note this is *semantics*, not layout: a delta may still ship under any
/// codec tag (Auto picks by size), so the receiver cannot infer it from
/// the tag and must be told — which also lets it fail loudly when server
/// and client disagree about what state the client holds.
pub const BROADCAST_DELTA: u32 = 1;

/// Read the client id a message *claims* to be from — bytes 4..8 of the
/// fixed header — without decoding anything else. The session layer uses
/// this to verify an upload's claimed sender against its connection's
/// authenticated session **before** any payload decode; `None` means the
/// message is too short to even carry the header field.
pub fn peek_client(payload: &[u8]) -> Option<u32> {
    let b: [u8; 4] = payload.get(4..8)?.try_into().ok()?;
    Some(u32::from_le_bytes(b))
}

/// The fixed-header fields a server can validate *without* decoding the
/// body: who the message claims to be from, which round it belongs to,
/// its sample weight, and the model width it was encoded against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeekedHeader {
    pub client: u32,
    pub round: u32,
    pub n_samples: u32,
    pub p: u32,
}

/// Read the full fixed header — magic, version, and the four routing
/// fields — without touching the body. The sharded aggregation path uses
/// this to run the round's cohort checks (round, membership, duplicate,
/// width) on the drain thread, then ships the *undecoded* payload to its
/// shard worker, which decodes and folds in parallel. `None` means the
/// bytes cannot be one of our messages (too short, wrong magic, or wrong
/// version) — the body itself is still only validated by the real decode.
pub fn peek_header(payload: &[u8]) -> Option<PeekedHeader> {
    if payload.len() < HEADER_BYTES {
        return None;
    }
    let word = |at: usize| -> Option<u32> {
        let b: [u8; 4] = payload.get(at..at + 4)?.try_into().ok()?;
        Some(u32::from_le_bytes(b))
    };
    let m: [u8; 2] = payload.get(0..2)?.try_into().ok()?;
    if u16::from_le_bytes(m) != MAGIC || payload.get(2) != Some(&VERSION) {
        return None;
    }
    Some(PeekedHeader {
        client: word(4)?,
        round: word(8)?,
        n_samples: word(12)?,
        p: word(16)?,
    })
}

/// Quantized-body prefix: min f32 + scale f32.
const QHEADER: usize = 8;

/// Chosen wire representation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    Dense,
    Sparse,
    /// Entropy-coded sparse: strictly-increasing indices stored as
    /// delta-then-LEB128-varint, values as f32. Lossless, like `Sparse`,
    /// but the per-index cost shrinks from a flat 4 bytes to the varint
    /// length of the gap (1 byte for gaps < 128).
    SparseDelta,
    /// Pick the smallest lossless representation (dense / sparse /
    /// sparse-delta) for the given payload, by exact encoded length.
    Auto,
    /// 8-bit linear quantization stacked on the auto dense/sparse choice
    /// (paper §1: masking "can also be combined with cutting-edge
    /// compression algorithms"). Lossy: values dequantize within half a
    /// quantization step (see [`crate::transport::quantize`]).
    AutoQ8,
    /// 4-bit linear quantization (two codes per byte, same fixed-point
    /// grid contract as q8) stacked on the auto dense/sparse-delta choice.
    /// Lossy: half a (coarser) quantization step.
    AutoQ4,
    /// Cross-round index caching (wire v3): when the caller supplies the
    /// session's [`IndexCache`] (the previous round's accepted index set)
    /// and the set-delta encoding is strictly smaller, emit only the
    /// added/removed indices against that set (tag 7, keyed by the cache
    /// epoch); otherwise — no cache, first round, or a churned mask that
    /// makes the delta dearer — fall back to the stateless `SparseDelta`
    /// form. Lossless either way.
    SparseCached,
    /// 8-bit quantization with a per-group `(min, scale)` grid every
    /// [`GQ8_GROUP`] values (wire v3): a single outlier no longer widens
    /// the whole tensor's quantization step, at 8 header bytes per group.
    /// Picks its dense/sparse arm by exact encoded length. Lossy: half of
    /// the *group's* step, bounded by half the global q8 step.
    GroupedQ8,
}

impl Encoding {
    /// Parse the CLI/JSON spelling.
    pub fn parse(s: &str) -> Result<Encoding> {
        match s {
            "dense" => Ok(Encoding::Dense),
            "sparse" => Ok(Encoding::Sparse),
            "sparse-delta" => Ok(Encoding::SparseDelta),
            "auto" => Ok(Encoding::Auto),
            "auto-q8" => Ok(Encoding::AutoQ8),
            "auto-q4" => Ok(Encoding::AutoQ4),
            "sparse-cached" => Ok(Encoding::SparseCached),
            "grouped-q8" => Ok(Encoding::GroupedQ8),
            other => Err(Error::invalid(format!(
                "bad encoding '{other}' (expected dense|sparse|sparse-delta|auto|auto-q8|auto-q4|\
                 sparse-cached|grouped-q8)"
            ))),
        }
    }

    /// Canonical config spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Encoding::Dense => "dense",
            Encoding::Sparse => "sparse",
            Encoding::SparseDelta => "sparse-delta",
            Encoding::Auto => "auto",
            Encoding::AutoQ8 => "auto-q8",
            Encoding::AutoQ4 => "auto-q4",
            Encoding::SparseCached => "sparse-cached",
            Encoding::GroupedQ8 => "grouped-q8",
        }
    }

    /// All encodings, for exhaustive tests/benches.
    pub const ALL: &'static [Encoding] = &[
        Encoding::Dense,
        Encoding::Sparse,
        Encoding::SparseDelta,
        Encoding::Auto,
        Encoding::AutoQ8,
        Encoding::AutoQ4,
        Encoding::SparseCached,
        Encoding::GroupedQ8,
    ];

    /// Does this encoding (or the driver on its behalf) maintain the
    /// per-session cross-round [`IndexCache`]? `SparseCached` by
    /// definition; `Auto` because its exact-length census also prices the
    /// cached arm whenever a cache is supplied.
    pub fn uses_index_cache(&self) -> bool {
        matches!(self, Encoding::SparseCached | Encoding::Auto)
    }

    /// Half the dequantization step this encoding can introduce on values
    /// spanning `[lo, hi]` — the per-value error bound of a lossy encoding,
    /// `0.0` for lossless ones. Callers that reconstruct state from a
    /// decoded message (the delta downlink) assert their reconstruction
    /// error against this bound. For `GroupedQ8` the true per-value bound
    /// is half the *group's* step; each group spans a sub-range of
    /// `[lo, hi]`, so the global q8 half-step reported here is a valid
    /// (loose) upper bound.
    pub fn lossy_half_step(&self, lo: f32, hi: f32) -> f32 {
        let range = (hi - lo).max(0.0);
        match self {
            Encoding::Dense
            | Encoding::Sparse
            | Encoding::SparseDelta
            | Encoding::Auto
            | Encoding::SparseCached => 0.0,
            Encoding::AutoQ8 | Encoding::GroupedQ8 => range / 255.0 * 0.5,
            Encoding::AutoQ4 => range / 15.0 * 0.5,
        }
    }
}

// ---------------------------------------------------------------------
// LEB128 varints (sparse-delta index coding)
// ---------------------------------------------------------------------

/// Encoded length of `v` as a LEB128 varint (1..=5 bytes for u32).
#[inline]
pub fn varint_len(v: u32) -> usize {
    match v {
        0..=0x7f => 1,
        0x80..=0x3fff => 2,
        0x4000..=0x1f_ffff => 3,
        0x20_0000..=0x0fff_ffff => 4,
        _ => 5,
    }
}

/// Append `v` in LEB128 form (7 payload bits per byte, low group first,
/// high bit = continuation).
#[inline]
fn push_varint(out: &mut Vec<u8>, mut v: u32) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Read one canonical LEB128 u32 at `at`, advancing the cursor. Strict:
/// rejects truncation, encodings longer than 5 bytes, values overflowing
/// u32, and overlong (non-canonical) forms whose final byte is zero.
fn read_varint(data: &[u8], at: &mut usize) -> Result<u32> {
    let mut v = 0u32;
    for k in 0..5usize {
        let b = *data
            .get(*at + k)
            .ok_or_else(|| Error::parse("codec: truncated varint"))?;
        let payload = (b & 0x7f) as u32;
        if k == 4 {
            if b & 0x80 != 0 {
                return Err(Error::parse("codec: varint longer than 5 bytes"));
            }
            if payload > 0x0f {
                return Err(Error::parse("codec: varint overflows u32"));
            }
        }
        v |= payload << (7 * k);
        if b & 0x80 == 0 {
            if k > 0 && b == 0 {
                return Err(Error::parse("codec: overlong varint encoding"));
            }
            *at += k + 1;
            return Ok(v);
        }
    }
    // The k == 4 arm above either returned the value or errored, so the
    // loop cannot fall through — but a typed error keeps the decode path
    // free of panicking constructs even if that invariant ever shifts.
    Err(Error::parse("codec: varint longer than 5 bytes"))
}

/// One-pass payload census: non-zero count and the exact byte length of
/// the sparse-delta varint index block — what exact-size auto selection
/// needs before writing a single byte.
fn census(params: &[f32]) -> (usize, usize) {
    let mut nnz = 0usize;
    let mut delta_bytes = 0usize;
    let mut prev = 0u32;
    let mut first = true;
    for (i, &v) in params.iter().enumerate() {
        if v != 0.0 {
            let delta = if first { i as u32 } else { i as u32 - prev };
            delta_bytes += varint_len(delta);
            prev = i as u32;
            first = false;
            nnz += 1;
        }
    }
    (nnz, delta_bytes)
}

/// A decoded update body, in whichever shape the wire carried it. Sparse
/// bodies stay sparse — densification is the *aggregator's* decision (and
/// with the O(nnz) fold it never happens on the server hot path).
#[derive(Debug, Clone, PartialEq)]
pub enum DecodedBody {
    Dense(Vec<f32>),
    /// Strictly-increasing indices into `[0, p)` paired with their values.
    Sparse { indices: Vec<u32>, values: Vec<f32> },
}

/// A decoded update message (owned).
#[derive(Debug, Clone, PartialEq)]
pub struct WireUpdate {
    pub client: u32,
    pub round: u32,
    pub n_samples: u32,
    /// Full model dimension the body addresses into.
    pub p: usize,
    pub body: DecodedBody,
}

impl WireUpdate {
    /// Non-zero entries actually carried by the body.
    pub fn nnz(&self) -> usize {
        match &self.body {
            DecodedBody::Dense(v) => v.iter().filter(|x| **x != 0.0).count(),
            DecodedBody::Sparse { indices, .. } => indices.len(),
        }
    }

    /// The full dense vector as a copy-on-write view: a dense body is
    /// **borrowed** (no O(p) copy — the broadcast-decode path reads the
    /// model through this without deep-copying it per client), a sparse
    /// body is materialized. Callers that only read keep the borrow;
    /// `into_owned()` reproduces the old [`Self::to_dense`] behavior.
    pub fn dense_cow(&self) -> std::borrow::Cow<'_, [f32]> {
        match &self.body {
            DecodedBody::Dense(v) => std::borrow::Cow::Borrowed(v.as_slice()),
            DecodedBody::Sparse { indices, values } => {
                let mut out = vec![0.0f32; self.p];
                for (&i, &v) in indices.iter().zip(values) {
                    out[i as usize] = v;
                }
                std::borrow::Cow::Owned(out)
            }
        }
    }

    /// Materialize the full dense vector (O(p)); test/compat convenience —
    /// the server hot path never calls this. Prefer [`Self::dense_cow`]
    /// when the caller only needs to read.
    pub fn to_dense(&self) -> Vec<f32> {
        self.dense_cow().into_owned()
    }

    /// [`Self::to_dense`], consuming: a dense body is moved out, not cloned.
    pub fn into_dense(self) -> Vec<f32> {
        let p = self.p;
        match self.body {
            DecodedBody::Dense(v) => v,
            DecodedBody::Sparse { indices, values } => {
                let mut out = vec![0.0f32; p];
                for (i, v) in indices.into_iter().zip(values) {
                    out[i as usize] = v;
                }
                out
            }
        }
    }
}

/// A decoded update body borrowed from a [`DecodeScratch`].
#[derive(Debug, Clone, Copy)]
pub enum BodyView<'a> {
    Dense(&'a [f32]),
    Sparse { indices: &'a [u32], values: &'a [f32] },
}

/// A decoded update message borrowing its body from caller-held scratch.
#[derive(Debug)]
pub struct WireView<'a> {
    pub client: u32,
    pub round: u32,
    pub n_samples: u32,
    pub p: usize,
    pub body: BodyView<'a>,
}

/// Reusable decode buffers: hold one of these across payloads (the server
/// holds one across *rounds*) and steady-state decoding allocates nothing.
#[derive(Debug, Default)]
pub struct DecodeScratch {
    dense: Vec<f32>,
    indices: Vec<u32>,
    values: Vec<f32>,
    /// Set-delta blocks of a `SparseCached` body (tag 7).
    removed: Vec<u32>,
    added: Vec<u32>,
    /// Entropy-decoded q8 codes (tag 10).
    codes: Vec<u8>,
}

/// Reusable encode temporaries (the q8 sparse value gather, the
/// cached-arm set-delta lists, and the fused path's quantizer-code and
/// group-grid buffers). Held across payloads — the `*_into` entry points
/// and [`encode_masked`] write into a caller-supplied output buffer too,
/// so a worker that also recycles its frame buffers (see
/// `runtime::bufpool::BufferPool`) encodes with zero steady-state heap
/// allocation.
#[derive(Debug, Default)]
pub struct EncodeScratch {
    vals: Vec<f32>,
    removed: Vec<u32>,
    added: Vec<u32>,
    /// Quantizer codes of the fused path (q8 / grouped-q8 / Rice arms) —
    /// replaces the per-call `codes` vectors the staged arms allocate.
    codes: Vec<u8>,
}

/// Wire size in bytes for a payload with `nnz` non-zeros out of `p`.
///
/// Exact — `wire_bytes == encoded.len()` for every payload shape — for
/// `Dense` and `Sparse`, whose sizes depend only on `(p, nnz)`. For the
/// payload-dependent encodings — `SparseDelta`/`Auto`/`AutoQ4` (varint
/// gap lengths depend on where the non-zeros sit), `AutoQ8` (its Rice
/// arm's length depends on the code distribution), `SparseCached` (the
/// set-delta depends on the previous round's cache), and `GroupedQ8`
/// (varint gaps again) — this returns a guaranteed **upper bound** (every
/// index delta priced at the widest varint an index `< p` can need, the
/// entropy-coded and cached arms priced at the stateless alternative
/// they never exceed), and the encoder itself picks the representation by
/// exact encoded length — so `encoded.len() <= wire_bytes` always holds,
/// with equality for the fixed-size encodings.
pub fn wire_bytes(p: usize, nnz: usize, enc: Encoding) -> usize {
    // widest varint any single index delta (<= p - 1) can occupy
    let vmax = varint_len(p.saturating_sub(1) as u32);
    match enc {
        Encoding::Dense => HEADER_BYTES + 4 * p,
        Encoding::Sparse => HEADER_BYTES + 8 * nnz,
        Encoding::SparseDelta => HEADER_BYTES + nnz * (4 + vmax),
        // the cached arm is only ever chosen when strictly smaller than
        // the stateless sparse-delta form it falls back to
        Encoding::SparseCached => wire_bytes(p, nnz, Encoding::SparseDelta),
        Encoding::Auto => wire_bytes(p, nnz, Encoding::Dense)
            .min(wire_bytes(p, nnz, Encoding::Sparse))
            .min(wire_bytes(p, nnz, Encoding::SparseDelta)),
        // the Rice arm is only chosen when strictly smaller than these
        Encoding::AutoQ8 => (HEADER_BYTES + QHEADER + p).min(HEADER_BYTES + QHEADER + 5 * nnz),
        Encoding::AutoQ4 => (HEADER_BYTES + QHEADER + p.div_ceil(2))
            .min(HEADER_BYTES + QHEADER + nnz * vmax + nnz.div_ceil(2)),
        Encoding::GroupedQ8 => (HEADER_BYTES + 8 * p.div_ceil(GQ8_GROUP) + p)
            .min(HEADER_BYTES + 8 * nnz.div_ceil(GQ8_GROUP) + nnz * vmax + nnz),
    }
}

/// Encode an update. `Encoding::Auto` picks the smaller representation;
/// `AutoQ8` additionally quantizes values to 8 bits (lossy).
pub fn encode_update(
    client: u32,
    round: u32,
    n_samples: u32,
    params: &[f32],
    enc: Encoding,
) -> Vec<u8> {
    encode_update_cached(client, round, n_samples, params, enc, None)
}

/// [`encode_update`] with the session's cross-round [`IndexCache`]: when
/// `cache` is `Some` and the encoding censuses the cached arm
/// (`SparseCached`, `Auto`), the set-delta against the previous round's
/// accepted index set competes by exact encoded length; `None` always
/// produces a stateless payload.
pub fn encode_update_cached(
    client: u32,
    round: u32,
    n_samples: u32,
    params: &[f32],
    enc: Encoding,
    cache: Option<&IndexCache>,
) -> Vec<u8> {
    encode_update_cached_with(
        &mut EncodeScratch::default(),
        client,
        round,
        n_samples,
        params,
        enc,
        cache,
    )
}

/// [`encode_update`] with caller-held scratch, so a worker encoding many
/// uploads reuses its temporaries instead of allocating per update.
pub fn encode_update_with(
    scratch: &mut EncodeScratch,
    client: u32,
    round: u32,
    n_samples: u32,
    params: &[f32],
    enc: Encoding,
) -> Vec<u8> {
    encode_update_cached_with(scratch, client, round, n_samples, params, enc, None)
}

/// [`encode_update_cached`] with caller-held scratch — delegates to
/// [`encode_update_cached_into`] with a fresh output buffer.
pub fn encode_update_cached_with(
    scratch: &mut EncodeScratch,
    client: u32,
    round: u32,
    n_samples: u32,
    params: &[f32],
    enc: Encoding,
    cache: Option<&IndexCache>,
) -> Vec<u8> {
    let mut out = Vec::new();
    encode_update_cached_into(scratch, &mut out, client, round, n_samples, params, enc, cache);
    out
}

/// The full-featured staged encoder every other entry point delegates
/// to, writing the frame into a caller-supplied buffer (`out` is cleared
/// first, then filled) — with a recycled buffer from
/// `runtime::bufpool::BufferPool` the steady-state frame write allocates
/// nothing. Byte-for-byte identical output to the allocating wrappers.
#[allow(clippy::too_many_arguments)]
pub fn encode_update_cached_into(
    scratch: &mut EncodeScratch,
    out: &mut Vec<u8>,
    client: u32,
    round: u32,
    n_samples: u32,
    params: &[f32],
    enc: Encoding,
    cache: Option<&IndexCache>,
) {
    let p = params.len();
    // Only the payload-dependent encodings need the varint census; the
    // flat sparse choice needs just the non-zero count, and a fixed dense
    // encode needs neither — so the (frequent) dense downlink broadcast
    // stays a straight header + memcpy with no per-element varint pass.
    let (nnz, delta_bytes) = match enc {
        Encoding::Dense => (0, 0),
        Encoding::Sparse => (params.iter().filter(|v| **v != 0.0).count(), 0),
        Encoding::SparseDelta
        | Encoding::Auto
        | Encoding::AutoQ8
        | Encoding::AutoQ4
        | Encoding::SparseCached
        | Encoding::GroupedQ8 => census(params),
    };
    // Exact body sizes (bytes after the 24-byte header's count field), so
    // the auto encodings select by true encoded length, not a heuristic.
    let body_dense = 4 * p;
    let body_sparse = 8 * nnz;
    let body_sparse_delta = delta_bytes + 4 * nnz;
    // Selection-time artifacts the write arms consume: the sparse-value
    // quantizer (+ Rice parameter) priced by the q8 census, and the cache
    // epoch the chosen cached arm echoes. Computed once, never twice.
    let mut sparse_q: Option<(Quantized, u8)> = None;
    let mut cached_epoch: Option<u32> = None;
    // Exact byte length of the tag-7 set-delta body against `cache`,
    // filling `scratch.removed` / `scratch.added` as a side effect.
    let cached_body = |scratch: &mut EncodeScratch, c: &IndexCache| {
        set_delta(&c.indices, params, &mut scratch.removed, &mut scratch.added);
        12 + delta_block_len(&scratch.removed) + delta_block_len(&scratch.added) + 4 * nnz
    };
    let (tag, body_len) = match enc {
        Encoding::Dense => (TAG_DENSE, body_dense),
        Encoding::Sparse => (TAG_SPARSE, body_sparse),
        Encoding::SparseDelta => (TAG_SPARSE_DELTA, body_sparse_delta),
        Encoding::Auto => {
            // ties break toward the earlier (simpler) representation; the
            // stateful cached arm competes last and must win strictly
            let mut best = (TAG_DENSE, body_dense);
            if body_sparse < best.1 {
                best = (TAG_SPARSE, body_sparse);
            }
            if body_sparse_delta < best.1 {
                best = (TAG_SPARSE_DELTA, body_sparse_delta);
            }
            if let Some(c) = cache {
                let len = cached_body(scratch, c);
                if len < best.1 {
                    cached_epoch = Some(c.epoch);
                    best = (TAG_SPARSE_CACHED, len);
                }
            }
            best
        }
        Encoding::AutoQ8 => {
            // price all three q8 arms from one quantization pass over the
            // non-zero values; ties break dense < sparse < rice
            scratch.vals.clear();
            scratch.vals.extend(params.iter().copied().filter(|v| *v != 0.0));
            // quantizing an empty value set: degenerate but legal (all-zero
            // upload) — a zero-range quantizer
            let q = if scratch.vals.is_empty() {
                Quantized { min: 0.0, scale: 0.0, codes: vec![] }
            } else {
                quantize(&scratch.vals).expect("finite params")
            };
            let (k, rice_len) = rice_plan(&q.codes);
            let dense_q8 = QHEADER + p;
            let sparse_q8 = QHEADER + 5 * nnz;
            let rice = QHEADER + 1 + delta_bytes + rice_len;
            let best = dense_q8.min(sparse_q8).min(rice);
            if best == dense_q8 {
                (TAG_DENSE_Q8, dense_q8)
            } else if best == sparse_q8 {
                sparse_q = Some((q, k));
                (TAG_SPARSE_Q8, sparse_q8)
            } else {
                sparse_q = Some((q, k));
                (TAG_SPARSE_RICE8, rice)
            }
        }
        Encoding::AutoQ4 => {
            let dense_q4 = QHEADER + p.div_ceil(2);
            let sparse_q4 = QHEADER + delta_bytes + nnz.div_ceil(2);
            if sparse_q4 < dense_q4 {
                (TAG_SPARSE_DELTA_Q4, sparse_q4)
            } else {
                (TAG_DENSE_Q4, dense_q4)
            }
        }
        Encoding::SparseCached => match cache {
            Some(c) => {
                let len = cached_body(scratch, c);
                if len < body_sparse_delta {
                    cached_epoch = Some(c.epoch);
                    (TAG_SPARSE_CACHED, len)
                } else {
                    // churned past the break-even point: the stateless form
                    // is at least as small, and resets nothing
                    (TAG_SPARSE_DELTA, body_sparse_delta)
                }
            }
            // no cache (first round, or invalidated): full stateless send
            None => (TAG_SPARSE_DELTA, body_sparse_delta),
        },
        Encoding::GroupedQ8 => {
            let dense_gq8 = 8 * p.div_ceil(GQ8_GROUP) + p;
            let sparse_gq8 = 8 * nnz.div_ceil(GQ8_GROUP) + delta_bytes + nnz;
            if sparse_gq8 < dense_gq8 {
                (TAG_SPARSE_GQ8, sparse_gq8)
            } else {
                (TAG_DENSE_GQ8, dense_gq8)
            }
        }
    };
    out.clear();
    out.reserve(HEADER_BYTES + body_len);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(tag);
    out.extend_from_slice(&client.to_le_bytes());
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&n_samples.to_le_bytes());
    out.extend_from_slice(&(p as u32).to_le_bytes());
    match tag {
        TAG_DENSE => {
            out.extend_from_slice(&(p as u32).to_le_bytes());
            let start = out.len();
            out.resize(start + 4 * p, 0);
            for (slot, v) in out[start..].chunks_exact_mut(4).zip(params) {
                slot.copy_from_slice(&v.to_le_bytes());
            }
        }
        TAG_SPARSE => {
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            let start = out.len();
            out.resize(start + 8 * nnz, 0);
            let mut slots = out[start..].chunks_exact_mut(8);
            for (i, &v) in params.iter().enumerate() {
                if v != 0.0 {
                    let slot = slots.next().expect("nnz slots");
                    slot[..4].copy_from_slice(&(i as u32).to_le_bytes());
                    slot[4..].copy_from_slice(&v.to_le_bytes());
                }
            }
        }
        TAG_DENSE_Q8 => {
            // quantizing an empty payload: degenerate but legal (p == 0) —
            // emit a zero-range quantizer
            let q = if params.is_empty() {
                Quantized { min: 0.0, scale: 0.0, codes: vec![] }
            } else {
                quantize(params).expect("finite params")
            };
            out.extend_from_slice(&(p as u32).to_le_bytes());
            out.extend_from_slice(&q.min.to_le_bytes());
            out.extend_from_slice(&q.scale.to_le_bytes());
            out.extend_from_slice(&q.codes);
        }
        TAG_SPARSE_Q8 => {
            let (q, _) = sparse_q.take().expect("quantizer precomputed at selection");
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            out.extend_from_slice(&q.min.to_le_bytes());
            out.extend_from_slice(&q.scale.to_le_bytes());
            let start = out.len();
            out.resize(start + 5 * nnz, 0);
            let mut slots = out[start..].chunks_exact_mut(5);
            let mut k = 0usize;
            for (i, &v) in params.iter().enumerate() {
                if v != 0.0 {
                    let slot = slots.next().expect("nnz slots");
                    slot[..4].copy_from_slice(&(i as u32).to_le_bytes());
                    slot[4] = q.codes[k];
                    k += 1;
                }
            }
        }
        TAG_SPARSE_DELTA => {
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            // varint index block: each entry is its gap from the previous
            // index (the first entry is the index itself)
            push_delta_block(&mut out, params);
            // value block: f32s in index order
            for &v in params {
                if v != 0.0 {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        TAG_DENSE_Q4 => {
            // quantizing an empty payload: degenerate but legal (p == 0)
            let q = if params.is_empty() {
                Quantized4 { min: 0.0, scale: 0.0, n: 0, packed: vec![] }
            } else {
                quantize4(params).expect("finite params")
            };
            out.extend_from_slice(&(p as u32).to_le_bytes());
            out.extend_from_slice(&q.min.to_le_bytes());
            out.extend_from_slice(&q.scale.to_le_bytes());
            out.extend_from_slice(&q.packed);
        }
        TAG_SPARSE_DELTA_Q4 => {
            scratch.vals.clear();
            scratch.vals.extend(params.iter().copied().filter(|v| *v != 0.0));
            let q = if scratch.vals.is_empty() {
                Quantized4 { min: 0.0, scale: 0.0, n: 0, packed: vec![] }
            } else {
                quantize4(&scratch.vals).expect("finite params")
            };
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            out.extend_from_slice(&q.min.to_le_bytes());
            out.extend_from_slice(&q.scale.to_le_bytes());
            push_delta_block(&mut out, params);
            out.extend_from_slice(&q.packed);
        }
        TAG_SPARSE_CACHED => {
            // count = the *resulting* support size, so cohort accounting
            // (nnz budgets, wire_bytes bounds) never needs the cache
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            out.extend_from_slice(
                &cached_epoch.expect("cache checked at selection").to_le_bytes(),
            );
            out.extend_from_slice(&(scratch.removed.len() as u32).to_le_bytes());
            out.extend_from_slice(&(scratch.added.len() as u32).to_le_bytes());
            push_index_delta_block(&mut out, &scratch.removed);
            push_index_delta_block(&mut out, &scratch.added);
            // value block: f32s in (resulting) index order
            for &v in params {
                if v != 0.0 {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        TAG_DENSE_GQ8 => {
            out.extend_from_slice(&(p as u32).to_le_bytes());
            // all group heads first (random-access decode), then all codes
            let mut codes = Vec::with_capacity(p);
            for chunk in params.chunks(GQ8_GROUP) {
                let q = quantize(chunk).expect("finite params");
                out.extend_from_slice(&q.min.to_le_bytes());
                out.extend_from_slice(&q.scale.to_le_bytes());
                codes.extend_from_slice(&q.codes);
            }
            out.extend_from_slice(&codes);
        }
        TAG_SPARSE_GQ8 => {
            scratch.vals.clear();
            scratch.vals.extend(params.iter().copied().filter(|v| *v != 0.0));
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            // groups are runs of carried values in index order, so the
            // group of the k-th value is k / GQ8_GROUP — no per-group map
            let mut codes = Vec::with_capacity(nnz);
            for chunk in scratch.vals.chunks(GQ8_GROUP) {
                let q = quantize(chunk).expect("finite params");
                out.extend_from_slice(&q.min.to_le_bytes());
                out.extend_from_slice(&q.scale.to_le_bytes());
                codes.extend_from_slice(&q.codes);
            }
            push_delta_block(&mut out, params);
            out.extend_from_slice(&codes);
        }
        TAG_SPARSE_RICE8 => {
            let (q, k) = sparse_q.take().expect("quantizer precomputed at selection");
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            out.extend_from_slice(&q.min.to_le_bytes());
            out.extend_from_slice(&q.scale.to_le_bytes());
            out.push(k);
            push_delta_block(&mut out, params);
            rice_encode(&q.codes, k, &mut out);
        }
        _ => unreachable!(),
    }
    debug_assert_eq!(
        out.len(),
        HEADER_BYTES + body_len,
        "codec: emitted size disagrees with the selection-time size formula"
    );
}

// ---------------------------------------------------------------------
// Fused mask→quantize→encode path (the client upload hot path)
// ---------------------------------------------------------------------

/// The kept (index, value) pairs of a masked update **plus the census
/// sideband accumulated in the same pass**: non-zero count, the exact
/// varint byte length of the sparse-delta index-gap block, the carried
/// value range for the quantizer grids, and a finiteness flag.
///
/// Produced directly by the selective masker's partition
/// (`fl::pipeline::mask_stream_selective`) — so no dense masked vector
/// ever exists on the upload path — and consumed by [`encode_masked`],
/// which prices every wire arm from the sideband without the second
/// census walk the staged `encode_update_*` entry points perform.
/// Entries with value exactly `0.0` are dropped at [`MaskedStream::push`]
/// (a kept-but-zero weight is indistinguishable on the wire from a
/// masked one — the same rule [`census`] applies to a dense payload).
///
/// The buffers are reused across rounds: `reset` keeps capacity, so a
/// worker holding its stream in `WorkerScratch` builds it with zero
/// steady-state allocation.
#[derive(Debug, Clone)]
pub struct MaskedStream {
    /// Full model dimension the indices address into.
    p: usize,
    /// Strictly increasing kept positions.
    indices: Vec<u32>,
    /// The kept values, all non-zero, in index order.
    values: Vec<f32>,
    /// Exact byte length of the varint index-gap block ([`census`]'s
    /// second output), accumulated per push.
    delta_bytes: usize,
    /// Running min/max over carried values (+inf / -inf while empty).
    vmin: f32,
    vmax: f32,
    /// Every carried value is finite so far (the lossy arms refuse a
    /// non-finite stream with a typed error).
    finite: bool,
}

impl Default for MaskedStream {
    fn default() -> MaskedStream {
        MaskedStream {
            p: 0,
            indices: Vec::new(),
            values: Vec::new(),
            delta_bytes: 0,
            vmin: f32::INFINITY,
            vmax: f32::NEG_INFINITY,
            finite: true,
        }
    }
}

impl MaskedStream {
    /// Clear the stream for a model of dimension `p`, keeping buffer
    /// capacity.
    pub fn reset(&mut self, p: usize) {
        self.p = p;
        self.indices.clear();
        self.values.clear();
        self.delta_bytes = 0;
        self.vmin = f32::INFINITY;
        self.vmax = f32::NEG_INFINITY;
        self.finite = true;
    }

    /// Append one kept coordinate. Indices must arrive strictly
    /// increasing and `< p` (the masker walks the model in order, so
    /// this is free); a `0.0` value is dropped, mirroring the census
    /// rule for dense payloads. Note `-0.0 == 0.0`, so negative zeros
    /// are canonicalized away — see `docs/SCALE.md` §"Hot path & memory"
    /// for the one (dense-arm) bitwise caveat this creates.
    pub fn push(&mut self, index: u32, value: f32) {
        debug_assert!((index as usize) < self.p, "stream index {index} out of range {}", self.p);
        debug_assert!(
            self.indices.last().map_or(true, |&last| last < index),
            "stream indices must be strictly increasing"
        );
        if value == 0.0 {
            return;
        }
        let delta = match self.indices.last() {
            Some(&prev) => index - prev,
            None => index,
        };
        self.delta_bytes += varint_len(delta);
        self.vmin = self.vmin.min(value);
        self.vmax = self.vmax.max(value);
        self.finite &= value.is_finite();
        self.indices.push(index);
        self.values.push(value);
    }

    /// Rebuild the stream from a dense vector — the bridge for payloads
    /// that were *not* produced by the fused masker (random masking, the
    /// HLO mask engine, tests).
    pub fn from_dense(&mut self, params: &[f32]) {
        self.reset(params.len());
        for (i, &v) in params.iter().enumerate() {
            self.push(i as u32, v);
        }
    }

    /// Carried (non-zero) entry count.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Full model dimension.
    pub fn p(&self) -> usize {
        self.p
    }

    /// The kept positions, strictly increasing.
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    /// The kept values, in index order.
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Quantizer grid over the **carried values only** — what the sparse
    /// lossy arms use. `(min, scale)`; degenerate `(0.0, 0.0)` when
    /// empty, matching the staged encoder's empty-gather special case.
    fn sparse_grid(&self, levels: f32) -> (f32, f32) {
        if self.values.is_empty() {
            (0.0, 0.0)
        } else {
            (self.vmin, grid_scale(self.vmin, self.vmax, levels))
        }
    }

    /// Quantizer grid over the **full dense vector** the stream
    /// represents — what the dense lossy arms use. When any position is
    /// zero (`nnz < p`) the staged full-vector min/max fold would have
    /// included `0.0`, so the carried range is widened to cover it;
    /// when the stream is full-support the carried range IS the vector
    /// range. Bit-identical to `quantize(params)`'s grid for finite,
    /// negative-zero-free input.
    fn dense_grid(&self, levels: f32) -> (f32, f32) {
        if self.indices.len() == self.p {
            self.sparse_grid(levels)
        } else {
            let min = self.vmin.min(0.0);
            let max = self.vmax.max(0.0);
            (min, grid_scale(min, max, levels))
        }
    }
}

/// Encode a [`MaskedStream`] — the fused-path twin of
/// [`encode_update_cached_into`]. Same selection structure, same exact
/// byte-length pricing, same tie-breaking, and (for negative-zero-free
/// input) byte-for-byte identical frames, but everything is derived from
/// the stream's census sideband in O(nnz): no dense masked vector, no
/// second census walk, and no intermediate `codes` allocation (the
/// grouped/Rice arms write through `scratch.codes`, which is reused
/// across calls). `out` is cleared, then filled.
///
/// Errors (typed, where the staged path would panic): a non-finite
/// carried value under a lossy encoding.
#[allow(clippy::too_many_arguments)]
pub fn encode_masked(
    scratch: &mut EncodeScratch,
    out: &mut Vec<u8>,
    client: u32,
    round: u32,
    n_samples: u32,
    stream: &MaskedStream,
    enc: Encoding,
    cache: Option<&IndexCache>,
) -> Result<()> {
    let p = stream.p;
    let nnz = stream.indices.len();
    let delta_bytes = stream.delta_bytes;
    if !stream.finite
        && matches!(enc, Encoding::AutoQ8 | Encoding::AutoQ4 | Encoding::GroupedQ8)
    {
        return Err(Error::invalid("cannot quantize non-finite values"));
    }
    let body_dense = 4 * p;
    let body_sparse = 8 * nnz;
    let body_sparse_delta = delta_bytes + 4 * nnz;
    let mut rice_k = 0u8;
    let mut cached_epoch: Option<u32> = None;
    // Exact byte length of the tag-7 set-delta body against `cache`,
    // filling `scratch.removed` / `scratch.added` as a side effect — the
    // same core the staged encoder prices with ([`set_delta_iter`]).
    let cached_body = |scratch: &mut EncodeScratch, c: &IndexCache| {
        set_delta_iter(
            &c.indices,
            stream.indices.iter().copied(),
            &mut scratch.removed,
            &mut scratch.added,
        );
        12 + delta_block_len(&scratch.removed) + delta_block_len(&scratch.added) + 4 * nnz
    };
    let (tag, body_len) = match enc {
        Encoding::Dense => (TAG_DENSE, body_dense),
        Encoding::Sparse => (TAG_SPARSE, body_sparse),
        Encoding::SparseDelta => (TAG_SPARSE_DELTA, body_sparse_delta),
        Encoding::Auto => {
            // ties break toward the earlier (simpler) representation; the
            // stateful cached arm competes last and must win strictly
            let mut best = (TAG_DENSE, body_dense);
            if body_sparse < best.1 {
                best = (TAG_SPARSE, body_sparse);
            }
            if body_sparse_delta < best.1 {
                best = (TAG_SPARSE_DELTA, body_sparse_delta);
            }
            if let Some(c) = cache {
                let len = cached_body(scratch, c);
                if len < best.1 {
                    cached_epoch = Some(c.epoch);
                    best = (TAG_SPARSE_CACHED, len);
                }
            }
            best
        }
        Encoding::AutoQ8 => {
            // the carried-value quantizer falls straight out of the
            // sideband's (vmin, vmax) — no gather, codes into scratch
            let (min, scale) = stream.sparse_grid(255.0);
            scratch.codes.clear();
            scratch
                .codes
                .extend(stream.values.iter().map(|&v| grid_code(v, min, scale, 255)));
            let (k, rice_len) = rice_plan(&scratch.codes);
            let dense_q8 = QHEADER + p;
            let sparse_q8 = QHEADER + 5 * nnz;
            let rice = QHEADER + 1 + delta_bytes + rice_len;
            let best = dense_q8.min(sparse_q8).min(rice);
            if best == dense_q8 {
                (TAG_DENSE_Q8, dense_q8)
            } else if best == sparse_q8 {
                (TAG_SPARSE_Q8, sparse_q8)
            } else {
                rice_k = k;
                (TAG_SPARSE_RICE8, rice)
            }
        }
        Encoding::AutoQ4 => {
            let dense_q4 = QHEADER + p.div_ceil(2);
            let sparse_q4 = QHEADER + delta_bytes + nnz.div_ceil(2);
            if sparse_q4 < dense_q4 {
                (TAG_SPARSE_DELTA_Q4, sparse_q4)
            } else {
                (TAG_DENSE_Q4, dense_q4)
            }
        }
        Encoding::SparseCached => match cache {
            Some(c) => {
                let len = cached_body(scratch, c);
                if len < body_sparse_delta {
                    cached_epoch = Some(c.epoch);
                    (TAG_SPARSE_CACHED, len)
                } else {
                    (TAG_SPARSE_DELTA, body_sparse_delta)
                }
            }
            None => (TAG_SPARSE_DELTA, body_sparse_delta),
        },
        Encoding::GroupedQ8 => {
            let dense_gq8 = 8 * p.div_ceil(GQ8_GROUP) + p;
            let sparse_gq8 = 8 * nnz.div_ceil(GQ8_GROUP) + delta_bytes + nnz;
            if sparse_gq8 < dense_gq8 {
                (TAG_SPARSE_GQ8, sparse_gq8)
            } else {
                (TAG_DENSE_GQ8, dense_gq8)
            }
        }
    };
    out.clear();
    out.reserve(HEADER_BYTES + body_len);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(tag);
    out.extend_from_slice(&client.to_le_bytes());
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&n_samples.to_le_bytes());
    out.extend_from_slice(&(p as u32).to_le_bytes());
    match tag {
        TAG_DENSE => {
            // zero-fill + scatter: positions the stream dropped are
            // 0.0f32's bit pattern (this is where a `-0.0` in the
            // original vector canonicalizes to `+0.0`)
            out.extend_from_slice(&(p as u32).to_le_bytes());
            let start = out.len();
            out.resize(start + 4 * p, 0);
            for (&idx, &v) in stream.indices.iter().zip(&stream.values) {
                let at = start + 4 * idx as usize;
                out[at..at + 4].copy_from_slice(&v.to_le_bytes());
            }
        }
        TAG_SPARSE => {
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            let start = out.len();
            out.resize(start + 8 * nnz, 0);
            let pairs = stream.indices.iter().zip(&stream.values);
            for (slot, (&idx, &v)) in out[start..].chunks_exact_mut(8).zip(pairs) {
                slot[..4].copy_from_slice(&idx.to_le_bytes());
                slot[4..].copy_from_slice(&v.to_le_bytes());
            }
        }
        TAG_SPARSE_DELTA => {
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            push_index_delta_block(out, &stream.indices);
            for &v in &stream.values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        TAG_DENSE_Q8 => {
            let (min, scale) = stream.dense_grid(255.0);
            out.extend_from_slice(&(p as u32).to_le_bytes());
            out.extend_from_slice(&min.to_le_bytes());
            out.extend_from_slice(&scale.to_le_bytes());
            // fill with the zero-value's code, then scatter kept codes
            let start = out.len();
            out.resize(start + p, grid_code(0.0, min, scale, 255));
            for (&idx, &v) in stream.indices.iter().zip(&stream.values) {
                out[start + idx as usize] = grid_code(v, min, scale, 255);
            }
        }
        TAG_SPARSE_Q8 => {
            let (min, scale) = stream.sparse_grid(255.0);
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            out.extend_from_slice(&min.to_le_bytes());
            out.extend_from_slice(&scale.to_le_bytes());
            let start = out.len();
            out.resize(start + 5 * nnz, 0);
            let pairs = stream.indices.iter().zip(&scratch.codes);
            for (slot, (&idx, &code)) in out[start..].chunks_exact_mut(5).zip(pairs) {
                slot[..4].copy_from_slice(&idx.to_le_bytes());
                slot[4] = code;
            }
        }
        TAG_DENSE_Q4 => {
            let (min, scale) = stream.dense_grid(15.0);
            out.extend_from_slice(&(p as u32).to_le_bytes());
            out.extend_from_slice(&min.to_le_bytes());
            out.extend_from_slice(&scale.to_le_bytes());
            let zero = grid_code(0.0, min, scale, 15);
            let start = out.len();
            out.resize(start + p.div_ceil(2), zero | (zero << 4));
            if p % 2 == 1 {
                // the unused high nibble of an odd-length tensor's last
                // byte must be zero on the wire
                if let Some(last) = out.last_mut() {
                    *last = zero;
                }
            }
            for (&idx, &v) in stream.indices.iter().zip(&stream.values) {
                let i = idx as usize;
                let shift = 4 * (i & 1) as u8;
                let slot = &mut out[start + i / 2];
                *slot = (*slot & !(0x0f << shift)) | (grid_code(v, min, scale, 15) << shift);
            }
        }
        TAG_SPARSE_DELTA_Q4 => {
            let (min, scale) = stream.sparse_grid(15.0);
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            out.extend_from_slice(&min.to_le_bytes());
            out.extend_from_slice(&scale.to_le_bytes());
            push_index_delta_block(out, &stream.indices);
            let start = out.len();
            out.resize(start + nnz.div_ceil(2), 0);
            for (k, &v) in stream.values.iter().enumerate() {
                out[start + k / 2] |= grid_code(v, min, scale, 15) << (4 * (k & 1));
            }
        }
        TAG_SPARSE_CACHED => {
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            out.extend_from_slice(
                &cached_epoch.expect("cache checked at selection").to_le_bytes(),
            );
            out.extend_from_slice(&(scratch.removed.len() as u32).to_le_bytes());
            out.extend_from_slice(&(scratch.added.len() as u32).to_le_bytes());
            push_index_delta_block(out, &scratch.removed);
            push_index_delta_block(out, &scratch.added);
            for &v in &stream.values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        TAG_DENSE_GQ8 => {
            out.extend_from_slice(&(p as u32).to_le_bytes());
            // pass 1: per-group grids — heads to the wire, (min, scale)
            // pairs into the scratch value buffer for the code pass. A
            // group with no kept entry is all-zero (scale 0); a partially
            // kept group widens its carried range over 0.0, exactly like
            // the staged full-chunk fold.
            scratch.vals.clear();
            let ngroups = p.div_ceil(GQ8_GROUP);
            let mut cur = 0usize;
            for g in 0..ngroups {
                let lo = g * GQ8_GROUP;
                let hi = (lo + GQ8_GROUP).min(p);
                let begin = cur;
                while cur < nnz && (stream.indices[cur] as usize) < hi {
                    cur += 1;
                }
                let kept = cur - begin;
                let (mn, mx) = if kept == 0 {
                    (0.0f32, 0.0f32)
                } else {
                    let mut mn = f32::INFINITY;
                    let mut mx = f32::NEG_INFINITY;
                    for &v in &stream.values[begin..cur] {
                        mn = mn.min(v);
                        mx = mx.max(v);
                    }
                    if kept < hi - lo {
                        (mn.min(0.0), mx.max(0.0))
                    } else {
                        (mn, mx)
                    }
                };
                let scale = grid_scale(mn, mx, 255.0);
                out.extend_from_slice(&mn.to_le_bytes());
                out.extend_from_slice(&scale.to_le_bytes());
                scratch.vals.push(mn);
                scratch.vals.push(scale);
            }
            // pass 2: codes written straight into the frame — the staged
            // arm's per-call `codes` vector does not exist here
            let start = out.len();
            out.resize(start + p, 0);
            let mut cur = 0usize;
            for g in 0..ngroups {
                let lo = g * GQ8_GROUP;
                let hi = (lo + GQ8_GROUP).min(p);
                let mn = scratch.vals[2 * g];
                let scale = scratch.vals[2 * g + 1];
                let zero = grid_code(0.0, mn, scale, 255);
                if zero != 0 {
                    out[start + lo..start + hi].fill(zero);
                }
                while cur < nnz && (stream.indices[cur] as usize) < hi {
                    out[start + stream.indices[cur] as usize] =
                        grid_code(stream.values[cur], mn, scale, 255);
                    cur += 1;
                }
            }
        }
        TAG_SPARSE_GQ8 => {
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            // groups are runs of carried values in index order; heads to
            // the wire, codes into scratch (reused, not allocated)
            scratch.codes.clear();
            for chunk in stream.values.chunks(GQ8_GROUP) {
                let mut mn = f32::INFINITY;
                let mut mx = f32::NEG_INFINITY;
                for &v in chunk {
                    mn = mn.min(v);
                    mx = mx.max(v);
                }
                let scale = grid_scale(mn, mx, 255.0);
                out.extend_from_slice(&mn.to_le_bytes());
                out.extend_from_slice(&scale.to_le_bytes());
                scratch.codes.extend(chunk.iter().map(|&v| grid_code(v, mn, scale, 255)));
            }
            push_index_delta_block(out, &stream.indices);
            out.extend_from_slice(&scratch.codes);
        }
        TAG_SPARSE_RICE8 => {
            let (min, scale) = stream.sparse_grid(255.0);
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            out.extend_from_slice(&min.to_le_bytes());
            out.extend_from_slice(&scale.to_le_bytes());
            out.push(rice_k);
            push_index_delta_block(out, &stream.indices);
            rice_encode(&scratch.codes, rice_k, out);
        }
        _ => unreachable!(),
    }
    debug_assert_eq!(
        out.len(),
        HEADER_BYTES + body_len,
        "codec: fused emitted size disagrees with the selection-time size formula"
    );
    Ok(())
}

/// Append the varint delta-coded index block for `params`' non-zero
/// positions: first entry is the index itself, each later entry the
/// (strictly positive) gap from the previous index.
fn push_delta_block(out: &mut Vec<u8>, params: &[f32]) {
    let mut prev = 0u32;
    let mut first = true;
    for (i, &v) in params.iter().enumerate() {
        if v != 0.0 {
            let delta = if first { i as u32 } else { i as u32 - prev };
            push_varint(out, delta);
            prev = i as u32;
            first = false;
        }
    }
}

/// [`push_delta_block`] over an explicit (strictly increasing) index list
/// rather than a dense payload's non-zero positions — the tag-7 removed /
/// added blocks.
fn push_index_delta_block(out: &mut Vec<u8>, indices: &[u32]) {
    let mut prev = 0u32;
    let mut first = true;
    for &i in indices {
        push_varint(out, if first { i } else { i - prev });
        prev = i;
        first = false;
    }
}

/// Exact byte length [`push_index_delta_block`] will emit for `indices`.
fn delta_block_len(indices: &[u32]) -> usize {
    let mut prev = 0u32;
    let mut first = true;
    let mut n = 0usize;
    for &i in indices {
        n += varint_len(if first { i } else { i - prev });
        prev = i;
        first = false;
    }
    n
}

/// Two-pointer set difference of the cached index set against a strictly
/// increasing support iterator: `removed` = cached positions no longer in
/// the support, `added` = support positions absent from the cache. Both
/// outputs come out sorted and disjoint — the canonical tag-7 set-delta.
/// One core serves both the staged encoder (support = a dense payload's
/// non-zero positions) and the fused encoder (support = the
/// [`MaskedStream`]'s index list), so the two emit identical blocks.
fn set_delta_iter(
    cached: &[u32],
    support: impl Iterator<Item = u32>,
    removed: &mut Vec<u32>,
    added: &mut Vec<u32>,
) {
    removed.clear();
    added.clear();
    let mut ci = 0usize;
    for idx in support {
        while ci < cached.len() && cached[ci] < idx {
            removed.push(cached[ci]);
            ci += 1;
        }
        if ci < cached.len() && cached[ci] == idx {
            ci += 1; // retained: carried by neither block
        } else {
            added.push(idx);
        }
    }
    removed.extend_from_slice(&cached[ci..]);
}

/// [`set_delta_iter`] over a dense payload's non-zero support.
fn set_delta(cached: &[u32], params: &[f32], removed: &mut Vec<u32>, added: &mut Vec<u32>) {
    let support = params
        .iter()
        .enumerate()
        .filter(|(_, v)| **v != 0.0)
        .map(|(i, _)| i as u32);
    set_delta_iter(cached, support, removed, added);
}

fn take<const N: usize>(data: &[u8], at: &mut usize) -> Result<[u8; N]> {
    let slice = data
        .get(*at..*at + N)
        .ok_or_else(|| Error::parse("codec: truncated message"))?;
    *at += N;
    slice
        .try_into()
        .map_err(|_| Error::parse("codec: truncated message"))
}

/// One byte at `at`, advancing the cursor.
fn take1(data: &[u8], at: &mut usize) -> Result<u8> {
    let [b] = take::<1>(data, at)?;
    Ok(b)
}

/// `f32` from a little-endian chunk (zero-padded if short; every caller
/// passes exact 4-byte chunks from `chunks_exact` / `split_at`).
fn le_f32(c: &[u8]) -> f32 {
    let mut b = [0u8; 4];
    for (d, s) in b.iter_mut().zip(c) {
        *d = *s;
    }
    f32::from_le_bytes(b)
}

/// `u32` from a little-endian chunk (zero-padded if short).
fn le_u32(c: &[u8]) -> u32 {
    let mut b = [0u8; 4];
    for (d, s) in b.iter_mut().zip(c) {
        *d = *s;
    }
    u32::from_le_bytes(b)
}

/// Grab the `len`-byte body slice at `at`, advancing the cursor.
fn body<'a>(data: &'a [u8], at: &mut usize, len: usize) -> Result<&'a [u8]> {
    let slice = data
        .get(*at..*at + len)
        .ok_or_else(|| Error::parse("codec: truncated message"))?;
    *at += len;
    Ok(slice)
}

struct Header {
    client: u32,
    round: u32,
    n_samples: u32,
    p: usize,
    sparse: bool,
}

/// Shared decode core: parses `data` into `scratch` (dense body into
/// `scratch.dense`, sparse body into `scratch.indices`/`scratch.values`)
/// and returns the header. Sparse indices are required to be in-range and
/// strictly increasing. `cache` is the session's cross-round index set: a
/// tag-7 (`SparseCached`) body is decoded against it — and is a typed
/// parse error when it is absent or its epoch disagrees. The cache is
/// read-only here by construction: a rejected decode can never leave it
/// partially mutated, because nothing in this path writes to it at all.
fn decode_into(
    data: &[u8],
    scratch: &mut DecodeScratch,
    cache: Option<&IndexCache>,
) -> Result<Header> {
    let mut at = 0usize;
    let magic = u16::from_le_bytes(take::<2>(data, &mut at)?);
    if magic != MAGIC {
        return Err(Error::parse(format!("codec: bad magic {magic:#x}")));
    }
    let version = take1(data, &mut at)?;
    if version != VERSION {
        return Err(Error::parse(format!("codec: unsupported version {version}")));
    }
    let tag = take1(data, &mut at)?;
    let client = u32::from_le_bytes(take::<4>(data, &mut at)?);
    let round = u32::from_le_bytes(take::<4>(data, &mut at)?);
    let n_samples = u32::from_le_bytes(take::<4>(data, &mut at)?);
    let p = u32::from_le_bytes(take::<4>(data, &mut at)?) as usize;
    let count = u32::from_le_bytes(take::<4>(data, &mut at)?) as usize;
    scratch.dense.clear();
    scratch.indices.clear();
    scratch.values.clear();
    let sparse = match tag {
        TAG_DENSE => {
            if count != p {
                return Err(Error::parse("codec: dense count != p"));
            }
            let b = body(data, &mut at, 4 * p)?;
            scratch.dense.reserve(p);
            scratch.dense.extend(b.chunks_exact(4).map(le_f32));
            false
        }
        TAG_SPARSE => {
            if count > p {
                return Err(Error::parse("codec: sparse count > p"));
            }
            let b = body(data, &mut at, 8 * count)?;
            scratch.indices.reserve(count);
            scratch.values.reserve(count);
            let mut next_min = 0u32;
            for entry in b.chunks_exact(8) {
                let (iw, vw) = entry.split_at(4);
                let idx = le_u32(iw);
                let val = le_f32(vw);
                check_sparse_index(idx, next_min, p)?;
                next_min = idx + 1;
                scratch.indices.push(idx);
                scratch.values.push(val);
            }
            true
        }
        TAG_DENSE_Q8 => {
            if count != p {
                return Err(Error::parse("codec: dense-q8 count != p"));
            }
            let min = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let scale = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let codes = body(data, &mut at, p)?;
            scratch.dense.reserve(p);
            scratch.dense.extend(codes.iter().map(|&c| min + scale * c as f32));
            false
        }
        TAG_SPARSE_Q8 => {
            if count > p {
                return Err(Error::parse("codec: sparse count > p"));
            }
            let min = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let scale = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let b = body(data, &mut at, 5 * count)?;
            scratch.indices.reserve(count);
            scratch.values.reserve(count);
            let mut next_min = 0u32;
            for entry in b.chunks_exact(5) {
                let (iw, code) = entry.split_at(4);
                let idx = le_u32(iw);
                check_sparse_index(idx, next_min, p)?;
                next_min = idx + 1;
                scratch.indices.push(idx);
                let c = code.first().copied().unwrap_or(0);
                scratch.values.push(min + scale * c as f32);
            }
            true
        }
        TAG_SPARSE_DELTA => {
            if count > p {
                return Err(Error::parse("codec: sparse count > p"));
            }
            // Each entry costs at least 1 varint byte + 4 value bytes: a
            // count the remaining payload cannot possibly hold is rejected
            // *before* the index buffer is reserved — a hostile header must
            // never size an allocation (the other sparse tags get this for
            // free from their fixed-size `body()` bound).
            if data.len().saturating_sub(at) < count.saturating_mul(5) {
                return Err(Error::parse("codec: truncated message"));
            }
            read_delta_block(data, &mut at, count, p, &mut scratch.indices)?;
            let b = body(data, &mut at, 4 * count)?;
            scratch.values.reserve(count);
            scratch.values.extend(b.chunks_exact(4).map(le_f32));
            true
        }
        TAG_DENSE_Q4 => {
            if count != p {
                return Err(Error::parse("codec: dense-q4 count != p"));
            }
            let min = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let scale = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let codes = body(data, &mut at, p.div_ceil(2))?;
            check_q4_padding(codes, p)?;
            scratch.dense.reserve(p);
            scratch
                .dense
                .extend((0..p).map(|k| min + scale * q4_code(codes, k) as f32));
            false
        }
        TAG_SPARSE_DELTA_Q4 => {
            if count > p {
                return Err(Error::parse("codec: sparse count > p"));
            }
            let min = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let scale = f32::from_le_bytes(take::<4>(data, &mut at)?);
            // >= 1 varint byte per entry + ceil(count/2) nibble bytes must
            // still follow; reject impossible counts before reserving
            if data.len().saturating_sub(at) < count.saturating_mul(3).div_ceil(2) {
                return Err(Error::parse("codec: truncated message"));
            }
            read_delta_block(data, &mut at, count, p, &mut scratch.indices)?;
            let codes = body(data, &mut at, count.div_ceil(2))?;
            check_q4_padding(codes, count)?;
            scratch.values.reserve(count);
            scratch
                .values
                .extend((0..count).map(|k| min + scale * q4_code(codes, k) as f32));
            true
        }
        TAG_SPARSE_CACHED => {
            let cache = cache.ok_or_else(|| {
                Error::parse("codec: sparse-cached payload but no index cache for this session")
            })?;
            if count > p {
                return Err(Error::parse("codec: sparse count > p"));
            }
            let epoch = u32::from_le_bytes(take::<4>(data, &mut at)?);
            if epoch != cache.epoch {
                return Err(Error::parse(format!(
                    "codec: cache epoch mismatch (payload {epoch}, session {})",
                    cache.epoch
                )));
            }
            let n_removed = u32::from_le_bytes(take::<4>(data, &mut at)?) as usize;
            let n_added = u32::from_le_bytes(take::<4>(data, &mut at)?) as usize;
            if n_removed > cache.indices.len() {
                return Err(Error::parse(
                    "codec: more removed indices than the cached set holds",
                ));
            }
            if cache.indices.len() - n_removed + n_added != count {
                return Err(Error::parse(
                    "codec: cached set-delta does not produce the declared count",
                ));
            }
            // each removed/added entry costs >= 1 varint byte and each
            // resulting entry 4 value bytes: reject impossible counts
            // before anything reserves
            if data.len().saturating_sub(at)
                < n_removed
                    .saturating_add(n_added)
                    .saturating_add(count.saturating_mul(4))
            {
                return Err(Error::parse("codec: truncated message"));
            }
            scratch.removed.clear();
            scratch.added.clear();
            read_delta_block(data, &mut at, n_removed, p, &mut scratch.removed)?;
            read_delta_block(data, &mut at, n_added, p, &mut scratch.added)?;
            merge_cached_indices(
                &cache.indices,
                &scratch.removed,
                &scratch.added,
                &mut scratch.indices,
            )?;
            let b = body(data, &mut at, 4 * count)?;
            scratch.values.reserve(count);
            scratch.values.extend(b.chunks_exact(4).map(le_f32));
            true
        }
        TAG_DENSE_GQ8 => {
            if count != p {
                return Err(Error::parse("codec: dense-gq8 count != p"));
            }
            let n_groups = p.div_ceil(GQ8_GROUP);
            let heads = body(data, &mut at, 8 * n_groups)?;
            let codes = body(data, &mut at, p)?;
            scratch.dense.reserve(p);
            // `heads` holds exactly `n_groups` 8-byte quantizer heads and
            // `codes.chunks` yields exactly `n_groups` chunks: zip pairs
            // each group with its head with no arithmetic indexing.
            for (h, chunk) in heads.chunks_exact(8).zip(codes.chunks(GQ8_GROUP)) {
                let (lo, hi) = h.split_at(4);
                let (min, scale) = (le_f32(lo), le_f32(hi));
                scratch.dense.extend(chunk.iter().map(|&c| min + scale * c as f32));
            }
            false
        }
        TAG_SPARSE_GQ8 => {
            if count > p {
                return Err(Error::parse("codec: sparse count > p"));
            }
            let n_groups = count.div_ceil(GQ8_GROUP);
            // >= 8 bytes per group head, 1 varint byte + 1 code byte per
            // entry; reject impossible counts before reserving
            if data.len().saturating_sub(at)
                < n_groups
                    .saturating_mul(8)
                    .saturating_add(count.saturating_mul(2))
            {
                return Err(Error::parse("codec: truncated message"));
            }
            let heads = body(data, &mut at, 8 * n_groups)?;
            read_delta_block(data, &mut at, count, p, &mut scratch.indices)?;
            let codes = body(data, &mut at, count)?;
            scratch.values.reserve(count);
            for (h, chunk) in heads.chunks_exact(8).zip(codes.chunks(GQ8_GROUP)) {
                let (lo, hi) = h.split_at(4);
                let (min, scale) = (le_f32(lo), le_f32(hi));
                scratch.values.extend(chunk.iter().map(|&c| min + scale * c as f32));
            }
            true
        }
        TAG_SPARSE_RICE8 => {
            if count > p {
                return Err(Error::parse("codec: sparse count > p"));
            }
            let min = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let scale = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let k = take1(data, &mut at)?;
            if k > RICE_MAX_K {
                return Err(Error::parse(format!(
                    "codec: rice parameter {k} exceeds {RICE_MAX_K}"
                )));
            }
            // >= 1 varint byte per entry + (1 + k) coded bits per entry;
            // reject impossible counts before reserving
            if data.len().saturating_sub(at)
                < count.saturating_add(count.saturating_mul(1 + k as usize).div_ceil(8))
            {
                return Err(Error::parse("codec: truncated message"));
            }
            read_delta_block(data, &mut at, count, p, &mut scratch.indices)?;
            // the Rice stream is everything that remains: rice_decode
            // consumes the slice exactly, rejecting truncation, overlong
            // streams, and non-zero padding bits
            scratch.codes.clear();
            scratch.codes.reserve(count);
            rice_decode(data.get(at..).unwrap_or(&[]), count, k, &mut scratch.codes)?;
            at = data.len();
            scratch.values.reserve(count);
            scratch
                .values
                .extend(scratch.codes.iter().map(|&c| min + scale * c as f32));
            true
        }
        other => return Err(Error::parse(format!("codec: unknown tag {other}"))),
    };
    if at != data.len() {
        return Err(Error::parse("codec: trailing bytes"));
    }
    Ok(Header {
        client,
        round,
        n_samples,
        p,
        sparse,
    })
}

fn check_sparse_index(idx: u32, next_min: u32, p: usize) -> Result<()> {
    if idx as usize >= p {
        return Err(Error::parse(format!("codec: index {idx} >= p {p}")));
    }
    if idx < next_min {
        return Err(Error::parse(format!(
            "codec: sparse index {idx} duplicate or out of order"
        )));
    }
    Ok(())
}

/// Decode `count` varint index deltas at `at` into absolute indices,
/// enforcing the sparse invariants as it goes: every varint canonical, a
/// zero gap after the first entry is non-monotone (a duplicate index),
/// accumulation must not overflow u32, and every index stays inside
/// `[0, p)`.
fn read_delta_block(
    data: &[u8],
    at: &mut usize,
    count: usize,
    p: usize,
    indices: &mut Vec<u32>,
) -> Result<()> {
    indices.reserve(count);
    let mut next_min = 0u32;
    for k in 0..count {
        let delta = read_varint(data, at)?;
        let idx = if k == 0 {
            delta
        } else {
            // prev index is next_min - 1; a zero delta lands on prev and is
            // rejected by the monotonicity check below
            (next_min - 1).checked_add(delta).ok_or_else(|| {
                Error::parse("codec: sparse-delta index overflows u32")
            })?
        };
        check_sparse_index(idx, next_min, p)?;
        next_min = idx + 1;
        indices.push(idx);
    }
    Ok(())
}

/// Apply a tag-7 set-delta to the session's cached index set:
/// `out = (cached \ removed) ∪ added`, strictly increasing. Strict on the
/// delta's shape: every removed index must be present in the cached set,
/// no added index may already be in it, and an index that is both removed
/// and re-added is non-canonical (the encoder ships it as retained) — all
/// typed parse errors. `cached` itself is never written.
fn merge_cached_indices(
    cached: &[u32],
    removed: &[u32],
    added: &[u32],
    out: &mut Vec<u32>,
) -> Result<()> {
    out.clear();
    out.reserve(cached.len().saturating_sub(removed.len()) + added.len());
    let mut remit = removed.iter().copied().peekable();
    let mut addit = added.iter().copied().peekable();
    for &c in cached {
        // emit additions sorting before this cached index first, so the
        // equality probes below are exact
        while let Some(a) = addit.next_if(|&a| a < c) {
            out.push(a);
        }
        if remit.next_if(|&r| r == c).is_some() {
            if addit.next_if(|&a| a == c).is_some() {
                return Err(Error::parse(
                    "codec: index both removed and re-added (non-canonical set-delta)",
                ));
            }
            continue;
        }
        if addit.next_if(|&a| a == c).is_some() {
            return Err(Error::parse("codec: added index collides with cached set"));
        }
        out.push(c);
    }
    // both lists are sorted, so any removal not consumed above names an
    // index the cached set does not hold
    if remit.next().is_some() {
        return Err(Error::parse("codec: removed index not in cached set"));
    }
    out.extend(addit);
    Ok(())
}

/// An odd-count q4 body carries one unused high nibble in its final byte;
/// the encoder always leaves it zero, so anything else is a malformed (or
/// non-canonical) message.
fn check_q4_padding(codes: &[u8], n: usize) -> Result<()> {
    // for odd n the final byte (index n/2) is the last one of the body,
    // whose length the caller already bounded to ceil(n/2)
    if n % 2 == 1 && codes.last().is_some_and(|&b| b >> 4 != 0) {
        return Err(Error::parse("codec: q4 padding nibble must be zero"));
    }
    Ok(())
}

/// Decode an update message produced by [`encode_update`] into an owned
/// [`WireUpdate`]. Sparse bodies stay sparse. Stateless: a tag-7
/// (`SparseCached`) payload is a typed parse error here — use
/// [`decode_update_cached`] with the session's cache.
pub fn decode_update(data: &[u8]) -> Result<WireUpdate> {
    decode_update_cached(data, None)
}

/// [`decode_update`] with the session's cross-round [`IndexCache`] (pass
/// `None` for a session without one — equivalent to [`decode_update`]).
pub fn decode_update_cached(data: &[u8], cache: Option<&IndexCache>) -> Result<WireUpdate> {
    let mut scratch = DecodeScratch::default();
    let h = decode_into(data, &mut scratch, cache)?;
    let body = if h.sparse {
        DecodedBody::Sparse {
            indices: std::mem::take(&mut scratch.indices),
            values: std::mem::take(&mut scratch.values),
        }
    } else {
        DecodedBody::Dense(std::mem::take(&mut scratch.dense))
    };
    Ok(WireUpdate {
        client: h.client,
        round: h.round,
        n_samples: h.n_samples,
        p: h.p,
        body,
    })
}

/// Decode an update into caller-held scratch, returning a borrowed view.
/// The server's aggregation loop uses this: one [`DecodeScratch`] held
/// across all payloads of all rounds means zero decode allocations at
/// steady state.
pub fn decode_update_view<'a>(
    data: &[u8],
    scratch: &'a mut DecodeScratch,
) -> Result<WireView<'a>> {
    decode_update_view_cached(data, scratch, None)
}

/// [`decode_update_view`] with the session's cross-round [`IndexCache`].
/// The cache is read-only: a rejected decode leaves it bitwise-identical
/// (the caller only ever *replaces* its session's cache after an accepted
/// fold, never mutates it through this path).
pub fn decode_update_view_cached<'a>(
    data: &[u8],
    scratch: &'a mut DecodeScratch,
    cache: Option<&IndexCache>,
) -> Result<WireView<'a>> {
    let h = decode_into(data, scratch, cache)?;
    let body = if h.sparse {
        BodyView::Sparse {
            indices: &scratch.indices,
            values: &scratch.values,
        }
    } else {
        BodyView::Dense(&scratch.dense)
    };
    Ok(WireView {
        client: h.client,
        round: h.round,
        n_samples: h.n_samples,
        p: h.p,
        body,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn sample_params(g: &mut Gen, p: usize, density: f32) -> Vec<f32> {
        (0..p)
            .map(|_| {
                if g.f32_in(0.0, 1.0) < density {
                    g.f32_in(-2.0, 2.0)
                } else {
                    0.0
                }
            })
            .collect()
    }

    #[test]
    fn dense_roundtrip() {
        let params: Vec<f32> = (0..100).map(|i| i as f32 * 0.5 - 10.0).collect();
        let bytes = encode_update(3, 7, 256, &params, Encoding::Dense);
        let u = decode_update(&bytes).unwrap();
        assert_eq!(u.client, 3);
        assert_eq!(u.round, 7);
        assert_eq!(u.n_samples, 256);
        assert_eq!(u.p, 100);
        assert_eq!(u.body, DecodedBody::Dense(params.clone()));
        assert_eq!(u.to_dense(), params);
        assert_eq!(bytes.len(), wire_bytes(100, 100, Encoding::Dense));
    }

    #[test]
    fn sparse_roundtrip_preserves_zeros_without_densifying() {
        let mut params = vec![0.0f32; 1000];
        params[13] = 1.5;
        params[999] = -2.25;
        let bytes = encode_update(0, 0, 1, &params, Encoding::Sparse);
        assert_eq!(bytes.len(), wire_bytes(1000, 2, Encoding::Sparse));
        let u = decode_update(&bytes).unwrap();
        // the body stays sparse: exactly the two carried entries
        assert_eq!(
            u.body,
            DecodedBody::Sparse {
                indices: vec![13, 999],
                values: vec![1.5, -2.25],
            }
        );
        assert_eq!(u.nnz(), 2);
        assert_eq!(u.to_dense(), params);
    }

    #[test]
    fn view_decode_reuses_scratch_and_matches_owned() {
        let mut scratch = DecodeScratch::default();
        let mut g = Gen::new(0x5c4a);
        for _ in 0..20 {
            let p = g.usize_in(1, 500);
            let density = g.f32_in(0.0, 1.0);
            let params = sample_params(&mut g, p, density);
            for &enc in Encoding::ALL {
                let bytes = encode_update(1, 2, 3, &params, enc);
                let owned = decode_update(&bytes).unwrap();
                let view = decode_update_view(&bytes, &mut scratch).unwrap();
                assert_eq!(view.client, owned.client);
                assert_eq!(view.p, owned.p);
                match (&view.body, &owned.body) {
                    (BodyView::Dense(a), DecodedBody::Dense(b)) => assert_eq!(*a, &b[..]),
                    (
                        BodyView::Sparse { indices: ia, values: va },
                        DecodedBody::Sparse { indices: ib, values: vb },
                    ) => {
                        assert_eq!(*ia, &ib[..]);
                        assert_eq!(*va, &vb[..]);
                    }
                    (a, b) => panic!("body shape mismatch: {a:?} vs {b:?}"),
                }
            }
        }
    }

    #[test]
    fn auto_picks_smallest_lossless_representation() {
        // every coordinate non-zero: dense (424) beats sparse (824) and
        // sparse-delta (524: 100 one-byte gaps + 400 value bytes)
        let dense_heavy: Vec<f32> = (0..100).map(|i| (i + 1) as f32).collect();
        let b1 = encode_update(0, 0, 1, &dense_heavy, Encoding::Auto);
        assert_eq!(b1.len(), wire_bytes(100, 100, Encoding::Dense));

        // one non-zero: sparse-delta (24 + 1 varint + 4 value = 29) beats
        // sparse f32 (32) beats dense (424)
        let mut sparse_heavy = vec![0.0f32; 100];
        sparse_heavy[5] = 1.0;
        let b2 = encode_update(0, 0, 1, &sparse_heavy, Encoding::Auto);
        let sd = encode_update(0, 0, 1, &sparse_heavy, Encoding::SparseDelta);
        assert_eq!(b2.len(), sd.len());
        assert_eq!(b2.len(), HEADER_BYTES + 1 + 4);
        assert!(b2.len() < wire_bytes(100, 1, Encoding::Sparse));
        assert!(b2.len() < wire_bytes(100, 100, Encoding::Dense));
    }

    #[test]
    fn sparse_delta_roundtrip_is_lossless_and_small() {
        let mut params = vec![0.0f32; 100_000];
        // clustered indices (small gaps, 1-byte varints) and one huge gap
        for i in [3usize, 4, 7, 130, 131, 99_999] {
            params[i] = (i as f32) * 0.25 - 8.0;
        }
        let bytes = encode_update(2, 9, 31, &params, Encoding::SparseDelta);
        // gaps: 3, 1, 3, 123, 1 -> one byte each; 99_868 -> three bytes
        assert_eq!(bytes.len(), HEADER_BYTES + (5 + 3) + 4 * 6);
        assert!(bytes.len() <= wire_bytes(100_000, 6, Encoding::SparseDelta));
        assert!(bytes.len() < wire_bytes(100_000, 6, Encoding::Sparse));
        let u = decode_update(&bytes).unwrap();
        assert_eq!(u.client, 2);
        assert_eq!(u.round, 9);
        assert_eq!(u.n_samples, 31);
        assert_eq!(
            u.body,
            DecodedBody::Sparse {
                indices: vec![3, 4, 7, 130, 131, 99_999],
                values: vec![3.0 * 0.25 - 8.0, -7.0, 7.0 * 0.25 - 8.0, 130.0 * 0.25 - 8.0,
                             131.0 * 0.25 - 8.0, 99_999.0 * 0.25 - 8.0],
            }
        );
        assert_eq!(u.to_dense(), params);
    }

    #[test]
    fn q4_dense_and_sparse_roundtrip_within_half_step() {
        // dense-ish payload: q4 dense arm, ~8x under f32 dense
        let params: Vec<f32> = (0..501).map(|i| (i as f32 - 250.0) * 0.01).collect();
        let bytes = encode_update(1, 2, 3, &params, Encoding::AutoQ4);
        assert_eq!(bytes.len(), HEADER_BYTES + QHEADER + 251);
        assert!(bytes.len() <= wire_bytes(501, 501, Encoding::AutoQ4));
        assert!(bytes.len() * 7 < wire_bytes(501, 501, Encoding::Dense));
        let u = decode_update(&bytes).unwrap();
        let dense = u.to_dense();
        let step = (params[500] - params[0]) / 15.0;
        for (a, b) in params.iter().zip(&dense) {
            assert!((a - b).abs() <= step * 0.5 + 1e-6);
        }

        // masked payload: sparse-delta-q4 arm, zeros preserved exactly
        let mut params = vec![0.0f32; 10_000];
        for i in (0..10_000).step_by(100) {
            params[i] = (i as f32) * 0.001 + 1.0;
        }
        let bytes = encode_update(0, 0, 1, &params, Encoding::AutoQ4);
        // 100 entries: gap 0 then 99 gaps of 100 (one byte each), 50 nibble bytes
        assert_eq!(bytes.len(), HEADER_BYTES + QHEADER + 100 + 50);
        assert!(bytes.len() <= wire_bytes(10_000, 100, Encoding::AutoQ4));
        assert!(bytes.len() < wire_bytes(10_000, 100, Encoding::AutoQ8));
        let u = decode_update(&bytes).unwrap();
        let dense = u.to_dense();
        let vmax = params.iter().cloned().fold(0.0f32, f32::max);
        let vmin = params.iter().cloned().filter(|v| *v != 0.0).fold(f32::INFINITY, f32::min);
        let step = (vmax - vmin) / 15.0;
        for (a, b) in params.iter().zip(&dense) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            } else {
                assert!((a - b).abs() <= step * 0.5 + 1e-6);
            }
        }
    }

    #[test]
    fn q4_all_zero_and_empty_uploads_are_legal() {
        for p in [0usize, 1, 64, 65] {
            let params = vec![0.0f32; p];
            for enc in [Encoding::AutoQ4, Encoding::SparseDelta] {
                let u = decode_update(&encode_update(0, 0, 1, &params, enc)).unwrap();
                assert_eq!(u.to_dense(), params, "{enc:?} p {p}");
                assert_eq!(u.nnz(), 0);
            }
        }
    }

    #[test]
    fn varint_encoding_is_canonical_and_exact() {
        for (v, len) in [
            (0u32, 1usize),
            (1, 1),
            (127, 1),
            (128, 2),
            (16_383, 2),
            (16_384, 3),
            (2_097_151, 3),
            (2_097_152, 4),
            (268_435_455, 4),
            (268_435_456, 5),
            (u32::MAX, 5),
        ] {
            let mut buf = Vec::new();
            push_varint(&mut buf, v);
            assert_eq!(buf.len(), len, "varint {v}");
            assert_eq!(varint_len(v), len, "varint_len {v}");
            let mut at = 0usize;
            assert_eq!(read_varint(&buf, &mut at).unwrap(), v);
            assert_eq!(at, len);
        }
    }

    #[test]
    fn malformed_varints_are_typed_errors() {
        // truncated: continuation bit set, stream ends
        let mut at = 0;
        let err = read_varint(&[0x80], &mut at).unwrap_err().to_string();
        assert!(err.contains("truncated varint"), "{err}");
        // overlong: 0x80 0x00 encodes 0 in two bytes
        let mut at = 0;
        let err = read_varint(&[0x80, 0x00], &mut at).unwrap_err().to_string();
        assert!(err.contains("overlong"), "{err}");
        // longer than five bytes
        let mut at = 0;
        let err = read_varint(&[0x80, 0x80, 0x80, 0x80, 0x80, 0x01], &mut at)
            .unwrap_err()
            .to_string();
        assert!(err.contains("longer than 5"), "{err}");
        // fifth byte pushes past 32 bits
        let mut at = 0;
        let err = read_varint(&[0x80, 0x80, 0x80, 0x80, 0x10], &mut at)
            .unwrap_err()
            .to_string();
        assert!(err.contains("overflows u32"), "{err}");
    }

    /// Sparse-delta payload with entries at indices 3 and 7 out of p = 16:
    /// header, then the varint block [3, 4], then two f32 values.
    fn two_entry_sparse_delta() -> Vec<u8> {
        let mut params = vec![0.0f32; 16];
        params[3] = 1.0;
        params[7] = 2.0;
        let bytes = encode_update(0, 0, 1, &params, Encoding::SparseDelta);
        assert_eq!(bytes.len(), HEADER_BYTES + 2 + 8);
        assert_eq!(bytes[HEADER_BYTES..HEADER_BYTES + 2], [3u8, 4]);
        bytes
    }

    #[test]
    fn sparse_delta_body_rejects_zero_gap_as_non_monotone() {
        let mut bytes = two_entry_sparse_delta();
        bytes[HEADER_BYTES + 1] = 0; // second gap becomes 0: duplicate index 3
        let err = decode_update(&bytes).unwrap_err().to_string();
        assert!(err.contains("duplicate or out of order"), "{err}");
    }

    #[test]
    fn sparse_delta_body_rejects_index_past_p() {
        let mut bytes = two_entry_sparse_delta();
        bytes[HEADER_BYTES + 1] = 13; // 3 + 13 = 16 == p: one past the end
        let err = decode_update(&bytes).unwrap_err().to_string();
        assert!(err.contains("index 16"), "{err}");
    }

    #[test]
    fn sparse_delta_body_rejects_overlong_varint_gap() {
        let mut bytes = two_entry_sparse_delta();
        // rewrite the second gap (4) as the overlong two-byte form 0x84 0x00;
        // splicing keeps the value block intact, shifted one byte right
        // (dropping the returned iterator completes the splice)
        drop(bytes.splice(HEADER_BYTES + 1..HEADER_BYTES + 2, [0x84u8, 0x00]));
        let err = decode_update(&bytes).unwrap_err().to_string();
        assert!(err.contains("overlong"), "{err}");
    }

    #[test]
    fn sparse_delta_body_rejects_u32_overflow_and_truncation() {
        // count promises 2 entries but the body carries varints that
        // accumulate past u32: first index u32::MAX - 1 (valid varint),
        // then a gap that overflows the accumulator
        let mut params = vec![0.0f32; 16];
        params[3] = 1.0;
        params[7] = 2.0;
        let good = encode_update(0, 0, 1, &params, Encoding::SparseDelta);
        let mut bytes = good[..HEADER_BYTES].to_vec();
        push_varint(&mut bytes, u32::MAX - 1);
        push_varint(&mut bytes, 2);
        bytes.extend_from_slice(&1.0f32.to_le_bytes());
        bytes.extend_from_slice(&2.0f32.to_le_bytes());
        let err = decode_update(&bytes).unwrap_err().to_string();
        // the first index already fails the in-range check (p = 16), which
        // is the point: nothing panics on the way to the typed error
        assert!(err.contains("index"), "{err}");

        // truncated mid-varint-block
        let mut bytes = good.clone();
        bytes.truncate(HEADER_BYTES + 1);
        assert!(decode_update(&bytes).is_err());
        // truncated mid-value-block
        let mut bytes = good;
        bytes.truncate(bytes.len() - 2);
        assert!(decode_update(&bytes).is_err());
    }

    #[test]
    fn hostile_delta_count_is_rejected_before_any_allocation() {
        // A header that promises u32::MAX delta entries: the decoder must
        // fail on the impossible count, not reserve a multi-GB index
        // buffer first (the wire is an open local endpoint).
        let hostile_header = |tag: u8| {
            let mut bytes = Vec::new();
            bytes.extend_from_slice(&MAGIC.to_le_bytes());
            bytes.push(VERSION);
            bytes.push(tag);
            bytes.extend_from_slice(&0u32.to_le_bytes()); // client
            bytes.extend_from_slice(&1u32.to_le_bytes()); // round
            bytes.extend_from_slice(&1u32.to_le_bytes()); // n_samples
            bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // p
            bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // count
            bytes
        };
        for tag in [4u8, 6] {
            let mut bytes = hostile_header(tag);
            if tag == 6 {
                bytes.extend_from_slice(&0.0f32.to_le_bytes()); // min
                bytes.extend_from_slice(&0.1f32.to_le_bytes()); // scale
            }
            let err = decode_update(&bytes).unwrap_err().to_string();
            assert!(err.contains("truncated"), "tag {tag}: {err}");
        }
        // tag 9 (sparse grouped-q8): guard fires straight after the count
        let err = decode_update(&hostile_header(9)).unwrap_err().to_string();
        assert!(err.contains("truncated"), "tag 9: {err}");
        // tag 10 (sparse rice8): min + scale + k prefix, then the guard
        let mut bytes = hostile_header(10);
        bytes.extend_from_slice(&0.0f32.to_le_bytes());
        bytes.extend_from_slice(&0.1f32.to_le_bytes());
        bytes.push(0); // k
        let err = decode_update(&bytes).unwrap_err().to_string();
        assert!(err.contains("truncated"), "tag 10: {err}");
        // tag 7 (sparse cached): a hostile added-count against an empty
        // cached set must hit the size guard, not an allocation — epoch 1
        // matches, n_removed 0, n_added u32::MAX so the count arithmetic
        // stays consistent up to the guard
        let cache = IndexCache::first(vec![]);
        let mut bytes = hostile_header(7);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // epoch
        bytes.extend_from_slice(&0u32.to_le_bytes()); // n_removed
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // n_added
        let err = decode_update_cached(&bytes, Some(&cache))
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated"), "tag 7: {err}");
    }

    #[test]
    fn q4_body_rejects_truncated_and_nonzero_padding_nibble() {
        // odd-count sparse q4 body: 3 entries -> 2 packed bytes, high
        // nibble of the last byte is padding
        let mut params = vec![0.0f32; 64];
        params[1] = 1.0;
        params[5] = 2.0;
        params[9] = 3.0;
        let good = encode_update(0, 0, 1, &params, Encoding::AutoQ4);
        assert_eq!(good.len(), HEADER_BYTES + QHEADER + 3 + 2);
        assert!(decode_update(&good).is_ok());
        // truncated nibble byte
        let mut bytes = good.clone();
        bytes.truncate(bytes.len() - 1);
        assert!(decode_update(&bytes).is_err());
        // non-zero padding nibble
        let mut bytes = good;
        let last = bytes.len() - 1;
        bytes[last] |= 0xf0;
        let err = decode_update(&bytes).unwrap_err().to_string();
        assert!(err.contains("padding nibble"), "{err}");

        // dense q4 with odd p: same padding rule
        let params = vec![0.5f32; 7];
        let good = encode_update(0, 0, 1, &params, Encoding::AutoQ4);
        let mut bytes = good;
        let last = bytes.len() - 1;
        bytes[last] |= 0x10;
        assert!(decode_update(&bytes).is_err());
    }

    /// Satellite invariant: `wire_bytes` is exact for the fixed-size
    /// encodings and a true upper bound for the payload-dependent ones,
    /// across every encoding x payload shape (empty, all-zero, dense,
    /// sparse, single-element).
    #[test]
    fn prop_wire_bytes_matches_or_bounds_encoded_len() {
        check("wire_bytes vs encoded.len()", 150, |g| {
            let p = match g.usize_in(0, 9) {
                0 => 0,
                1 => 1,
                _ => g.usize_in(2, 2000),
            };
            let density = match g.usize_in(0, 4) {
                0 => 0.0,
                _ => g.f32_in(0.0, 1.0),
            };
            let params = sample_params(g, p, density);
            let nnz = params.iter().filter(|v| **v != 0.0).count();
            for &enc in Encoding::ALL {
                let encoded = encode_update(1, 2, 3, &params, enc);
                let predicted = wire_bytes(p, nnz, enc);
                match enc {
                    Encoding::Dense | Encoding::Sparse => assert_eq!(
                        encoded.len(),
                        predicted,
                        "{enc:?} p {p} nnz {nnz} seed {:#x}",
                        g.seed
                    ),
                    // AutoQ8 joined the upper-bound class in wire v3: its
                    // Rice arm can beat both fixed-size q8 arms
                    Encoding::SparseDelta
                    | Encoding::Auto
                    | Encoding::AutoQ8
                    | Encoding::AutoQ4
                    | Encoding::SparseCached
                    | Encoding::GroupedQ8 => assert!(
                        encoded.len() <= predicted,
                        "{enc:?} p {p} nnz {nnz}: {} > bound {predicted} (seed {:#x})",
                        encoded.len(),
                        g.seed
                    ),
                }
            }
        });
    }

    #[test]
    fn corrupt_messages_rejected() {
        let params = vec![1.0f32; 10];
        let mut bytes = encode_update(0, 0, 1, &params, Encoding::Dense);
        bytes[0] ^= 0xff; // magic
        assert!(decode_update(&bytes).is_err());

        let mut bytes = encode_update(0, 0, 1, &params, Encoding::Dense);
        bytes.truncate(bytes.len() - 2);
        assert!(decode_update(&bytes).is_err());

        let mut bytes = encode_update(0, 0, 1, &params, Encoding::Dense);
        bytes.push(0);
        assert!(decode_update(&bytes).is_err());
    }

    /// Sparse payload with entries at indices 3 and 7 (values 1.0, 2.0) out
    /// of p = 16; entry i starts at byte HEADER_BYTES + 8 * i.
    fn two_entry_sparse() -> Vec<u8> {
        let mut params = vec![0.0f32; 16];
        params[3] = 1.0;
        params[7] = 2.0;
        let bytes = encode_update(0, 0, 1, &params, Encoding::Sparse);
        assert_eq!(bytes.len(), HEADER_BYTES + 16);
        bytes
    }

    #[test]
    fn sparse_body_rejects_out_of_range_index() {
        let mut bytes = two_entry_sparse();
        // overwrite second entry's index with p (= 16): one past the end
        bytes[HEADER_BYTES + 8..HEADER_BYTES + 12].copy_from_slice(&16u32.to_le_bytes());
        let err = decode_update(&bytes).unwrap_err().to_string();
        assert!(err.contains("index 16"), "{err}");
    }

    #[test]
    fn sparse_body_rejects_duplicate_index() {
        let mut bytes = two_entry_sparse();
        // second entry repeats the first entry's index
        bytes[HEADER_BYTES + 8..HEADER_BYTES + 12].copy_from_slice(&3u32.to_le_bytes());
        let err = decode_update(&bytes).unwrap_err().to_string();
        assert!(err.contains("duplicate or out of order"), "{err}");
    }

    #[test]
    fn sparse_body_rejects_unsorted_indices() {
        let mut bytes = two_entry_sparse();
        // swap the two entries: indices arrive as 7, 3
        let (a, b) = (HEADER_BYTES, HEADER_BYTES + 8);
        let mut entry = [0u8; 8];
        entry.copy_from_slice(&bytes[a..a + 8]);
        bytes.copy_within(b..b + 8, a);
        bytes[b..b + 8].copy_from_slice(&entry);
        let err = decode_update(&bytes).unwrap_err().to_string();
        assert!(err.contains("duplicate or out of order"), "{err}");
    }

    #[test]
    fn sparse_body_rejects_truncated_value() {
        let mut bytes = two_entry_sparse();
        // cut the last entry's value in half
        bytes.truncate(bytes.len() - 2);
        assert!(decode_update(&bytes).is_err());
        // and a count that promises more entries than the body carries
        let mut bytes = two_entry_sparse();
        bytes[20..24].copy_from_slice(&3u32.to_le_bytes());
        assert!(decode_update(&bytes).is_err());
    }

    #[test]
    fn sparse_q8_body_rejects_malformed_indices() {
        let mut params = vec![0.0f32; 64];
        params[10] = 1.0;
        params[20] = 2.0;
        let good = encode_update(0, 0, 1, &params, Encoding::AutoQ8);
        // q8 sparse body: count(4) + min(4) + scale(4), then 5-byte entries
        let entries = HEADER_BYTES + 8;
        // duplicate index
        let mut bytes = good.clone();
        bytes[entries + 5..entries + 9].copy_from_slice(&10u32.to_le_bytes());
        assert!(decode_update(&bytes).is_err());
        // out-of-range index
        let mut bytes = good.clone();
        bytes[entries + 5..entries + 9].copy_from_slice(&64u32.to_le_bytes());
        assert!(decode_update(&bytes).is_err());
        // truncated value byte
        let mut bytes = good;
        bytes.truncate(bytes.len() - 1);
        assert!(decode_update(&bytes).is_err());
    }

    #[test]
    fn prop_roundtrip_all_densities() {
        check("codec roundtrip", 100, |g| {
            let p = g.usize_in(1, 2000);
            let density = g.f32_in(0.0, 1.0);
            let params = sample_params(g, p, density);
            for enc in [
                Encoding::Dense,
                Encoding::Sparse,
                Encoding::SparseDelta,
                Encoding::Auto,
            ] {
                let bytes = encode_update(1, 2, 3, &params, enc);
                let u = decode_update(&bytes).unwrap();
                assert_eq!(u.to_dense(), params, "enc {enc:?} seed {:#x}", g.seed);
            }
        });
    }

    #[test]
    fn q8_dense_roundtrip_within_half_step() {
        let params: Vec<f32> = (0..500).map(|i| (i as f32 - 250.0) * 0.01).collect();
        let bytes = encode_update(1, 2, 3, &params, Encoding::AutoQ8);
        assert_eq!(bytes.len(), wire_bytes(500, 500, Encoding::AutoQ8));
        // q8 dense is ~4x smaller than f32 dense
        assert!(bytes.len() * 3 < wire_bytes(500, 500, Encoding::Dense));
        let u = decode_update(&bytes).unwrap();
        let dense = u.to_dense();
        let step = (params[499] - params[0]) / 255.0;
        for (a, b) in params.iter().zip(&dense) {
            assert!((a - b).abs() <= step * 0.5 + 1e-6);
        }
    }

    #[test]
    fn q8_sparse_roundtrip_and_size() {
        let mut params = vec![0.0f32; 10_000];
        for i in (0..10_000).step_by(100) {
            params[i] = (i as f32) * 0.001 + 1.0;
        }
        let bytes = encode_update(0, 0, 1, &params, Encoding::AutoQ8);
        // wire_bytes is an upper bound for AutoQ8 since wire v3: the Rice
        // arm beats the flat 5-bytes-per-entry sparse-q8 form here
        assert!(bytes.len() <= wire_bytes(10_000, 100, Encoding::AutoQ8));
        assert!(bytes.len() < wire_bytes(10_000, 100, Encoding::Sparse));
        let u = decode_update(&bytes).unwrap();
        let dense = u.to_dense();
        // zeros preserved exactly; values within half a step
        let vmax = params.iter().cloned().fold(0.0f32, f32::max);
        let vmin = params.iter().cloned().filter(|v| *v != 0.0).fold(f32::INFINITY, f32::min);
        let step = (vmax - vmin) / 255.0;
        for (a, b) in params.iter().zip(&dense) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            } else {
                assert!((a - b).abs() <= step * 0.5 + 1e-6);
            }
        }
    }

    #[test]
    fn q8_all_zero_upload_is_legal() {
        let params = vec![0.0f32; 64];
        let u = decode_update(&encode_update(0, 0, 1, &params, Encoding::AutoQ8)).unwrap();
        assert_eq!(u.to_dense(), params);
        assert_eq!(u.nnz(), 0);
    }

    #[test]
    fn prop_auto_never_larger_than_any_fixed_encoding() {
        check("auto minimality", 100, |g| {
            let p = g.usize_in(1, 500);
            let density = g.f32_in(0.0, 1.0);
            let params = sample_params(g, p, density);
            let auto = encode_update(0, 0, 0, &params, Encoding::Auto).len();
            let dense = encode_update(0, 0, 0, &params, Encoding::Dense).len();
            let sparse = encode_update(0, 0, 0, &params, Encoding::Sparse).len();
            let sparse_delta = encode_update(0, 0, 0, &params, Encoding::SparseDelta).len();
            assert!(auto <= dense && auto <= sparse && auto <= sparse_delta);
            // and the lossy auto picks its smaller arm by actual length too
            let q4 = encode_update(0, 0, 0, &params, Encoding::AutoQ4).len();
            let nnz = params.iter().filter(|v| **v != 0.0).count();
            assert!(q4 <= wire_bytes(p, nnz, Encoding::AutoQ4));
        });
    }

    #[test]
    fn encoding_parses_and_prints_round_trip() {
        for &enc in Encoding::ALL {
            assert_eq!(Encoding::parse(enc.as_str()).unwrap(), enc);
        }
        assert!(Encoding::parse("zstd").is_err());
    }

    #[test]
    fn peek_header_reads_routing_fields_without_decoding() {
        for &enc in Encoding::ALL {
            let payload = encode_update(9, 41, 130, &[1.5, 0.0, -2.0], enc);
            let h = peek_header(&payload).unwrap();
            assert_eq!(h.client, 9, "{enc:?}");
            assert_eq!(h.round, 41, "{enc:?}");
            assert_eq!(h.n_samples, 130, "{enc:?}");
            assert_eq!(h.p, 3, "{enc:?}");
            assert_eq!(peek_client(&payload), Some(9));
        }
        // too short, wrong magic, wrong version: all None, never a panic
        assert_eq!(peek_header(&[0u8; 23]), None);
        let mut bad = encode_update(1, 2, 3, &[1.0], Encoding::Dense);
        bad[0] ^= 0xff;
        assert_eq!(peek_header(&bad), None);
        let mut bad = encode_update(1, 2, 3, &[1.0], Encoding::Dense);
        bad[2] = VERSION + 1;
        assert_eq!(peek_header(&bad), None);
    }

    #[test]
    fn lossy_half_step_matches_quantizer_grids() {
        assert_eq!(Encoding::Auto.lossy_half_step(-1.0, 1.0), 0.0);
        assert_eq!(Encoding::SparseDelta.lossy_half_step(-1.0, 1.0), 0.0);
        // the cached arm is lossless: same f32 values, cheaper indices
        assert_eq!(Encoding::SparseCached.lossy_half_step(-1.0, 1.0), 0.0);
        let q8 = Encoding::AutoQ8.lossy_half_step(0.0, 255.0);
        assert!((q8 - 0.5).abs() < 1e-6);
        // grouped q8 reports the global grid's half-step (a valid upper
        // bound on every group's step)
        let gq8 = Encoding::GroupedQ8.lossy_half_step(0.0, 255.0);
        assert!((gq8 - 0.5).abs() < 1e-6);
        let q4 = Encoding::AutoQ4.lossy_half_step(0.0, 15.0);
        assert!((q4 - 0.5).abs() < 1e-6);
        // degenerate range is exact
        assert_eq!(Encoding::AutoQ4.lossy_half_step(2.0, 2.0), 0.0);
    }

    fn support_of(params: &[f32]) -> Vec<u32> {
        params
            .iter()
            .enumerate()
            .filter(|(_, v)| **v != 0.0)
            .map(|(i, _)| i as u32)
            .collect()
    }

    #[test]
    fn sparse_cached_roundtrip_matches_stateless_and_shrinks() {
        let p = 4096usize;
        // round 1: every 8th coordinate carried
        let mut prev = vec![0.0f32; p];
        for i in (0..2048).step_by(8) {
            prev[i] = i as f32 * 0.01 + 1.0;
        }
        let cache = IndexCache::first(support_of(&prev));
        // round 2: small churn — 3 indices leave, 3 join, values move
        let mut params = prev.clone();
        params[0] = 0.0;
        params[8] = 0.0;
        params[16] = 0.0;
        params[3000] = -1.5;
        params[3001] = 2.5;
        params[4095] = 0.25;
        for v in params.iter_mut().filter(|v| **v != 0.0) {
            *v += 0.125;
        }
        let cached = encode_update_cached(7, 2, 64, &params, Encoding::SparseCached, Some(&cache));
        let stateless = encode_update(7, 2, 64, &params, Encoding::SparseDelta);
        assert_eq!(cached[3], TAG_SPARSE_CACHED);
        assert!(
            cached.len() < stateless.len(),
            "cached {} !< stateless {}",
            cached.len(),
            stateless.len()
        );
        assert!(cached.len() <= wire_bytes(p, support_of(&params).len(), Encoding::SparseCached));
        // the stateful decode is bitwise-equal to the stateless decode
        let a = decode_update_cached(&cached, Some(&cache)).unwrap();
        let b = decode_update(&stateless).unwrap();
        assert_eq!(a.body, b.body);
        assert_eq!((a.client, a.round, a.n_samples, a.p), (7, 2, 64, p));
        // without the cache (or with a desynced epoch) the same bytes are
        // a typed parse error, never a silent wrong decode
        let err = decode_update(&cached).unwrap_err().to_string();
        assert!(err.contains("no index cache"), "{err}");
        let stale = IndexCache { epoch: cache.epoch + 1, indices: cache.indices.clone() };
        let err = decode_update_cached(&cached, Some(&stale)).unwrap_err().to_string();
        assert!(err.contains("epoch mismatch"), "{err}");
    }

    #[test]
    fn sparse_cached_without_cache_falls_back_to_stateless() {
        let mut params = vec![0.0f32; 1000];
        for i in (0..1000).step_by(7) {
            params[i] = i as f32 + 0.5;
        }
        // no cache: byte-identical to the stateless sparse-delta encode
        let bytes = encode_update(1, 1, 10, &params, Encoding::SparseCached);
        let sd = encode_update(1, 1, 10, &params, Encoding::SparseDelta);
        assert_eq!(bytes, sd);
        // a fully-churned cache (disjoint support) makes the set-delta
        // dearer than starting over: same stateless fallback
        let churned = IndexCache::first((0..143).map(|i| i * 7 + 1).collect());
        let bytes = encode_update_cached(1, 1, 10, &params, Encoding::SparseCached, Some(&churned));
        assert_eq!(bytes, sd);
    }

    #[test]
    fn auto_censuses_cached_arm_by_exact_length() {
        let p = 4096usize;
        let mut params = vec![0.0f32; p];
        for i in (0..p).step_by(16) {
            params[i] = (i as f32).sin() + 1.5;
        }
        // zero churn: the cached arm (12 bytes of set-delta header, no
        // index bytes at all) beats every stateless arm
        let cache = IndexCache::first(support_of(&params));
        let auto = encode_update_cached(0, 1, 1, &params, Encoding::Auto, Some(&cache));
        assert_eq!(auto[3], TAG_SPARSE_CACHED);
        for &enc in &[Encoding::Dense, Encoding::Sparse, Encoding::SparseDelta] {
            assert!(auto.len() < encode_update(0, 1, 1, &params, enc).len(), "{enc:?}");
        }
        assert_eq!(
            decode_update_cached(&auto, Some(&cache)).unwrap().body,
            decode_update(&encode_update(0, 1, 1, &params, Encoding::SparseDelta))
                .unwrap()
                .body
        );
        // without a cache, Auto is unchanged from its stateless census
        let stateless = encode_update(0, 1, 1, &params, Encoding::Auto);
        assert_ne!(stateless[3], TAG_SPARSE_CACHED);
    }

    #[test]
    fn grouped_q8_limits_outlier_damage_to_its_group() {
        // two groups; one huge outlier in group 0 must not coarsen group 1
        let mut params: Vec<f32> = (0..512).map(|i| (i % 256) as f32 / 255.0).collect();
        params[0] = 1000.0;
        let bytes = encode_update(2, 3, 4, &params, Encoding::GroupedQ8);
        assert_eq!(bytes[3], TAG_DENSE_GQ8);
        assert!(bytes.len() <= wire_bytes(512, 512, Encoding::GroupedQ8));
        let dense = decode_update(&bytes).unwrap().to_dense();
        // group 1 keeps its own tight grid: half of (1.0 / 255), not half
        // of (1000 / 255) as the global q8 grid would force
        let local_half = 1.0 / 255.0 * 0.5 + 1e-5;
        for (a, b) in params[256..].iter().zip(&dense[256..]) {
            assert!((a - b).abs() <= local_half, "{a} vs {b}");
        }
        // group 0 is still bounded by its own (outlier-widened) step
        let outlier_half = 1000.0 / 255.0 * 0.5 + 1e-3;
        for (a, b) in params[..256].iter().zip(&dense[..256]) {
            assert!((a - b).abs() <= outlier_half, "{a} vs {b}");
        }
        // sparse arm: masked payload, zeros preserved exactly
        let mut masked = vec![0.0f32; 10_000];
        for i in (0..10_000).step_by(40) {
            masked[i] = (i as f32) * 1e-3 + 1.0;
        }
        let bytes = encode_update(2, 3, 4, &masked, Encoding::GroupedQ8);
        assert_eq!(bytes[3], TAG_SPARSE_GQ8);
        let dense = decode_update(&bytes).unwrap().to_dense();
        for (a, b) in masked.iter().zip(&dense) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            } else {
                assert!((a - b).abs() <= 10.0 / 255.0 * 0.5 + 1e-4);
            }
        }
    }

    #[test]
    fn auto_q8_rice_arm_wins_on_skewed_codes() {
        // values cluster at 1.0 with a few at 2.0: the q8 codes are almost
        // all zero, which Rice coding crushes far below 1 byte per value
        let mut params = vec![0.0f32; 10_000];
        for i in (0..10_000).step_by(50) {
            params[i] = if i % 1000 == 0 { 2.0 } else { 1.0 };
        }
        let bytes = encode_update(5, 6, 7, &params, Encoding::AutoQ8);
        assert_eq!(bytes[3], TAG_SPARSE_RICE8);
        let nnz = support_of(&params).len();
        assert!(
            bytes.len() < wire_bytes(10_000, nnz, Encoding::AutoQ8),
            "rice {} !< flat bound {}",
            bytes.len(),
            wire_bytes(10_000, nnz, Encoding::AutoQ8)
        );
        // the Rice stream decodes to exactly the same q8 grid values the
        // flat sparse-q8 arm would have produced — bitwise
        let vals: Vec<f32> = params.iter().copied().filter(|v| *v != 0.0).collect();
        let q = quantize(&vals).unwrap();
        let expect: Vec<f32> = q.codes.iter().map(|&c| q.min + q.scale * c as f32).collect();
        match decode_update(&bytes).unwrap().body {
            DecodedBody::Sparse { indices, values } => {
                assert_eq!(indices, support_of(&params));
                assert_eq!(values, expect);
            }
            other => panic!("expected sparse body, got {other:?}"),
        }
    }

    #[test]
    fn sparse_cached_decode_is_strict_about_the_set_delta() {
        // cached set {3, 7}; a payload claiming to remove 5 (absent) or
        // add 7 (present) must be a typed error
        let cache = IndexCache::first(vec![3, 7]);
        let p = 16u32;
        let build = |n_removed: u32, n_added: u32, count: u32, blocks: &[u8], values: usize| {
            let mut bytes = Vec::new();
            bytes.extend_from_slice(&MAGIC.to_le_bytes());
            bytes.push(VERSION);
            bytes.push(TAG_SPARSE_CACHED);
            bytes.extend_from_slice(&0u32.to_le_bytes()); // client
            bytes.extend_from_slice(&1u32.to_le_bytes()); // round
            bytes.extend_from_slice(&1u32.to_le_bytes()); // n_samples
            bytes.extend_from_slice(&p.to_le_bytes());
            bytes.extend_from_slice(&count.to_le_bytes());
            bytes.extend_from_slice(&1u32.to_le_bytes()); // epoch
            bytes.extend_from_slice(&n_removed.to_le_bytes());
            bytes.extend_from_slice(&n_added.to_le_bytes());
            bytes.extend_from_slice(blocks);
            for _ in 0..values {
                bytes.extend_from_slice(&1.0f32.to_le_bytes());
            }
            bytes
        };
        // removed index 5 not in {3, 7}
        let bytes = build(1, 0, 1, &[5], 1);
        let err = decode_update_cached(&bytes, Some(&cache)).unwrap_err().to_string();
        assert!(err.contains("not in cached set"), "{err}");
        // added index 7 collides with the cached set
        let bytes = build(0, 1, 3, &[7], 3);
        let err = decode_update_cached(&bytes, Some(&cache)).unwrap_err().to_string();
        assert!(err.contains("collides"), "{err}");
        // removing and re-adding 3 is non-canonical
        let bytes = build(1, 1, 2, &[3, 3], 2);
        let err = decode_update_cached(&bytes, Some(&cache)).unwrap_err().to_string();
        assert!(err.contains("non-canonical"), "{err}");
        // count that disagrees with |cached| - removed + added
        let bytes = build(0, 0, 5, &[], 5);
        let err = decode_update_cached(&bytes, Some(&cache)).unwrap_err().to_string();
        assert!(err.contains("declared count"), "{err}");
        // and the well-formed zero-churn delta decodes to the cached set
        let bytes = build(0, 0, 2, &[], 2);
        let u = decode_update_cached(&bytes, Some(&cache)).unwrap();
        assert_eq!(
            u.body,
            DecodedBody::Sparse { indices: vec![3, 7], values: vec![1.0, 1.0] }
        );
    }
}
