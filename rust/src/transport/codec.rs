//! Wire encoding of model updates — the **load-bearing** client->server
//! (and optionally server->client) data path, not just byte accounting.
//!
//! A masked update is mostly zeros; shipping it densely would throw the
//! paper's saving away. The codec picks the cheaper of:
//!
//! * **dense**  — header + P * 4 bytes of f32;
//! * **sparse** — header + nnz * (4-byte index + 4-byte value).
//!
//! Sparse wins whenever nnz < P/2 — exactly the masked regimes the paper
//! sweeps (gamma <= 0.5 strictly, and layered masking keeps biases dense so
//! the crossover is measured, not assumed). All integers are little-endian;
//! the header carries (client id, round, sample count) for the aggregator —
//! `ClientJob::run` encodes, `Server::run_round` decodes and folds, and
//! nothing else ever sees the raw parameter vector in between.

use crate::transport::quantize::{quantize, Quantized};
use crate::util::error::{Error, Result};

/// Magic + version guard ("FM" + v1).
const MAGIC: u16 = 0x464d;
const VERSION: u8 = 1;

const TAG_DENSE: u8 = 0;
const TAG_SPARSE: u8 = 1;
const TAG_DENSE_Q8: u8 = 2;
const TAG_SPARSE_Q8: u8 = 3;

/// Chosen wire representation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    Dense,
    Sparse,
    /// Pick whichever is smaller for the given payload.
    Auto,
    /// 8-bit linear quantization stacked on the auto dense/sparse choice
    /// (paper §1: masking "can also be combined with cutting-edge
    /// compression algorithms"). Lossy: values dequantize within half a
    /// quantization step (see [`crate::transport::quantize`]).
    AutoQ8,
}

/// A decoded update message.
#[derive(Debug, Clone, PartialEq)]
pub struct WireUpdate {
    pub client: u32,
    pub round: u32,
    pub n_samples: u32,
    pub params: Vec<f32>,
}

/// Exact wire size in bytes for a payload with `nnz` non-zeros out of `p`.
pub fn wire_bytes(p: usize, nnz: usize, enc: Encoding) -> usize {
    const HEADER: usize = 2 + 1 + 1 + 4 + 4 + 4 + 4 + 4; // magic..len fields
    const QHEADER: usize = 8; // min + scale f32
    match enc {
        Encoding::Dense => HEADER + 4 * p,
        Encoding::Sparse => HEADER + 8 * nnz,
        Encoding::Auto => {
            wire_bytes(p, nnz, Encoding::Dense).min(wire_bytes(p, nnz, Encoding::Sparse))
        }
        Encoding::AutoQ8 => (HEADER + QHEADER + p).min(HEADER + QHEADER + 5 * nnz),
    }
}

/// Encode an update. `Encoding::Auto` picks the smaller representation;
/// `AutoQ8` additionally quantizes values to 8 bits (lossy).
pub fn encode_update(
    client: u32,
    round: u32,
    n_samples: u32,
    params: &[f32],
    enc: Encoding,
) -> Vec<u8> {
    let p = params.len();
    let nnz = params.iter().filter(|v| **v != 0.0).count();
    let (tag, body_len) = match enc {
        Encoding::Dense => (TAG_DENSE, 4 * p),
        Encoding::Sparse => (TAG_SPARSE, 8 * nnz),
        Encoding::Auto => {
            if 8 * nnz < 4 * p {
                (TAG_SPARSE, 8 * nnz)
            } else {
                (TAG_DENSE, 4 * p)
            }
        }
        Encoding::AutoQ8 => {
            if 5 * nnz < p {
                (TAG_SPARSE_Q8, 8 + 5 * nnz)
            } else {
                (TAG_DENSE_Q8, 8 + p)
            }
        }
    };
    let mut out = Vec::with_capacity(26 + body_len);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(tag);
    out.extend_from_slice(&client.to_le_bytes());
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&n_samples.to_le_bytes());
    out.extend_from_slice(&(p as u32).to_le_bytes());
    match tag {
        TAG_DENSE => {
            out.extend_from_slice(&(p as u32).to_le_bytes());
            for &v in params {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        TAG_SPARSE => {
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            for (i, &v) in params.iter().enumerate() {
                if v != 0.0 {
                    out.extend_from_slice(&(i as u32).to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        TAG_DENSE_Q8 => {
            // quantizing an empty payload: degenerate but legal (p == 0) —
            // emit a zero-range quantizer
            let q = if params.is_empty() {
                Quantized { min: 0.0, scale: 0.0, codes: vec![] }
            } else {
                quantize(params).expect("finite params")
            };
            out.extend_from_slice(&(p as u32).to_le_bytes());
            out.extend_from_slice(&q.min.to_le_bytes());
            out.extend_from_slice(&q.scale.to_le_bytes());
            out.extend_from_slice(&q.codes);
        }
        TAG_SPARSE_Q8 => {
            let values: Vec<f32> = params.iter().copied().filter(|v| *v != 0.0).collect();
            // quantizing an empty value set: degenerate but legal (all-zero
            // upload) — emit a zero-range quantizer
            let q = if values.is_empty() {
                Quantized { min: 0.0, scale: 0.0, codes: vec![] }
            } else {
                quantize(&values).expect("finite params")
            };
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            out.extend_from_slice(&q.min.to_le_bytes());
            out.extend_from_slice(&q.scale.to_le_bytes());
            let mut k = 0usize;
            for (i, &v) in params.iter().enumerate() {
                if v != 0.0 {
                    out.extend_from_slice(&(i as u32).to_le_bytes());
                    out.push(q.codes[k]);
                    k += 1;
                }
            }
        }
        _ => unreachable!(),
    }
    out
}

fn take<const N: usize>(data: &[u8], at: &mut usize) -> Result<[u8; N]> {
    let slice = data
        .get(*at..*at + N)
        .ok_or_else(|| Error::parse("codec: truncated message"))?;
    *at += N;
    Ok(slice.try_into().unwrap())
}

/// Decode an update message produced by [`encode_update`].
pub fn decode_update(data: &[u8]) -> Result<WireUpdate> {
    let mut at = 0usize;
    let magic = u16::from_le_bytes(take::<2>(data, &mut at)?);
    if magic != MAGIC {
        return Err(Error::parse(format!("codec: bad magic {magic:#x}")));
    }
    let version = take::<1>(data, &mut at)?[0];
    if version != VERSION {
        return Err(Error::parse(format!("codec: unsupported version {version}")));
    }
    let tag = take::<1>(data, &mut at)?[0];
    let client = u32::from_le_bytes(take::<4>(data, &mut at)?);
    let round = u32::from_le_bytes(take::<4>(data, &mut at)?);
    let n_samples = u32::from_le_bytes(take::<4>(data, &mut at)?);
    let p = u32::from_le_bytes(take::<4>(data, &mut at)?) as usize;
    let count = u32::from_le_bytes(take::<4>(data, &mut at)?) as usize;
    let mut params = vec![0.0f32; p];
    match tag {
        TAG_DENSE => {
            if count != p {
                return Err(Error::parse("codec: dense count != p"));
            }
            for slot in params.iter_mut() {
                *slot = f32::from_le_bytes(take::<4>(data, &mut at)?);
            }
        }
        TAG_SPARSE => {
            for _ in 0..count {
                let idx = u32::from_le_bytes(take::<4>(data, &mut at)?) as usize;
                let val = f32::from_le_bytes(take::<4>(data, &mut at)?);
                if idx >= p {
                    return Err(Error::parse(format!("codec: index {idx} >= p {p}")));
                }
                params[idx] = val;
            }
        }
        TAG_DENSE_Q8 => {
            if count != p {
                return Err(Error::parse("codec: dense-q8 count != p"));
            }
            let min = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let scale = f32::from_le_bytes(take::<4>(data, &mut at)?);
            for slot in params.iter_mut() {
                let code = take::<1>(data, &mut at)?[0];
                *slot = min + scale * code as f32;
            }
        }
        TAG_SPARSE_Q8 => {
            let min = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let scale = f32::from_le_bytes(take::<4>(data, &mut at)?);
            for _ in 0..count {
                let idx = u32::from_le_bytes(take::<4>(data, &mut at)?) as usize;
                let code = take::<1>(data, &mut at)?[0];
                if idx >= p {
                    return Err(Error::parse(format!("codec: index {idx} >= p {p}")));
                }
                params[idx] = min + scale * code as f32;
            }
        }
        other => return Err(Error::parse(format!("codec: unknown tag {other}"))),
    }
    if at != data.len() {
        return Err(Error::parse("codec: trailing bytes"));
    }
    Ok(WireUpdate {
        client,
        round,
        n_samples,
        params,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn sample_params(g: &mut Gen, p: usize, density: f32) -> Vec<f32> {
        (0..p)
            .map(|_| {
                if g.f32_in(0.0, 1.0) < density {
                    g.f32_in(-2.0, 2.0)
                } else {
                    0.0
                }
            })
            .collect()
    }

    #[test]
    fn dense_roundtrip() {
        let params: Vec<f32> = (0..100).map(|i| i as f32 * 0.5 - 10.0).collect();
        let bytes = encode_update(3, 7, 256, &params, Encoding::Dense);
        let u = decode_update(&bytes).unwrap();
        assert_eq!(u.client, 3);
        assert_eq!(u.round, 7);
        assert_eq!(u.n_samples, 256);
        assert_eq!(u.params, params);
        assert_eq!(bytes.len(), wire_bytes(100, 100, Encoding::Dense));
    }

    #[test]
    fn sparse_roundtrip_preserves_zeros() {
        let mut params = vec![0.0f32; 1000];
        params[13] = 1.5;
        params[999] = -2.25;
        let bytes = encode_update(0, 0, 1, &params, Encoding::Sparse);
        assert_eq!(bytes.len(), wire_bytes(1000, 2, Encoding::Sparse));
        let u = decode_update(&bytes).unwrap();
        assert_eq!(u.params, params);
    }

    #[test]
    fn auto_picks_smaller() {
        let dense_heavy: Vec<f32> = (0..100).map(|i| (i + 1) as f32).collect();
        let b1 = encode_update(0, 0, 1, &dense_heavy, Encoding::Auto);
        assert_eq!(b1.len(), wire_bytes(100, 100, Encoding::Dense));

        let mut sparse_heavy = vec![0.0f32; 100];
        sparse_heavy[5] = 1.0;
        let b2 = encode_update(0, 0, 1, &sparse_heavy, Encoding::Auto);
        assert_eq!(b2.len(), wire_bytes(100, 1, Encoding::Sparse));
        assert!(b2.len() < wire_bytes(100, 100, Encoding::Dense));
    }

    #[test]
    fn corrupt_messages_rejected() {
        let params = vec![1.0f32; 10];
        let mut bytes = encode_update(0, 0, 1, &params, Encoding::Dense);
        bytes[0] ^= 0xff; // magic
        assert!(decode_update(&bytes).is_err());

        let mut bytes = encode_update(0, 0, 1, &params, Encoding::Dense);
        bytes.truncate(bytes.len() - 2);
        assert!(decode_update(&bytes).is_err());

        let mut bytes = encode_update(0, 0, 1, &params, Encoding::Dense);
        bytes.push(0);
        assert!(decode_update(&bytes).is_err());
    }

    #[test]
    fn prop_roundtrip_all_densities() {
        check("codec roundtrip", 100, |g| {
            let p = g.usize_in(1, 2000);
            let density = g.f32_in(0.0, 1.0);
            let params = sample_params(g, p, density);
            for enc in [Encoding::Dense, Encoding::Sparse, Encoding::Auto] {
                let bytes = encode_update(1, 2, 3, &params, enc);
                let u = decode_update(&bytes).unwrap();
                assert_eq!(u.params, params, "enc {enc:?} seed {:#x}", g.seed);
            }
        });
    }

    #[test]
    fn q8_dense_roundtrip_within_half_step() {
        let params: Vec<f32> = (0..500).map(|i| (i as f32 - 250.0) * 0.01).collect();
        let bytes = encode_update(1, 2, 3, &params, Encoding::AutoQ8);
        assert_eq!(bytes.len(), wire_bytes(500, 500, Encoding::AutoQ8));
        // q8 dense is ~4x smaller than f32 dense
        assert!(bytes.len() * 3 < wire_bytes(500, 500, Encoding::Dense));
        let u = decode_update(&bytes).unwrap();
        let step = (params[499] - params[0]) / 255.0;
        for (a, b) in params.iter().zip(&u.params) {
            assert!((a - b).abs() <= step * 0.5 + 1e-6);
        }
    }

    #[test]
    fn q8_sparse_roundtrip_and_size() {
        let mut params = vec![0.0f32; 10_000];
        for i in (0..10_000).step_by(100) {
            params[i] = (i as f32) * 0.001 + 1.0;
        }
        let bytes = encode_update(0, 0, 1, &params, Encoding::AutoQ8);
        assert_eq!(bytes.len(), wire_bytes(10_000, 100, Encoding::AutoQ8));
        // sparse-q8 is 5 bytes/entry vs 8 for sparse-f32
        assert!(bytes.len() < wire_bytes(10_000, 100, Encoding::Sparse));
        let u = decode_update(&bytes).unwrap();
        // zeros preserved exactly; values within half a step
        let vmax = params.iter().cloned().fold(0.0f32, f32::max);
        let vmin = params.iter().cloned().filter(|v| *v != 0.0).fold(f32::INFINITY, f32::min);
        let step = (vmax - vmin) / 255.0;
        for (a, b) in params.iter().zip(&u.params) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            } else {
                assert!((a - b).abs() <= step * 0.5 + 1e-6);
            }
        }
    }

    #[test]
    fn q8_all_zero_upload_is_legal() {
        let params = vec![0.0f32; 64];
        let u = decode_update(&encode_update(0, 0, 1, &params, Encoding::AutoQ8)).unwrap();
        assert_eq!(u.params, params);
    }

    #[test]
    fn prop_auto_never_larger_than_either() {
        check("auto minimality", 100, |g| {
            let p = g.usize_in(1, 500);
            let density = g.f32_in(0.0, 1.0);
            let params = sample_params(g, p, density);
            let auto = encode_update(0, 0, 0, &params, Encoding::Auto).len();
            let dense = encode_update(0, 0, 0, &params, Encoding::Dense).len();
            let sparse = encode_update(0, 0, 0, &params, Encoding::Sparse).len();
            assert!(auto <= dense && auto <= sparse);
        });
    }
}
