//! Wire encoding of model updates — the **load-bearing** client->server
//! (and optionally server->client) data path, not just byte accounting.
//!
//! A masked update is mostly zeros; shipping it densely would throw the
//! paper's saving away. The codec picks the cheaper of:
//!
//! * **dense**  — header + P * 4 bytes of f32;
//! * **sparse** — header + nnz * (4-byte index + 4-byte value).
//!
//! Sparse wins whenever nnz < P/2 — exactly the masked regimes the paper
//! sweeps (gamma <= 0.5 strictly, and layered masking keeps biases dense so
//! the crossover is measured, not assumed). All integers are little-endian;
//! the header carries (client id, round, sample count) for the aggregator —
//! `ClientJob::run` encodes, `Server::run_round` decodes and folds, and
//! nothing else ever sees the raw parameter vector in between.
//!
//! ## Sparse-native decoding
//!
//! Since the O(nnz) aggregation refactor the decoder no longer densifies:
//! a sparse body decodes to its `(indices, values)` pairs
//! ([`DecodedBody::Sparse`] / [`BodyView::Sparse`]) and flows into the
//! aggregator's sparse fold untouched, so a masked upload costs
//! O(nnz) — not O(p) — from the first wire byte to the accumulator. Two
//! entry points:
//!
//! * [`decode_update`] — owned [`WireUpdate`]; allocates per call.
//! * [`decode_update_view`] — borrows a caller-held [`DecodeScratch`], so a
//!   server decoding a whole cohort (or many rounds) reuses the same
//!   buffers and steady-state decoding performs no heap allocation.
//!
//! Sparse bodies are validated strictly: indices must be in-range **and
//! strictly increasing** (the encoder always emits them sorted), which
//! rejects duplicate and shuffled indices that would otherwise make the
//! fold order-dependent. Byte-to-float conversion is bulk
//! (`chunks_exact` over the body slice) rather than per-element cursor
//! reads.

use crate::transport::quantize::{quantize, Quantized};
use crate::util::error::{Error, Result};

/// Magic + version guard ("FM" + v1).
const MAGIC: u16 = 0x464d;
const VERSION: u8 = 1;

const TAG_DENSE: u8 = 0;
const TAG_SPARSE: u8 = 1;
const TAG_DENSE_Q8: u8 = 2;
const TAG_SPARSE_Q8: u8 = 3;

/// Fixed header: magic(2) version(1) tag(1) client(4) round(4)
/// n_samples(4) p(4) count(4).
const HEADER_BYTES: usize = 24;

/// Chosen wire representation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    Dense,
    Sparse,
    /// Pick whichever is smaller for the given payload.
    Auto,
    /// 8-bit linear quantization stacked on the auto dense/sparse choice
    /// (paper §1: masking "can also be combined with cutting-edge
    /// compression algorithms"). Lossy: values dequantize within half a
    /// quantization step (see [`crate::transport::quantize`]).
    AutoQ8,
}

/// A decoded update body, in whichever shape the wire carried it. Sparse
/// bodies stay sparse — densification is the *aggregator's* decision (and
/// with the O(nnz) fold it never happens on the server hot path).
#[derive(Debug, Clone, PartialEq)]
pub enum DecodedBody {
    Dense(Vec<f32>),
    /// Strictly-increasing indices into `[0, p)` paired with their values.
    Sparse { indices: Vec<u32>, values: Vec<f32> },
}

/// A decoded update message (owned).
#[derive(Debug, Clone, PartialEq)]
pub struct WireUpdate {
    pub client: u32,
    pub round: u32,
    pub n_samples: u32,
    /// Full model dimension the body addresses into.
    pub p: usize,
    pub body: DecodedBody,
}

impl WireUpdate {
    /// Non-zero entries actually carried by the body.
    pub fn nnz(&self) -> usize {
        match &self.body {
            DecodedBody::Dense(v) => v.iter().filter(|x| **x != 0.0).count(),
            DecodedBody::Sparse { indices, .. } => indices.len(),
        }
    }

    /// Materialize the full dense vector (O(p)); test/compat convenience —
    /// the server hot path never calls this.
    pub fn to_dense(&self) -> Vec<f32> {
        match &self.body {
            DecodedBody::Dense(v) => v.clone(),
            DecodedBody::Sparse { indices, values } => {
                let mut out = vec![0.0f32; self.p];
                for (&i, &v) in indices.iter().zip(values) {
                    out[i as usize] = v;
                }
                out
            }
        }
    }

    /// [`Self::to_dense`], consuming: a dense body is moved out, not cloned.
    pub fn into_dense(self) -> Vec<f32> {
        let p = self.p;
        match self.body {
            DecodedBody::Dense(v) => v,
            DecodedBody::Sparse { indices, values } => {
                let mut out = vec![0.0f32; p];
                for (i, v) in indices.into_iter().zip(values) {
                    out[i as usize] = v;
                }
                out
            }
        }
    }
}

/// A decoded update body borrowed from a [`DecodeScratch`].
#[derive(Debug, Clone, Copy)]
pub enum BodyView<'a> {
    Dense(&'a [f32]),
    Sparse { indices: &'a [u32], values: &'a [f32] },
}

/// A decoded update message borrowing its body from caller-held scratch.
#[derive(Debug)]
pub struct WireView<'a> {
    pub client: u32,
    pub round: u32,
    pub n_samples: u32,
    pub p: usize,
    pub body: BodyView<'a>,
}

/// Reusable decode buffers: hold one of these across payloads (the server
/// holds one across *rounds*) and steady-state decoding allocates nothing.
#[derive(Debug, Default)]
pub struct DecodeScratch {
    dense: Vec<f32>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

/// Reusable encode temporaries (the q8 sparse value gather). The returned
/// payload itself is an owned message and is allocated per call — it
/// outlives the encoder by design.
#[derive(Debug, Default)]
pub struct EncodeScratch {
    vals: Vec<f32>,
}

/// Exact wire size in bytes for a payload with `nnz` non-zeros out of `p`.
pub fn wire_bytes(p: usize, nnz: usize, enc: Encoding) -> usize {
    const QHEADER: usize = 8; // min + scale f32
    match enc {
        Encoding::Dense => HEADER_BYTES + 4 * p,
        Encoding::Sparse => HEADER_BYTES + 8 * nnz,
        Encoding::Auto => {
            wire_bytes(p, nnz, Encoding::Dense).min(wire_bytes(p, nnz, Encoding::Sparse))
        }
        Encoding::AutoQ8 => (HEADER_BYTES + QHEADER + p).min(HEADER_BYTES + QHEADER + 5 * nnz),
    }
}

/// Encode an update. `Encoding::Auto` picks the smaller representation;
/// `AutoQ8` additionally quantizes values to 8 bits (lossy).
pub fn encode_update(
    client: u32,
    round: u32,
    n_samples: u32,
    params: &[f32],
    enc: Encoding,
) -> Vec<u8> {
    encode_update_with(&mut EncodeScratch::default(), client, round, n_samples, params, enc)
}

/// [`encode_update`] with caller-held scratch, so a worker encoding many
/// uploads reuses its temporaries instead of allocating per update.
pub fn encode_update_with(
    scratch: &mut EncodeScratch,
    client: u32,
    round: u32,
    n_samples: u32,
    params: &[f32],
    enc: Encoding,
) -> Vec<u8> {
    let p = params.len();
    let nnz = params.iter().filter(|v| **v != 0.0).count();
    let (tag, body_len) = match enc {
        Encoding::Dense => (TAG_DENSE, 4 * p),
        Encoding::Sparse => (TAG_SPARSE, 8 * nnz),
        Encoding::Auto => {
            if 8 * nnz < 4 * p {
                (TAG_SPARSE, 8 * nnz)
            } else {
                (TAG_DENSE, 4 * p)
            }
        }
        Encoding::AutoQ8 => {
            if 5 * nnz < p {
                (TAG_SPARSE_Q8, 8 + 5 * nnz)
            } else {
                (TAG_DENSE_Q8, 8 + p)
            }
        }
    };
    let mut out = Vec::with_capacity(HEADER_BYTES + body_len);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(tag);
    out.extend_from_slice(&client.to_le_bytes());
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&n_samples.to_le_bytes());
    out.extend_from_slice(&(p as u32).to_le_bytes());
    match tag {
        TAG_DENSE => {
            out.extend_from_slice(&(p as u32).to_le_bytes());
            let start = out.len();
            out.resize(start + 4 * p, 0);
            for (slot, v) in out[start..].chunks_exact_mut(4).zip(params) {
                slot.copy_from_slice(&v.to_le_bytes());
            }
        }
        TAG_SPARSE => {
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            let start = out.len();
            out.resize(start + 8 * nnz, 0);
            let mut slots = out[start..].chunks_exact_mut(8);
            for (i, &v) in params.iter().enumerate() {
                if v != 0.0 {
                    let slot = slots.next().expect("nnz slots");
                    slot[..4].copy_from_slice(&(i as u32).to_le_bytes());
                    slot[4..].copy_from_slice(&v.to_le_bytes());
                }
            }
        }
        TAG_DENSE_Q8 => {
            // quantizing an empty payload: degenerate but legal (p == 0) —
            // emit a zero-range quantizer
            let q = if params.is_empty() {
                Quantized { min: 0.0, scale: 0.0, codes: vec![] }
            } else {
                quantize(params).expect("finite params")
            };
            out.extend_from_slice(&(p as u32).to_le_bytes());
            out.extend_from_slice(&q.min.to_le_bytes());
            out.extend_from_slice(&q.scale.to_le_bytes());
            out.extend_from_slice(&q.codes);
        }
        TAG_SPARSE_Q8 => {
            scratch.vals.clear();
            scratch.vals.extend(params.iter().copied().filter(|v| *v != 0.0));
            // quantizing an empty value set: degenerate but legal (all-zero
            // upload) — emit a zero-range quantizer
            let q = if scratch.vals.is_empty() {
                Quantized { min: 0.0, scale: 0.0, codes: vec![] }
            } else {
                quantize(&scratch.vals).expect("finite params")
            };
            out.extend_from_slice(&(nnz as u32).to_le_bytes());
            out.extend_from_slice(&q.min.to_le_bytes());
            out.extend_from_slice(&q.scale.to_le_bytes());
            let start = out.len();
            out.resize(start + 5 * nnz, 0);
            let mut slots = out[start..].chunks_exact_mut(5);
            let mut k = 0usize;
            for (i, &v) in params.iter().enumerate() {
                if v != 0.0 {
                    let slot = slots.next().expect("nnz slots");
                    slot[..4].copy_from_slice(&(i as u32).to_le_bytes());
                    slot[4] = q.codes[k];
                    k += 1;
                }
            }
        }
        _ => unreachable!(),
    }
    out
}

fn take<const N: usize>(data: &[u8], at: &mut usize) -> Result<[u8; N]> {
    let slice = data
        .get(*at..*at + N)
        .ok_or_else(|| Error::parse("codec: truncated message"))?;
    *at += N;
    Ok(slice.try_into().unwrap())
}

/// Grab the `len`-byte body slice at `at`, advancing the cursor.
fn body<'a>(data: &'a [u8], at: &mut usize, len: usize) -> Result<&'a [u8]> {
    let slice = data
        .get(*at..*at + len)
        .ok_or_else(|| Error::parse("codec: truncated message"))?;
    *at += len;
    Ok(slice)
}

struct Header {
    client: u32,
    round: u32,
    n_samples: u32,
    p: usize,
    sparse: bool,
}

/// Shared decode core: parses `data` into `scratch` (dense body into
/// `scratch.dense`, sparse body into `scratch.indices`/`scratch.values`)
/// and returns the header. Sparse indices are required to be in-range and
/// strictly increasing.
fn decode_into(data: &[u8], scratch: &mut DecodeScratch) -> Result<Header> {
    let mut at = 0usize;
    let magic = u16::from_le_bytes(take::<2>(data, &mut at)?);
    if magic != MAGIC {
        return Err(Error::parse(format!("codec: bad magic {magic:#x}")));
    }
    let version = take::<1>(data, &mut at)?[0];
    if version != VERSION {
        return Err(Error::parse(format!("codec: unsupported version {version}")));
    }
    let tag = take::<1>(data, &mut at)?[0];
    let client = u32::from_le_bytes(take::<4>(data, &mut at)?);
    let round = u32::from_le_bytes(take::<4>(data, &mut at)?);
    let n_samples = u32::from_le_bytes(take::<4>(data, &mut at)?);
    let p = u32::from_le_bytes(take::<4>(data, &mut at)?) as usize;
    let count = u32::from_le_bytes(take::<4>(data, &mut at)?) as usize;
    scratch.dense.clear();
    scratch.indices.clear();
    scratch.values.clear();
    let sparse = match tag {
        TAG_DENSE => {
            if count != p {
                return Err(Error::parse("codec: dense count != p"));
            }
            let b = body(data, &mut at, 4 * p)?;
            scratch.dense.reserve(p);
            scratch
                .dense
                .extend(b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())));
            false
        }
        TAG_SPARSE => {
            if count > p {
                return Err(Error::parse("codec: sparse count > p"));
            }
            let b = body(data, &mut at, 8 * count)?;
            scratch.indices.reserve(count);
            scratch.values.reserve(count);
            let mut next_min = 0u32;
            for entry in b.chunks_exact(8) {
                let idx = u32::from_le_bytes(entry[..4].try_into().unwrap());
                let val = f32::from_le_bytes(entry[4..].try_into().unwrap());
                check_sparse_index(idx, next_min, p)?;
                next_min = idx + 1;
                scratch.indices.push(idx);
                scratch.values.push(val);
            }
            true
        }
        TAG_DENSE_Q8 => {
            if count != p {
                return Err(Error::parse("codec: dense-q8 count != p"));
            }
            let min = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let scale = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let codes = body(data, &mut at, p)?;
            scratch.dense.reserve(p);
            scratch.dense.extend(codes.iter().map(|&c| min + scale * c as f32));
            false
        }
        TAG_SPARSE_Q8 => {
            if count > p {
                return Err(Error::parse("codec: sparse count > p"));
            }
            let min = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let scale = f32::from_le_bytes(take::<4>(data, &mut at)?);
            let b = body(data, &mut at, 5 * count)?;
            scratch.indices.reserve(count);
            scratch.values.reserve(count);
            let mut next_min = 0u32;
            for entry in b.chunks_exact(5) {
                let idx = u32::from_le_bytes(entry[..4].try_into().unwrap());
                check_sparse_index(idx, next_min, p)?;
                next_min = idx + 1;
                scratch.indices.push(idx);
                scratch.values.push(min + scale * entry[4] as f32);
            }
            true
        }
        other => return Err(Error::parse(format!("codec: unknown tag {other}"))),
    };
    if at != data.len() {
        return Err(Error::parse("codec: trailing bytes"));
    }
    Ok(Header {
        client,
        round,
        n_samples,
        p,
        sparse,
    })
}

fn check_sparse_index(idx: u32, next_min: u32, p: usize) -> Result<()> {
    if idx as usize >= p {
        return Err(Error::parse(format!("codec: index {idx} >= p {p}")));
    }
    if idx < next_min {
        return Err(Error::parse(format!(
            "codec: sparse index {idx} duplicate or out of order"
        )));
    }
    Ok(())
}

/// Decode an update message produced by [`encode_update`] into an owned
/// [`WireUpdate`]. Sparse bodies stay sparse.
pub fn decode_update(data: &[u8]) -> Result<WireUpdate> {
    let mut scratch = DecodeScratch::default();
    let h = decode_into(data, &mut scratch)?;
    let body = if h.sparse {
        DecodedBody::Sparse {
            indices: std::mem::take(&mut scratch.indices),
            values: std::mem::take(&mut scratch.values),
        }
    } else {
        DecodedBody::Dense(std::mem::take(&mut scratch.dense))
    };
    Ok(WireUpdate {
        client: h.client,
        round: h.round,
        n_samples: h.n_samples,
        p: h.p,
        body,
    })
}

/// Decode an update into caller-held scratch, returning a borrowed view.
/// The server's aggregation loop uses this: one [`DecodeScratch`] held
/// across all payloads of all rounds means zero decode allocations at
/// steady state.
pub fn decode_update_view<'a>(
    data: &[u8],
    scratch: &'a mut DecodeScratch,
) -> Result<WireView<'a>> {
    let h = decode_into(data, scratch)?;
    let body = if h.sparse {
        BodyView::Sparse {
            indices: &scratch.indices,
            values: &scratch.values,
        }
    } else {
        BodyView::Dense(&scratch.dense)
    };
    Ok(WireView {
        client: h.client,
        round: h.round,
        n_samples: h.n_samples,
        p: h.p,
        body,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn sample_params(g: &mut Gen, p: usize, density: f32) -> Vec<f32> {
        (0..p)
            .map(|_| {
                if g.f32_in(0.0, 1.0) < density {
                    g.f32_in(-2.0, 2.0)
                } else {
                    0.0
                }
            })
            .collect()
    }

    #[test]
    fn dense_roundtrip() {
        let params: Vec<f32> = (0..100).map(|i| i as f32 * 0.5 - 10.0).collect();
        let bytes = encode_update(3, 7, 256, &params, Encoding::Dense);
        let u = decode_update(&bytes).unwrap();
        assert_eq!(u.client, 3);
        assert_eq!(u.round, 7);
        assert_eq!(u.n_samples, 256);
        assert_eq!(u.p, 100);
        assert_eq!(u.body, DecodedBody::Dense(params.clone()));
        assert_eq!(u.to_dense(), params);
        assert_eq!(bytes.len(), wire_bytes(100, 100, Encoding::Dense));
    }

    #[test]
    fn sparse_roundtrip_preserves_zeros_without_densifying() {
        let mut params = vec![0.0f32; 1000];
        params[13] = 1.5;
        params[999] = -2.25;
        let bytes = encode_update(0, 0, 1, &params, Encoding::Sparse);
        assert_eq!(bytes.len(), wire_bytes(1000, 2, Encoding::Sparse));
        let u = decode_update(&bytes).unwrap();
        // the body stays sparse: exactly the two carried entries
        assert_eq!(
            u.body,
            DecodedBody::Sparse {
                indices: vec![13, 999],
                values: vec![1.5, -2.25],
            }
        );
        assert_eq!(u.nnz(), 2);
        assert_eq!(u.to_dense(), params);
    }

    #[test]
    fn view_decode_reuses_scratch_and_matches_owned() {
        let mut scratch = DecodeScratch::default();
        let mut g = Gen::new(0x5c4a);
        for _ in 0..20 {
            let p = g.usize_in(1, 500);
            let density = g.f32_in(0.0, 1.0);
            let params = sample_params(&mut g, p, density);
            for enc in [Encoding::Dense, Encoding::Sparse, Encoding::Auto, Encoding::AutoQ8] {
                let bytes = encode_update(1, 2, 3, &params, enc);
                let owned = decode_update(&bytes).unwrap();
                let view = decode_update_view(&bytes, &mut scratch).unwrap();
                assert_eq!(view.client, owned.client);
                assert_eq!(view.p, owned.p);
                match (&view.body, &owned.body) {
                    (BodyView::Dense(a), DecodedBody::Dense(b)) => assert_eq!(*a, &b[..]),
                    (
                        BodyView::Sparse { indices: ia, values: va },
                        DecodedBody::Sparse { indices: ib, values: vb },
                    ) => {
                        assert_eq!(*ia, &ib[..]);
                        assert_eq!(*va, &vb[..]);
                    }
                    (a, b) => panic!("body shape mismatch: {a:?} vs {b:?}"),
                }
            }
        }
    }

    #[test]
    fn auto_picks_smaller() {
        let dense_heavy: Vec<f32> = (0..100).map(|i| (i + 1) as f32).collect();
        let b1 = encode_update(0, 0, 1, &dense_heavy, Encoding::Auto);
        assert_eq!(b1.len(), wire_bytes(100, 100, Encoding::Dense));

        let mut sparse_heavy = vec![0.0f32; 100];
        sparse_heavy[5] = 1.0;
        let b2 = encode_update(0, 0, 1, &sparse_heavy, Encoding::Auto);
        assert_eq!(b2.len(), wire_bytes(100, 1, Encoding::Sparse));
        assert!(b2.len() < wire_bytes(100, 100, Encoding::Dense));
    }

    #[test]
    fn corrupt_messages_rejected() {
        let params = vec![1.0f32; 10];
        let mut bytes = encode_update(0, 0, 1, &params, Encoding::Dense);
        bytes[0] ^= 0xff; // magic
        assert!(decode_update(&bytes).is_err());

        let mut bytes = encode_update(0, 0, 1, &params, Encoding::Dense);
        bytes.truncate(bytes.len() - 2);
        assert!(decode_update(&bytes).is_err());

        let mut bytes = encode_update(0, 0, 1, &params, Encoding::Dense);
        bytes.push(0);
        assert!(decode_update(&bytes).is_err());
    }

    /// Sparse payload with entries at indices 3 and 7 (values 1.0, 2.0) out
    /// of p = 16; entry i starts at byte HEADER_BYTES + 8 * i.
    fn two_entry_sparse() -> Vec<u8> {
        let mut params = vec![0.0f32; 16];
        params[3] = 1.0;
        params[7] = 2.0;
        let bytes = encode_update(0, 0, 1, &params, Encoding::Sparse);
        assert_eq!(bytes.len(), HEADER_BYTES + 16);
        bytes
    }

    #[test]
    fn sparse_body_rejects_out_of_range_index() {
        let mut bytes = two_entry_sparse();
        // overwrite second entry's index with p (= 16): one past the end
        bytes[HEADER_BYTES + 8..HEADER_BYTES + 12].copy_from_slice(&16u32.to_le_bytes());
        let err = decode_update(&bytes).unwrap_err().to_string();
        assert!(err.contains("index 16"), "{err}");
    }

    #[test]
    fn sparse_body_rejects_duplicate_index() {
        let mut bytes = two_entry_sparse();
        // second entry repeats the first entry's index
        bytes[HEADER_BYTES + 8..HEADER_BYTES + 12].copy_from_slice(&3u32.to_le_bytes());
        let err = decode_update(&bytes).unwrap_err().to_string();
        assert!(err.contains("duplicate or out of order"), "{err}");
    }

    #[test]
    fn sparse_body_rejects_unsorted_indices() {
        let mut bytes = two_entry_sparse();
        // swap the two entries: indices arrive as 7, 3
        let (a, b) = (HEADER_BYTES, HEADER_BYTES + 8);
        let mut entry = [0u8; 8];
        entry.copy_from_slice(&bytes[a..a + 8]);
        bytes.copy_within(b..b + 8, a);
        bytes[b..b + 8].copy_from_slice(&entry);
        let err = decode_update(&bytes).unwrap_err().to_string();
        assert!(err.contains("duplicate or out of order"), "{err}");
    }

    #[test]
    fn sparse_body_rejects_truncated_value() {
        let mut bytes = two_entry_sparse();
        // cut the last entry's value in half
        bytes.truncate(bytes.len() - 2);
        assert!(decode_update(&bytes).is_err());
        // and a count that promises more entries than the body carries
        let mut bytes = two_entry_sparse();
        bytes[20..24].copy_from_slice(&3u32.to_le_bytes());
        assert!(decode_update(&bytes).is_err());
    }

    #[test]
    fn sparse_q8_body_rejects_malformed_indices() {
        let mut params = vec![0.0f32; 64];
        params[10] = 1.0;
        params[20] = 2.0;
        let good = encode_update(0, 0, 1, &params, Encoding::AutoQ8);
        // q8 sparse body: count(4) + min(4) + scale(4), then 5-byte entries
        let entries = HEADER_BYTES + 8;
        // duplicate index
        let mut bytes = good.clone();
        bytes[entries + 5..entries + 9].copy_from_slice(&10u32.to_le_bytes());
        assert!(decode_update(&bytes).is_err());
        // out-of-range index
        let mut bytes = good.clone();
        bytes[entries + 5..entries + 9].copy_from_slice(&64u32.to_le_bytes());
        assert!(decode_update(&bytes).is_err());
        // truncated value byte
        let mut bytes = good;
        bytes.truncate(bytes.len() - 1);
        assert!(decode_update(&bytes).is_err());
    }

    #[test]
    fn prop_roundtrip_all_densities() {
        check("codec roundtrip", 100, |g| {
            let p = g.usize_in(1, 2000);
            let density = g.f32_in(0.0, 1.0);
            let params = sample_params(g, p, density);
            for enc in [Encoding::Dense, Encoding::Sparse, Encoding::Auto] {
                let bytes = encode_update(1, 2, 3, &params, enc);
                let u = decode_update(&bytes).unwrap();
                assert_eq!(u.to_dense(), params, "enc {enc:?} seed {:#x}", g.seed);
            }
        });
    }

    #[test]
    fn q8_dense_roundtrip_within_half_step() {
        let params: Vec<f32> = (0..500).map(|i| (i as f32 - 250.0) * 0.01).collect();
        let bytes = encode_update(1, 2, 3, &params, Encoding::AutoQ8);
        assert_eq!(bytes.len(), wire_bytes(500, 500, Encoding::AutoQ8));
        // q8 dense is ~4x smaller than f32 dense
        assert!(bytes.len() * 3 < wire_bytes(500, 500, Encoding::Dense));
        let u = decode_update(&bytes).unwrap();
        let dense = u.to_dense();
        let step = (params[499] - params[0]) / 255.0;
        for (a, b) in params.iter().zip(&dense) {
            assert!((a - b).abs() <= step * 0.5 + 1e-6);
        }
    }

    #[test]
    fn q8_sparse_roundtrip_and_size() {
        let mut params = vec![0.0f32; 10_000];
        for i in (0..10_000).step_by(100) {
            params[i] = (i as f32) * 0.001 + 1.0;
        }
        let bytes = encode_update(0, 0, 1, &params, Encoding::AutoQ8);
        assert_eq!(bytes.len(), wire_bytes(10_000, 100, Encoding::AutoQ8));
        // sparse-q8 is 5 bytes/entry vs 8 for sparse-f32
        assert!(bytes.len() < wire_bytes(10_000, 100, Encoding::Sparse));
        let u = decode_update(&bytes).unwrap();
        let dense = u.to_dense();
        // zeros preserved exactly; values within half a step
        let vmax = params.iter().cloned().fold(0.0f32, f32::max);
        let vmin = params.iter().cloned().filter(|v| *v != 0.0).fold(f32::INFINITY, f32::min);
        let step = (vmax - vmin) / 255.0;
        for (a, b) in params.iter().zip(&dense) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            } else {
                assert!((a - b).abs() <= step * 0.5 + 1e-6);
            }
        }
    }

    #[test]
    fn q8_all_zero_upload_is_legal() {
        let params = vec![0.0f32; 64];
        let u = decode_update(&encode_update(0, 0, 1, &params, Encoding::AutoQ8)).unwrap();
        assert_eq!(u.to_dense(), params);
        assert_eq!(u.nnz(), 0);
    }

    #[test]
    fn prop_auto_never_larger_than_either() {
        check("auto minimality", 100, |g| {
            let p = g.usize_in(1, 500);
            let density = g.f32_in(0.0, 1.0);
            let params = sample_params(g, p, density);
            let auto = encode_update(0, 0, 0, &params, Encoding::Auto).len();
            let dense = encode_update(0, 0, 0, &params, Encoding::Dense).len();
            let sparse = encode_update(0, 0, 0, &params, Encoding::Sparse).len();
            assert!(auto <= dense && auto <= sparse);
        });
    }
}
