//! The transport abstraction the server runs its rounds over.
//!
//! A [`Transport`] is now a **full-duplex session plane**: it carries the
//! round's encoded downlink broadcast *to* each registered client and the
//! encoded update payloads *back* to the server's streaming-aggregation
//! loop. Three implementations:
//!
//! * [`InProcess`] — mpsc upload channel + per-client downlink queues;
//!   today's default and the bitwise reference every other transport is
//!   tested against.
//! * [`crate::transport::socket::Loopback`] — one persistent,
//!   token-authenticated framed TCP/UDS connection per registered client;
//!   the broadcast and the upload cross the same kernel socket.
//! * [`Simulated`] — wraps either of the above and re-orders upload
//!   deliveries by [`NetworkModel::upload_time`], so completion order
//!   models link speed instead of scheduler luck (the downlink passes
//!   through untimed — its cost is accounted by the virtual clock, not by
//!   delivery order).
//!
//! The split matters for streaming: the *sink* half ([`UploadSink`]) and
//! the *downlink* half ([`DownlinkSource`]) are `Send + Sync` and are
//! cloned into every client job (worker threads receive the broadcast and
//! push the upload the moment it is encoded), while the *receive* half
//! stays with the server loop, which folds payloads into the round's
//! aggregator in arrival order. Because the fold is order-independent by
//! construction, every transport produces a bitwise identical aggregate —
//! the integration suite pins exactly that.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::sim::availability::AvailabilityModel;
use crate::transport::codec::peek_header;
use crate::transport::network::NetworkModel;
use crate::util::error::{Error, Result};

/// How long the server waits for the next upload before declaring the
/// round wedged. Generous: it only trips when a client job died without
/// reporting (job errors surface through the pool first).
pub const DEFAULT_UPLOAD_TIMEOUT: Duration = Duration::from_secs(300);

/// Which wire the transport plane uses (config/CLI surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process channels (default; no socket, bitwise reference).
    InProcess,
    /// Framed TCP over localhost.
    Tcp,
    /// Framed unix-domain socket.
    Uds,
}

impl TransportKind {
    /// Parse the CLI/JSON spelling.
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s {
            "inproc" | "in-process" => Ok(TransportKind::InProcess),
            "tcp" => Ok(TransportKind::Tcp),
            "uds" | "unix" => Ok(TransportKind::Uds),
            other => Err(Error::invalid(format!(
                "bad transport '{other}' (expected inproc|tcp|uds)"
            ))),
        }
    }

    /// Canonical config spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            TransportKind::InProcess => "inproc",
            TransportKind::Tcp => "tcp",
            TransportKind::Uds => "uds",
        }
    }
}

/// The client-side upload half: ships one encoded payload toward the
/// server. Cloned (as `Arc<dyn UploadSink>`) into every client job; called
/// from engine-pool worker threads.
pub trait UploadSink: Send + Sync {
    fn send(&self, payload: Vec<u8>) -> Result<()>;
}

/// The client-side downlink half: where a client job receives the round's
/// encoded broadcast. Cloned (as `Arc<dyn DownlinkSource>`) into every
/// client job; called from engine-pool worker threads before local
/// training starts.
pub trait DownlinkSource: Send + Sync {
    /// Blocking receive of the next broadcast payload addressed to
    /// `client`, waiting at most `timeout`. The payload is shared
    /// (`Arc`) because one round's broadcast fans out to the whole
    /// cohort — the in-process wire hands every client the same
    /// allocation instead of a per-client deep copy.
    fn recv(&self, client: u32, timeout: Duration) -> Result<Arc<Vec<u8>>>;
}

/// The server-side transport: hand out sinks to client jobs, then receive
/// the uploaded payloads back in (transport-determined) completion order.
pub trait Transport: Send {
    /// Human-readable name for logs.
    fn label(&self) -> &'static str;

    /// Whether processes outside this run can inject payloads (an open
    /// socket endpoint). Decides how the server treats an invalid payload:
    /// on a shared wire it is dropped as stray-peer noise; on a closed
    /// wire (in-process channels) it can only be an internal bug and
    /// fails the round precisely and immediately.
    fn accepts_foreign_peers(&self) -> bool {
        false
    }

    /// Open this run's per-client sessions. On the socket transport this
    /// establishes one persistent duplex connection per client and runs
    /// the hello/welcome token handshake; in-process it allocates the
    /// per-client downlink queues. Must be called once, before any
    /// [`Transport::send_downlink`] or upload; ids not registered here
    /// cannot speak on the wire.
    fn register_clients(&mut self, clients: &[u32]) -> Result<()>;

    /// Sink for client jobs to upload through.
    fn sink(&self) -> Arc<dyn UploadSink>;

    /// Push one round's encoded broadcast to a registered client. The
    /// call only *enqueues* — the socket transport writes from a
    /// dedicated thread so a full kernel buffer backpressures the wire,
    /// never the server's round loop. The payload is `Arc`-shared so a
    /// cohort-wide broadcast costs one allocation, not one per client.
    fn send_downlink(&mut self, client: u32, payload: Arc<Vec<u8>>) -> Result<()>;

    /// Handle client jobs receive their broadcast through.
    fn downlink(&self) -> Arc<dyn DownlinkSource>;

    /// Announce a round of `expected` uploads. [`Simulated`] needs the
    /// cohort size to model delivery order; pass-through elsewhere.
    fn begin_round(&mut self, expected: usize);

    /// Receive the next well-formed payload. Malformed peers never surface
    /// here (the socket transport drops them with a log line); an `Err`
    /// means the transport itself failed (closed, timed out).
    fn recv(&mut self) -> Result<Vec<u8>>;

    /// Bounded-wait receive: wait at most `timeout` for the next payload.
    /// `Ok(None)` means nothing arrived in the window — *not* an error —
    /// so a caller can interleave short wire waits with other work (the
    /// server's round loop polls its worker-result channel between waits,
    /// which is how a dead client's concrete error surfaces immediately
    /// instead of after the full upload timeout). `Err` means the
    /// transport itself failed (link closed). [`Simulated`] accumulates
    /// its delivery-order cohort across calls, so short polls never lose
    /// payloads.
    fn try_recv_for(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>>;
}

/// `Sender` wrapped for `Sync`: worker threads share one sink `Arc`.
struct ChannelSink {
    tx: Mutex<Sender<Vec<u8>>>,
}

impl UploadSink for ChannelSink {
    fn send(&self, payload: Vec<u8>) -> Result<()> {
        self.tx
            .lock()
            .map_err(|_| Error::transport("in-process sink poisoned"))?
            .send(payload)
            .map_err(|_| Error::transport("in-process link closed"))
    }
}

/// Per-client downlink mailboxes for the in-process wire: the server
/// pushes encoded broadcasts in, client jobs (on worker threads) block
/// until theirs arrives. A condvar-backed queue map rather than one
/// channel per client so the `Arc<dyn DownlinkSource>` handle stays a
/// single shareable object.
#[derive(Default)]
struct DownlinkHub {
    queues: Mutex<HashMap<u32, VecDeque<Arc<Vec<u8>>>>>,
    ready: Condvar,
}

impl DownlinkHub {
    /// Register `client` with an empty mailbox (idempotent).
    fn register(&self, client: u32) {
        self.queues.lock().expect("downlink hub poisoned").entry(client).or_default();
    }

    fn push(&self, client: u32, payload: Arc<Vec<u8>>) -> Result<()> {
        let mut queues = self.queues.lock().map_err(|_| Error::transport("downlink hub poisoned"))?;
        match queues.get_mut(&client) {
            Some(q) => {
                q.push_back(payload);
                self.ready.notify_all();
                Ok(())
            }
            None => Err(Error::invalid(format!(
                "downlink to client {client}, which was never registered"
            ))),
        }
    }
}

impl DownlinkSource for DownlinkHub {
    fn recv(&self, client: u32, timeout: Duration) -> Result<Arc<Vec<u8>>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut queues = self.queues.lock().map_err(|_| Error::transport("downlink hub poisoned"))?;
        loop {
            match queues.get_mut(&client) {
                None => {
                    return Err(Error::invalid(format!(
                        "client {client} has no downlink mailbox (not registered)"
                    )))
                }
                Some(q) => {
                    if let Some(p) = q.pop_front() {
                        return Ok(p);
                    }
                }
            }
            let window = deadline
                .checked_duration_since(std::time::Instant::now())
                .filter(|w| !w.is_zero())
                .ok_or_else(|| {
                    Error::transport(format!(
                        "client {client} timed out after {timeout:?} waiting for the broadcast"
                    ))
                })?;
            let (guard, _) = self
                .ready
                .wait_timeout(queues, window)
                .map_err(|_| Error::transport("downlink hub poisoned"))?;
            queues = guard;
        }
    }
}

/// Channel-backed transport: payloads never leave the process. The
/// default, and the reference the socket paths are asserted bitwise
/// identical to.
pub struct InProcess {
    sink: Arc<ChannelSink>,
    rx: Receiver<Vec<u8>>,
    downlink: Arc<DownlinkHub>,
    timeout: Duration,
}

impl Default for InProcess {
    fn default() -> Self {
        InProcess::new()
    }
}

impl InProcess {
    pub fn new() -> InProcess {
        InProcess::with_timeout(DEFAULT_UPLOAD_TIMEOUT)
    }

    pub fn with_timeout(timeout: Duration) -> InProcess {
        let (tx, rx) = channel();
        InProcess {
            sink: Arc::new(ChannelSink { tx: Mutex::new(tx) }),
            rx,
            downlink: Arc::new(DownlinkHub::default()),
            timeout,
        }
    }
}

impl Transport for InProcess {
    fn label(&self) -> &'static str {
        "inproc"
    }

    fn register_clients(&mut self, clients: &[u32]) -> Result<()> {
        for &c in clients {
            self.downlink.register(c);
        }
        Ok(())
    }

    fn sink(&self) -> Arc<dyn UploadSink> {
        let sink: Arc<dyn UploadSink> = Arc::clone(&self.sink);
        sink
    }

    fn send_downlink(&mut self, client: u32, payload: Arc<Vec<u8>>) -> Result<()> {
        self.downlink.push(client, payload)
    }

    fn downlink(&self) -> Arc<dyn DownlinkSource> {
        let dl: Arc<dyn DownlinkSource> = Arc::clone(&self.downlink);
        dl
    }

    fn begin_round(&mut self, _expected: usize) {}

    fn recv(&mut self) -> Result<Vec<u8>> {
        recv_deadline(&self.rx, self.timeout)
    }

    fn try_recv_for(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        poll_channel(&self.rx, timeout)
    }
}

/// Shared timeout-aware receive for channel-drained transports.
pub(crate) fn recv_deadline(rx: &Receiver<Vec<u8>>, timeout: Duration) -> Result<Vec<u8>> {
    match rx.recv_timeout(timeout) {
        Ok(p) => Ok(p),
        Err(RecvTimeoutError::Timeout) => Err(Error::transport(format!(
            "timed out after {:?} waiting for an upload",
            timeout
        ))),
        Err(RecvTimeoutError::Disconnected) => {
            Err(Error::transport("upload link closed before the round completed"))
        }
    }
}

/// Shared bounded-wait receive for channel-drained transports: a lapse of
/// the window is `Ok(None)`, only a closed link is an error.
pub(crate) fn poll_channel(rx: &Receiver<Vec<u8>>, timeout: Duration) -> Result<Option<Vec<u8>>> {
    match rx.recv_timeout(timeout) {
        Ok(p) => Ok(Some(p)),
        Err(RecvTimeoutError::Timeout) => Ok(None),
        Err(RecvTimeoutError::Disconnected) => {
            Err(Error::transport("upload link closed before the round completed"))
        }
    }
}

/// [`NetworkModel`]-timed delivery over any inner transport.
///
/// Real arrival order on a loopback socket reflects scheduler timing, not
/// link speed. `Simulated` re-orders each round's deliveries by the virtual
/// completion time `upload_time(payload bytes)` (ties broken by true
/// arrival order), so a figure sweep over a simulated network sees byte-size
/// stragglers arrive last, deterministically. Modeling delivery *order*
/// requires the whole cohort, so the first `recv` of a round barriers on
/// all `expected` uploads — the aggregate is unchanged either way (the fold
/// is order-independent); only the arrival sequence is modeled.
pub struct Simulated {
    inner: Box<dyn Transport>,
    network: NetworkModel,
    /// This round's re-ordered queue, earliest completion last (pop order).
    queue: Vec<Vec<u8>>,
    /// Announced cohort size; deliveries re-order once `batch` fills.
    pending: usize,
    /// Uploads pulled off the inner wire but not yet re-ordered: (virtual
    /// completion time, true arrival sequence, payload). Kept across
    /// [`Transport::try_recv_for`] calls so bounded polls accumulate the
    /// cohort instead of losing partial progress.
    batch: Vec<(f64, usize, Vec<u8>)>,
    /// Device heterogeneity: when set, each upload's virtual completion
    /// time also includes [`AvailabilityModel::compute_time`] for the
    /// sending client (peeked from the payload header) over this many
    /// local epochs — so a slow device's upload arrives late even when
    /// its payload is small.
    compute: Option<(AvailabilityModel, usize)>,
}

impl Simulated {
    pub fn new(inner: Box<dyn Transport>, network: NetworkModel) -> Simulated {
        Simulated {
            inner,
            network,
            queue: Vec::new(),
            pending: 0,
            batch: Vec::new(),
            compute: None,
        }
    }

    /// Like [`Simulated::new`], but delivery order models local compute
    /// time too: completion = compute + transfer. With the default model
    /// (homogeneous compute, zero jitter) the added term is a constant
    /// shift, so ordering — and thus the aggregate — is unchanged.
    pub fn with_compute(
        inner: Box<dyn Transport>,
        network: NetworkModel,
        availability: AvailabilityModel,
        local_epochs: usize,
    ) -> Simulated {
        let mut t = Simulated::new(inner, network);
        t.compute = Some((availability, local_epochs));
        t
    }

    /// The whole cohort has arrived: order by virtual completion time
    /// (ties broken by true arrival order) and stage for pop-delivery.
    fn finalize_batch(&mut self) {
        self.pending = 0;
        self.batch.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        // pop() delivers earliest virtual completion first
        self.batch.reverse();
        self.queue = std::mem::take(&mut self.batch).into_iter().map(|(_, _, p)| p).collect();
    }

    /// Stash one inner-wire arrival into the accumulating cohort batch;
    /// returns true once the batch is complete.
    fn absorb(&mut self, payload: Vec<u8>) -> bool {
        let seq = self.batch.len();
        let mut t = self.network.upload_time(payload.len());
        if let Some((availability, epochs)) = &self.compute {
            // the device trains before it uploads: completion time is
            // compute + transfer (payloads without our header — stray
            // wire noise — carry transfer time only)
            if let Some(h) = peek_header(&payload) {
                t += availability.compute_time(h.round as u64, h.client as u64, *epochs);
            }
        }
        self.batch.push((t, seq, payload));
        self.batch.len() == self.pending
    }
}

impl Transport for Simulated {
    fn label(&self) -> &'static str {
        "simulated"
    }

    fn accepts_foreign_peers(&self) -> bool {
        self.inner.accepts_foreign_peers()
    }

    fn register_clients(&mut self, clients: &[u32]) -> Result<()> {
        self.inner.register_clients(clients)
    }

    fn sink(&self) -> Arc<dyn UploadSink> {
        self.inner.sink()
    }

    fn send_downlink(&mut self, client: u32, payload: Arc<Vec<u8>>) -> Result<()> {
        // Downlink delivery order is not modeled (one broadcast per client
        // per round; the virtual clock prices its bytes) — pass through.
        self.inner.send_downlink(client, payload)
    }

    fn downlink(&self) -> Arc<dyn DownlinkSource> {
        self.inner.downlink()
    }

    fn begin_round(&mut self, expected: usize) {
        self.inner.begin_round(expected);
        self.queue.clear();
        self.batch.clear();
        self.pending = expected;
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        if let Some(p) = self.queue.pop() {
            return Ok(p);
        }
        if self.pending == 0 {
            // Pulls beyond the announced cohort pass through in arrival
            // order: the server re-pulls after rejecting an invalid
            // payload (a stray peer's message may have consumed one of
            // the barrier's slots), and the genuine upload it displaced
            // is still queued in the inner transport.
            return self.inner.recv();
        }
        while self.batch.len() < self.pending {
            let payload = self.inner.recv()?;
            self.absorb(payload);
        }
        self.finalize_batch();
        Ok(self.queue.pop().expect("cohort batch just staged"))
    }

    fn try_recv_for(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        if let Some(p) = self.queue.pop() {
            return Ok(Some(p));
        }
        if self.pending == 0 {
            return self.inner.try_recv_for(timeout);
        }
        // Accumulate cohort arrivals within the window; partial progress
        // survives in `batch` for the next poll.
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let now = std::time::Instant::now();
            let Some(window) = deadline.checked_duration_since(now).filter(|w| !w.is_zero())
            else {
                return Ok(None);
            };
            match self.inner.try_recv_for(window)? {
                None => return Ok(None),
                Some(payload) => {
                    if self.absorb(payload) {
                        self.finalize_batch();
                        return Ok(self.queue.pop());
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_process_ships_payloads_across_threads() {
        let mut t = InProcess::new();
        let sink = t.sink();
        t.begin_round(3);
        let handles: Vec<_> = (0..3u8)
            .map(|i| {
                let sink = Arc::clone(&sink);
                std::thread::spawn(move || sink.send(vec![i; 4 + i as usize]).unwrap())
            })
            .collect();
        let mut got: Vec<Vec<u8>> = (0..3).map(|_| t.recv().unwrap()).collect();
        for h in handles {
            h.join().unwrap();
        }
        got.sort();
        assert_eq!(got, vec![vec![0; 4], vec![1; 5], vec![2; 6]]);
    }

    #[test]
    fn recv_timeout_is_a_typed_transport_error() {
        let mut t = InProcess::with_timeout(Duration::from_millis(20));
        let err = t.recv().unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    fn simulated_orders_deliveries_by_upload_time() {
        // 1 MB/s client links, no latency: virtual completion time is
        // proportional to payload size, so the 1-byte upload lands first
        // regardless of send order.
        let network = NetworkModel {
            client_bw: 1e6,
            server_bw: 1e9,
            latency_s: 0.0,
        };
        let mut t = Simulated::new(Box::new(InProcess::new()), network);
        let sink = t.sink();
        t.begin_round(3);
        sink.send(vec![3u8; 3000]).unwrap();
        sink.send(vec![1u8; 1]).unwrap();
        sink.send(vec![2u8; 200]).unwrap();
        let sizes: Vec<usize> = (0..3).map(|_| t.recv().unwrap().len()).collect();
        assert_eq!(sizes, vec![1, 200, 3000]);
    }

    #[test]
    fn simulated_compute_jitter_orders_equal_size_uploads_by_compute_time() {
        use crate::transport::codec::{encode_update, Encoding};
        // equal payload sizes on an ideal network: transfer time ties at
        // zero, so high compute jitter alone decides delivery order — the
        // slowest device's upload is pinned to arrive last
        let availability = AvailabilityModel::with_compute(1.0, 0.0, 10.0, 0.9, 77);
        let epochs = 2;
        let mut t = Simulated::with_compute(
            Box::new(InProcess::new()),
            NetworkModel::ideal(),
            availability.clone(),
            epochs,
        );
        let sink = t.sink();
        t.begin_round(6);
        for c in 0..6u32 {
            sink.send(encode_update(c, 1, 10, &[1.0f32; 8], Encoding::Dense)).unwrap();
        }
        let arrived: Vec<u32> =
            (0..6).map(|_| peek_header(&t.recv().unwrap()).unwrap().client).collect();
        let mut expect: Vec<u32> = (0..6).collect();
        expect.sort_by(|a, b| {
            availability
                .compute_time(1, *a as u64, epochs)
                .partial_cmp(&availability.compute_time(1, *b as u64, epochs))
                .unwrap()
        });
        assert_eq!(arrived, expect, "equal-size uploads must follow compute time");
        assert_eq!(arrived.last(), expect.last(), "slowest device must land last");
    }

    #[test]
    fn simulated_ideal_network_preserves_arrival_order() {
        // infinite bandwidth: every upload_time is exactly 0.0, so the
        // sequence tie-break keeps true arrival order
        let mut t = Simulated::new(Box::new(InProcess::new()), NetworkModel::ideal());
        let sink = t.sink();
        t.begin_round(3);
        for i in [5u8, 9, 7] {
            sink.send(vec![i]).unwrap();
        }
        let got: Vec<u8> = (0..3).map(|_| t.recv().unwrap()[0]).collect();
        assert_eq!(got, vec![5, 9, 7]);
    }

    #[test]
    fn simulated_recv_beyond_the_cohort_passes_through_to_the_inner_wire() {
        // no round announced: recv defers to the inner transport, so with
        // nothing in flight it times out with a typed error...
        let inner = InProcess::with_timeout(Duration::from_millis(20));
        let mut t = Simulated::new(Box::new(inner), NetworkModel::ideal());
        let err = t.recv().unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        // ...and a payload beyond the announced cohort (a barrier slot was
        // consumed by a message the server rejected) still arrives.
        let sink = t.sink();
        t.begin_round(1);
        sink.send(vec![1]).unwrap();
        sink.send(vec![2, 2]).unwrap();
        assert_eq!(t.recv().unwrap(), vec![1]);
        assert_eq!(t.recv().unwrap(), vec![2, 2], "displaced upload must still surface");
    }

    #[test]
    fn try_recv_for_bounded_wait_returns_none_not_error() {
        let mut t = InProcess::new();
        let started = std::time::Instant::now();
        assert!(t.try_recv_for(Duration::from_millis(10)).unwrap().is_none());
        assert!(started.elapsed() < Duration::from_secs(5));
        let sink = t.sink();
        sink.send(vec![7u8; 3]).unwrap();
        assert_eq!(
            t.try_recv_for(Duration::from_millis(10)).unwrap(),
            Some(vec![7u8; 3])
        );
    }

    #[test]
    fn simulated_short_polls_accumulate_the_cohort_without_losing_payloads() {
        // 1 MB/s links: delivery order follows payload size once the whole
        // cohort lands, even when it lands across several bounded polls.
        let network = NetworkModel {
            client_bw: 1e6,
            server_bw: 1e9,
            latency_s: 0.0,
        };
        let mut t = Simulated::new(Box::new(InProcess::new()), network);
        let sink = t.sink();
        t.begin_round(3);
        // nothing sent yet: poll lapses quietly
        assert!(t.try_recv_for(Duration::from_millis(5)).unwrap().is_none());
        sink.send(vec![3u8; 3000]).unwrap();
        // partial cohort: the arrival is absorbed but nothing is deliverable
        assert!(t.try_recv_for(Duration::from_millis(20)).unwrap().is_none());
        sink.send(vec![1u8; 1]).unwrap();
        sink.send(vec![2u8; 200]).unwrap();
        // cohort complete: deliveries follow virtual upload time
        let mut sizes = Vec::new();
        while sizes.len() < 3 {
            if let Some(p) = t.try_recv_for(Duration::from_millis(50)).unwrap() {
                sizes.push(p.len());
            }
        }
        assert_eq!(sizes, vec![1, 200, 3000]);
        // and recv() after the cohort passes through to the inner wire
        sink.send(vec![9u8]).unwrap();
        assert_eq!(t.try_recv_for(Duration::from_millis(50)).unwrap(), Some(vec![9u8]));
    }

    #[test]
    fn simulated_mixed_recv_and_poll_agree() {
        // blocking recv() after poll-accumulated partial progress must not
        // double-count or drop anything
        let mut t = Simulated::new(Box::new(InProcess::new()), NetworkModel::ideal());
        let sink = t.sink();
        t.begin_round(2);
        sink.send(vec![5u8]).unwrap();
        assert!(t.try_recv_for(Duration::from_millis(20)).unwrap().is_none());
        sink.send(vec![6u8]).unwrap();
        assert_eq!(t.recv().unwrap(), vec![5u8]);
        assert_eq!(t.recv().unwrap(), vec![6u8]);
    }

    #[test]
    fn in_process_downlink_reaches_each_registered_client() {
        let mut t = InProcess::new();
        t.register_clients(&[3, 9]).unwrap();
        t.send_downlink(3, Arc::new(vec![0xa; 4])).unwrap();
        t.send_downlink(9, Arc::new(vec![0xb; 2])).unwrap();
        let dl = t.downlink();
        // worker threads pull their own mailbox, in any order
        let h = {
            let dl = Arc::clone(&dl);
            std::thread::spawn(move || dl.recv(9, Duration::from_secs(5)).unwrap())
        };
        assert_eq!(*dl.recv(3, Duration::from_secs(5)).unwrap(), vec![0xa; 4]);
        assert_eq!(*h.join().unwrap(), vec![0xb; 2]);
    }

    #[test]
    fn downlink_to_unregistered_client_is_a_typed_error() {
        let mut t = InProcess::new();
        t.register_clients(&[1]).unwrap();
        let err = t.send_downlink(7, Arc::new(vec![1])).unwrap_err();
        assert!(matches!(err, Error::Invalid(_)), "{err}");
        let err = t.downlink().recv(7, Duration::from_millis(10)).unwrap_err();
        assert!(matches!(err, Error::Invalid(_)), "{err}");
    }

    #[test]
    fn downlink_recv_blocks_until_the_broadcast_lands_and_times_out_otherwise() {
        let mut t = InProcess::new();
        t.register_clients(&[0]).unwrap();
        let dl = t.downlink();
        // nothing queued: a short wait trips the typed timeout
        let err = dl.recv(0, Duration::from_millis(20)).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(err.to_string().contains("timed out"), "{err}");
        // a broadcast pushed from another thread wakes the waiter
        let h = {
            let dl = Arc::clone(&dl);
            std::thread::spawn(move || dl.recv(0, Duration::from_secs(5)).unwrap())
        };
        std::thread::sleep(Duration::from_millis(30));
        t.send_downlink(0, Arc::new(vec![42])).unwrap();
        assert_eq!(*h.join().unwrap(), vec![42]);
    }

    #[test]
    fn simulated_delegates_registration_and_downlink_to_the_inner_wire() {
        let mut t = Simulated::new(Box::new(InProcess::new()), NetworkModel::ideal());
        t.register_clients(&[2]).unwrap();
        t.send_downlink(2, Arc::new(vec![9, 9])).unwrap();
        assert_eq!(*t.downlink().recv(2, Duration::from_secs(1)).unwrap(), vec![9, 9]);
        assert!(t.send_downlink(4, Arc::new(vec![1])).is_err());
    }

    #[test]
    fn transport_kind_parses_and_prints() {
        for (s, k) in [
            ("inproc", TransportKind::InProcess),
            ("tcp", TransportKind::Tcp),
            ("uds", TransportKind::Uds),
        ] {
            assert_eq!(TransportKind::parse(s).unwrap(), k);
            assert_eq!(TransportKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());
    }
}
