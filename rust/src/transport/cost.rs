//! Communication-cost accounting.
//!
//! Two views, kept side by side deliberately:
//!
//! * **Unit cost (Eq. 6)** — the paper's abstract metric: one full-model
//!   client<->server transfer = 1 unit; a round with sampling rate `c` and
//!   masking rate `gamma` costs `c * M * gamma` units uplink. [`eq6_cost`]
//!   is the closed form `f(beta, gamma) = gamma/R * sum_t C/exp(beta t)`.
//! * **Byte cost** — what the codec actually emitted, including headers and
//!   the dense/sparse crossover. The figure drivers report both, and the
//!   ledger's unit/byte ratio is itself a sanity check on the codec.
//!
//! The ledger records `payload.len()` — the true emitted size — never a
//! formula. That distinction went live with the entropy-coded encodings
//! (`sparse-delta`, `auto-q4`), whose sizes depend on where the non-zeros
//! sit: [`crate::transport::codec::wire_bytes`] is only an upper bound
//! there, so any accounting that priced uploads from `(p, nnz)` alone
//! would overstate the cost the paper's figures are meant to measure.

/// Eq. 6 of the paper: mean per-round unit transport cost over `rounds`
/// rounds of dynamic sampling (initial rate `c0`, decay `beta`) with
/// masking rate `gamma`. `t` runs 1..=R as in the paper.
pub fn eq6_cost(c0: f64, beta: f64, gamma: f64, rounds: usize) -> f64 {
    assert!(rounds > 0);
    let sum: f64 = (1..=rounds).map(|t| c0 / (beta * t as f64).exp()).sum();
    gamma / rounds as f64 * sum
}

/// Running totals for one experiment.
#[derive(Debug, Clone, Default)]
pub struct CostLedger {
    /// Client -> server model uploads, in full-model units (masked upload
    /// of rate gamma counts gamma units, matching the paper's accounting).
    pub uplink_units: f64,
    /// Server -> client model broadcasts, in full-model units.
    pub downlink_units: f64,
    /// Exact bytes the codec emitted uplink.
    pub uplink_bytes: u64,
    /// Exact bytes broadcast downlink (dense model per selected client).
    pub downlink_bytes: u64,
    /// Client<->server messages exchanged.
    pub messages: u64,
}

impl CostLedger {
    pub fn new() -> CostLedger {
        CostLedger::default()
    }

    /// Record one client upload: `nnz/p` of a model in units, plus the
    /// actual encoded byte count.
    pub fn record_upload(&mut self, p: usize, nnz: usize, bytes: usize) {
        assert!(nnz <= p);
        self.uplink_units += nnz as f64 / p as f64;
        self.uplink_bytes += bytes as u64;
        self.messages += 1;
    }

    /// Record one dense model broadcast to a selected client.
    pub fn record_download(&mut self, bytes: usize) {
        self.downlink_units += 1.0;
        self.downlink_bytes += bytes as u64;
        self.messages += 1;
    }

    /// Record one (possibly delta-encoded) broadcast to a selected client:
    /// `nnz/p` of a model in units plus the actual encoded byte count —
    /// the downlink mirror of [`CostLedger::record_upload`]. A dense
    /// broadcast passes `nnz == p` and degenerates to
    /// [`CostLedger::record_download`].
    pub fn record_download_sparse(&mut self, p: usize, nnz: usize, bytes: usize) {
        assert!(nnz <= p);
        self.downlink_units += nnz as f64 / p as f64;
        self.downlink_bytes += bytes as u64;
        self.messages += 1;
    }

    /// Record redundant upload traffic: retransmitted or duplicated
    /// frames that crossed the wire but folded zero times (the chaos
    /// harness makes these observable). The bytes and messages are real
    /// — the client's radio sent them — but they carry no model mass, so
    /// units are untouched.
    pub fn record_redundant_upload(&mut self, frames: u64, bytes: u64) {
        self.uplink_bytes += bytes;
        self.messages += frames;
    }

    /// Total units (the paper's headline cost metric counts uploads; we
    /// keep both directions separable).
    pub fn total_units(&self) -> f64 {
        self.uplink_units + self.downlink_units
    }

    /// Uplink units normalized by round count — comparable to [`eq6_cost`].
    pub fn mean_uplink_units_per_round(&self, rounds: usize) -> f64 {
        assert!(rounds > 0);
        self.uplink_units / rounds as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq6_matches_hand_computation() {
        // R=2, C=1, beta=0: cost = gamma/2 * (1 + 1) = gamma
        assert!((eq6_cost(1.0, 0.0, 0.3, 2) - 0.3).abs() < 1e-12);
        // single round: gamma * C * e^-beta
        let v = eq6_cost(0.5, 0.1, 0.4, 1);
        assert!((v - 0.4 * 0.5 * (-0.1f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn eq6_decreases_with_beta() {
        let flat = eq6_cost(1.0, 0.01, 0.5, 50);
        let steep = eq6_cost(1.0, 0.1, 0.5, 50);
        assert!(steep < flat);
        assert!(flat < 0.5); // any decay beats static C=1
    }

    #[test]
    fn eq6_linear_in_gamma() {
        let a = eq6_cost(1.0, 0.05, 0.2, 30);
        let b = eq6_cost(1.0, 0.05, 0.4, 30);
        assert!((b / a - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ledger_accumulates() {
        let mut l = CostLedger::new();
        l.record_download(4000);
        l.record_upload(1000, 300, 2500); // gamma = 0.3
        l.record_upload(1000, 1000, 4026);
        assert!((l.uplink_units - 1.3).abs() < 1e-12);
        assert_eq!(l.downlink_units, 1.0);
        assert_eq!(l.uplink_bytes, 6526);
        assert_eq!(l.messages, 3);
        assert!((l.mean_uplink_units_per_round(2) - 0.65).abs() < 1e-12);
    }

    #[test]
    fn redundant_uploads_bill_bytes_and_messages_but_no_units() {
        let mut l = CostLedger::new();
        l.record_upload(1000, 300, 2500);
        // the same frame delivered again by a retransmit storm
        l.record_redundant_upload(1, 2500);
        assert_eq!(l.uplink_bytes, 5000, "duplicated frames cost real bytes");
        assert_eq!(l.messages, 2);
        assert!((l.uplink_units - 0.3).abs() < 1e-12, "but fold zero model mass");
    }

    #[test]
    fn sparse_download_mirrors_upload_accounting() {
        let mut l = CostLedger::new();
        l.record_download_sparse(1000, 1000, 4026); // dense broadcast
        assert_eq!(l.downlink_units, 1.0);
        l.record_download_sparse(1000, 250, 2026); // delta broadcast
        assert!((l.downlink_units - 1.25).abs() < 1e-12);
        assert_eq!(l.downlink_bytes, 6052);
        assert_eq!(l.messages, 2);
    }

    #[test]
    fn ledger_bytes_are_codec_exact_for_entropy_coded_uploads() {
        use crate::transport::codec::{encode_update, wire_bytes, Encoding};
        // A masked update whose sparse-delta size beats every flat-index
        // formula: the ledger must carry the emitted length, and that
        // length must respect the wire_bytes upper bound.
        let p = 4096usize;
        let mut params = vec![0.0f32; p];
        for i in (0..p).step_by(64) {
            params[i] = 0.5 + i as f32 * 1e-3;
        }
        let nnz = params.iter().filter(|v| **v != 0.0).count();
        let mut ledger = CostLedger::new();
        let mut emitted = 0u64;
        for enc in [Encoding::SparseDelta, Encoding::Auto, Encoding::AutoQ4] {
            let payload = encode_update(0, 1, 10, &params, enc);
            assert!(payload.len() <= wire_bytes(p, nnz, enc), "{enc:?}");
            ledger.record_upload(p, nnz, payload.len());
            emitted += payload.len() as u64;
        }
        assert_eq!(ledger.uplink_bytes, emitted);
        assert_eq!(ledger.messages, 3);
        assert!((ledger.uplink_units - 3.0 * nnz as f64 / p as f64).abs() < 1e-12);
    }

    #[test]
    fn simulated_run_matches_eq6_closed_form() {
        // emulate R rounds of dynamic sampling + masking accounting and
        // compare against the closed form (paper consistency check)
        let (c0, beta, gamma, rounds, m) = (1.0, 0.1, 0.5, 20usize, 100usize);
        let mut ledger = CostLedger::new();
        for t in 1..=rounds {
            let rate = c0 / (beta * t as f64).exp();
            let selected = (rate * m as f64).round().max(1.0) as usize;
            for _ in 0..selected {
                let p = 10_000;
                let nnz = (gamma * p as f64) as usize;
                ledger.record_upload(p, nnz, 8 * nnz + 26);
            }
        }
        let measured = ledger.mean_uplink_units_per_round(rounds) / m as f64;
        let closed = eq6_cost(c0, beta, gamma, rounds);
        // rounding of client counts introduces small slack
        assert!(
            (measured - closed).abs() / closed < 0.05,
            "measured {measured} vs closed {closed}"
        );
    }
}
