//! Micro-benchmark timer (no `criterion` in the offline environment).
//!
//! Used by the `rust/benches/*.rs` targets (built with `harness = false`).
//! Each benchmark warms up, then runs timed iterations until a wall-clock
//! budget is spent, and reports median / p10 / p90 per-iteration time plus
//! derived throughput. Output is stable, one line per benchmark, so bench
//! logs diff cleanly across optimization iterations (EXPERIMENTS.md §Perf).
//!
//! Besides the human-readable lines, [`Bench::write_json`] emits the same
//! measurements machine-readably: each bench target writes a
//! `BENCH_<name>.json` trajectory file at the repo root so successive PRs
//! have a perf baseline to diff against.

use std::time::{Duration, Instant};

use crate::util::json::Json;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
}

impl Measurement {
    /// ns per iteration at the median.
    pub fn median_ns(&self) -> f64 {
        self.median.as_secs_f64() * 1e9
    }

    /// Render one stable report line; `work_items` lets callers derive a
    /// throughput column (e.g. parameters aggregated per second).
    pub fn report(&self, work_items: Option<(f64, &str)>) -> String {
        let thr = match work_items {
            Some((n, unit)) => {
                let per_sec = n / self.median.as_secs_f64();
                format!("  {:>12.3e} {unit}/s", per_sec)
            }
            None => String::new(),
        };
        format!(
            "{:<44} {:>10} iters  median {:>12?}  p10 {:>12?}  p90 {:>12?}{}",
            self.name, self.iters, self.median, self.p10, self.p90, thr
        )
    }

    /// Machine-readable form (nanosecond durations; object keys sorted by
    /// the JSON writer, so emitted files diff cleanly).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::num(self.iters as f64)),
            ("median_ns", Json::num(self.median.as_secs_f64() * 1e9)),
            ("p10_ns", Json::num(self.p10.as_secs_f64() * 1e9)),
            ("p90_ns", Json::num(self.p90.as_secs_f64() * 1e9)),
        ])
    }
}

/// Benchmark runner with a per-bench time budget.
pub struct Bench {
    budget: Duration,
    warmup: Duration,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        // Overridable for quick smoke runs: FEDMASK_BENCH_MS=50 cargo bench
        let ms = std::env::var("FEDMASK_BENCH_MS")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .unwrap_or(800);
        Bench {
            budget: Duration::from_millis(ms),
            warmup: Duration::from_millis(ms / 4),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Bench {
        Bench::default()
    }

    /// Time `f` repeatedly; the closure's return value is black-boxed so the
    /// optimizer cannot elide the work.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Measurement {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Timed samples.
        let mut samples: Vec<Duration> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.budget || samples.len() < 5 {
            let s = Instant::now();
            std::hint::black_box(f());
            samples.push(s.elapsed());
            if samples.len() >= 10_000 {
                break;
            }
        }
        samples.sort();
        let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            median: pick(0.5),
            p10: pick(0.1),
            p90: pick(0.9),
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Print all accumulated measurements.
    pub fn report_all(&self) {
        for m in &self.results {
            println!("{}", m.report(None));
        }
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Write the `BENCH_<file_name>` trajectory at the repo root — the
    /// committed perf baseline later PRs diff against. Skipped on smoke
    /// runs (`FEDMASK_BENCH_MS` set) so a quick low-budget pass cannot
    /// clobber the baseline; `FEDMASK_BENCH_JSON=1` forces the write.
    pub fn write_trajectory(&self, file_name: &str) {
        let smoke = std::env::var_os("FEDMASK_BENCH_MS").is_some();
        let forced = std::env::var_os("FEDMASK_BENCH_JSON").is_some();
        if smoke && !forced {
            println!(
                "(smoke budget: not writing {file_name}; set FEDMASK_BENCH_JSON=1 to force)"
            );
            return;
        }
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join(file_name);
        match self.write_json(&path) {
            Ok(()) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("could not write {}: {e}", path.display()),
        }
    }

    /// Write every accumulated measurement as a JSON trajectory file. The
    /// budget rides along so a quick `FEDMASK_BENCH_MS=50` smoke file is
    /// distinguishable from a full run.
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        let doc = Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("budget_ms", Json::num(self.budget.as_millis() as f64)),
            (
                "results",
                Json::Arr(self.results.iter().map(|m| m.to_json()).collect()),
            ),
        ]);
        std::fs::write(path, doc.to_pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("FEDMASK_BENCH_MS", "20");
        let mut b = Bench::new();
        let m = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(m.median_ns() > 0.0);
        assert!(m.iters >= 5);
    }

    #[test]
    fn report_contains_name_and_throughput() {
        std::env::set_var("FEDMASK_BENCH_MS", "10");
        let mut b = Bench::new();
        b.run("fmt", || 1 + 1);
        let line = b.results()[0].report(Some((1e6, "items")));
        assert!(line.contains("fmt"));
        assert!(line.contains("items/s"));
    }

    #[test]
    fn json_trajectory_roundtrips() {
        std::env::set_var("FEDMASK_BENCH_MS", "10");
        let mut b = Bench::new();
        b.run("alpha", || 1 + 1);
        b.run("beta", || 2 + 2);
        let path = std::env::temp_dir().join(format!("fedmask_bench_{}.json", std::process::id()));
        b.write_json(&path).unwrap();
        let doc = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").unwrap().as_str().unwrap(), "alpha");
        assert!(results[0].get("median_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert!(doc.get("budget_ms").unwrap().as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_file(&path);
    }
}
