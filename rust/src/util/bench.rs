//! Micro-benchmark timer (no `criterion` in the offline environment).
//!
//! Used by the `rust/benches/*.rs` targets (built with `harness = false`).
//! Each benchmark warms up, then runs timed iterations until a wall-clock
//! budget is spent, and reports median / p10 / p90 per-iteration time plus
//! derived throughput. Output is stable, one line per benchmark, so bench
//! logs diff cleanly across optimization iterations (EXPERIMENTS.md §Perf).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
}

impl Measurement {
    /// ns per iteration at the median.
    pub fn median_ns(&self) -> f64 {
        self.median.as_secs_f64() * 1e9
    }

    /// Render one stable report line; `work_items` lets callers derive a
    /// throughput column (e.g. parameters aggregated per second).
    pub fn report(&self, work_items: Option<(f64, &str)>) -> String {
        let thr = match work_items {
            Some((n, unit)) => {
                let per_sec = n / self.median.as_secs_f64();
                format!("  {:>12.3e} {unit}/s", per_sec)
            }
            None => String::new(),
        };
        format!(
            "{:<44} {:>10} iters  median {:>12?}  p10 {:>12?}  p90 {:>12?}{}",
            self.name, self.iters, self.median, self.p10, self.p90, thr
        )
    }
}

/// Benchmark runner with a per-bench time budget.
pub struct Bench {
    budget: Duration,
    warmup: Duration,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        // Overridable for quick smoke runs: FEDMASK_BENCH_MS=50 cargo bench
        let ms = std::env::var("FEDMASK_BENCH_MS")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .unwrap_or(800);
        Bench {
            budget: Duration::from_millis(ms),
            warmup: Duration::from_millis(ms / 4),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Bench {
        Bench::default()
    }

    /// Time `f` repeatedly; the closure's return value is black-boxed so the
    /// optimizer cannot elide the work.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Measurement {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Timed samples.
        let mut samples: Vec<Duration> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.budget || samples.len() < 5 {
            let s = Instant::now();
            std::hint::black_box(f());
            samples.push(s.elapsed());
            if samples.len() >= 10_000 {
                break;
            }
        }
        samples.sort();
        let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            median: pick(0.5),
            p10: pick(0.1),
            p90: pick(0.9),
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Print all accumulated measurements.
    pub fn report_all(&self) {
        for m in &self.results {
            println!("{}", m.report(None));
        }
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("FEDMASK_BENCH_MS", "20");
        let mut b = Bench::new();
        let m = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(m.median_ns() > 0.0);
        assert!(m.iters >= 5);
    }

    #[test]
    fn report_contains_name_and_throughput() {
        std::env::set_var("FEDMASK_BENCH_MS", "10");
        let mut b = Bench::new();
        b.run("fmt", || 1 + 1);
        let line = b.results()[0].report(Some((1e6, "items")));
        assert!(line.contains("fmt"));
        assert!(line.contains("items/s"));
    }
}
