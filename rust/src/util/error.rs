//! Crate-wide error type.
//!
//! Most fallible paths are IO (artifact loading), parse (JSON / config /
//! dataset formats), XLA (PJRT compile/execute), or validation (config and
//! shape checks). A single enum keeps `?` ergonomic across module
//! boundaries without pulling in `anyhow` on the hot path.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error enum.
#[derive(Debug)]
pub enum Error {
    /// Filesystem / IO failure (artifact or dataset access).
    Io(std::io::Error),
    /// JSON / config / dataset format parse failure.
    Parse(String),
    /// PJRT compile or execute failure (wraps the `xla` crate error).
    Xla(String),
    /// Configuration or shape validation failure.
    Invalid(String),
    /// An engine worker thread died or a channel closed unexpectedly.
    Engine(String),
    /// Wire transport failure: malformed frame, oversized declared length,
    /// mid-frame disconnect, socket setup/teardown, or upload timeout.
    Transport(String),
    /// Session authentication failure: a hello for an unregistered or
    /// already-active client, a missing/wrong session token on an upload,
    /// or an upload naming a client other than its session's. Always
    /// raised *before* any payload decode.
    Auth(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Invalid(m) => write!(f, "invalid: {m}"),
            Error::Engine(m) => write!(f, "engine error: {m}"),
            Error::Transport(m) => write!(f, "transport error: {m}"),
            Error::Auth(m) => write!(f, "auth error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Shorthand for a validation error.
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::Invalid(msg.into())
    }

    /// Shorthand for a parse error.
    pub fn parse(msg: impl Into<String>) -> Self {
        Error::Parse(msg.into())
    }

    /// Shorthand for a transport error.
    pub fn transport(msg: impl Into<String>) -> Self {
        Error::Transport(msg.into())
    }

    /// Shorthand for a session-authentication error.
    pub fn auth(msg: impl Into<String>) -> Self {
        Error::Auth(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_kind_and_message() {
        let e = Error::invalid("gamma must be in (0, 1]");
        assert!(e.to_string().contains("invalid"));
        assert!(e.to_string().contains("gamma"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "missing artifact");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("missing artifact"));
    }
}
