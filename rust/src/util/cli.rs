//! Tiny CLI argument parser (no `clap` in the offline environment).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments. Unknown flags are an error, listing the accepted set — the
//! same fail-fast behaviour a derive-based parser would give.

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

/// Parsed command line: positionals in order, options by name.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

/// Declarative option spec used for validation + help text.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

impl OptSpec {
    pub const fn value(name: &'static str, help: &'static str) -> Self {
        OptSpec {
            name,
            takes_value: true,
            help,
        }
    }

    pub const fn flag(name: &'static str, help: &'static str) -> Self {
        OptSpec {
            name,
            takes_value: false,
            help,
        }
    }
}

impl Args {
    /// Parse `argv` (without the program name) against `specs`.
    pub fn parse<I, S>(argv: I, specs: &[OptSpec]) -> Result<Args>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let mut iter = argv.into_iter().map(Into::into).peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                let (name, inline_val) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = specs.iter().find(|s| s.name == name).ok_or_else(|| {
                    let known: Vec<_> = specs.iter().map(|s| format!("--{}", s.name)).collect();
                    Error::invalid(format!(
                        "unknown option --{name}; accepted: {}",
                        known.join(", ")
                    ))
                })?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => iter.next().ok_or_else(|| {
                            Error::invalid(format!("option --{name} requires a value"))
                        })?,
                    };
                    args.options.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(Error::invalid(format!("flag --{name} takes no value")));
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Typed accessor with a default.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(raw) => raw
                .parse::<T>()
                .map_err(|_| Error::invalid(format!("--{name}: cannot parse '{raw}'"))),
        }
    }

    /// Comma-separated list accessor (`--betas 0.01,0.1`).
    pub fn get_list_or<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
    {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(raw) => raw
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse::<T>()
                        .map_err(|_| Error::invalid(format!("--{name}: cannot parse '{s}'")))
                })
                .collect(),
        }
    }
}

/// Render help text for a subcommand.
pub fn render_help(cmd: &str, summary: &str, specs: &[OptSpec]) -> String {
    let mut out = format!("{cmd} — {summary}\n\noptions:\n");
    for s in specs {
        let arg = if s.takes_value {
            format!("--{} <v>", s.name)
        } else {
            format!("--{}", s.name)
        };
        out.push_str(&format!("  {arg:24} {}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPECS: &[OptSpec] = &[
        OptSpec::value("rounds", "number of rounds"),
        OptSpec::value("out", "output CSV"),
        OptSpec::flag("verbose", "chatty"),
    ];

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(
            vec!["fig3", "--rounds=50", "--out", "x.csv", "--verbose", "tail"],
            SPECS,
        )
        .unwrap();
        assert_eq!(a.positional, vec!["fig3", "tail"]);
        assert_eq!(a.get("rounds"), Some("50"));
        assert_eq!(a.get("out"), Some("x.csv"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn unknown_option_lists_accepted() {
        let err = Args::parse(vec!["--bogus"], SPECS).unwrap_err().to_string();
        assert!(err.contains("--bogus"));
        assert!(err.contains("--rounds"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(vec!["--rounds"], SPECS).is_err());
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(vec!["--rounds", "50"], SPECS).unwrap();
        assert_eq!(a.get_or("rounds", 10usize).unwrap(), 50);
        assert_eq!(a.get_or("missing", 10usize).unwrap_or(10), 10);
        let bad = Args::parse(vec!["--rounds", "abc"], SPECS).unwrap();
        assert!(bad.get_or("rounds", 10usize).is_err());
    }

    #[test]
    fn list_accessor() {
        let specs = [OptSpec::value("betas", "decay list")];
        let a = Args::parse(vec!["--betas", "0.01,0.1"], &specs).unwrap();
        assert_eq!(a.get_list_or("betas", &[0.5f64]).unwrap(), vec![0.01, 0.1]);
        let b = Args::parse(Vec::<String>::new(), &specs).unwrap();
        assert_eq!(b.get_list_or("betas", &[0.5f64]).unwrap(), vec![0.5]);
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(Args::parse(vec!["--verbose=yes"], SPECS).is_err());
    }
}
