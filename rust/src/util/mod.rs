//! Cross-cutting substrates built from scratch for the offline environment:
//! a JSON parser/writer ([`json`]), a CLI argument parser ([`cli`]), a tiny
//! property-testing harness ([`prop`]), a micro-benchmark timer ([`bench`]),
//! and the crate error type ([`error`]).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod logging;
pub mod prop;
