//! Tiny property-testing harness (no `proptest` in the offline environment).
//!
//! `check` runs a property over N seeded random cases; on failure it reports
//! the failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! use fedmask::util::prop::{check, Gen};
//! check("sum is commutative", 200, |g: &mut Gen| {
//!     let (a, b) = (g.f32_in(-1.0, 1.0), g.f32_in(-1.0, 1.0));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::sim::rng::Rng;

/// Random value source handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Seed of this case, for replay.
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            rng: Rng::new(seed),
            seed,
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi, "empty range");
        lo + (self.rng.next_u64() as usize) % (hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f32()
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of standard-normal f32 values.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.next_normal()).collect()
    }

    /// Vector of uniform f32 in [lo, hi).
    pub fn f32_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `cases` seeded property evaluations. Panics (with the seed) on the
/// first failing case. Base seed is fixed for reproducibility; override
/// with `FEDMASK_PROP_SEED` to explore.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: u32, mut property: F) {
    let base = std::env::var("FEDMASK_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xfed_5eed);
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| property(&mut g)));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed on case {i} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_are_respected() {
        check("ranges", 500, |g| {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f32_in(-2.0, 2.0);
            assert!((-2.0..=2.0).contains(&f));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failure_reports_seed() {
        check("always fails", 3, |_| panic!("boom"));
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut a = Gen::new(42);
        let mut b = Gen::new(42);
        assert_eq!(a.normal_vec(16), b.normal_vec(16));
    }
}
