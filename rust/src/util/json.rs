//! Minimal JSON parser / writer.
//!
//! The offline build environment has no `serde_json`, so the crate carries
//! its own RFC 8259 subset implementation. It is used for the artifact
//! `manifest.json`, experiment configs, and metrics output. Numbers are
//! held as `f64` (adequate: every integer we round-trip — offsets, sizes,
//! counts — is far below 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{Error, Result};

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so emitted
/// JSON is deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------

    /// Object field lookup; errors with the key name for diagnosability.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| Error::parse(format!("missing key '{key}'"))),
            _ => Err(Error::parse(format!("expected object for key '{key}'"))),
        }
    }

    /// Optional object field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::parse(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::parse(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::parse(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::parse(format!("expected bool, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::parse(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::parse(format!("expected object, got {self:?}"))),
        }
    }

    /// `[1, 2, 3]` -> `Vec<usize>` convenience for shape fields.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ------------------------------------------------------------------
    // Builders
    // ------------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ------------------------------------------------------------------
    // Serialization
    // ------------------------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) if v.is_empty() => out.push_str("[]"),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) if m.is_empty() => out.push_str("{}"),
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Trailing content after the value is an error.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::parse(format!("trailing content at byte {}", p.pos)));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::parse(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::parse(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ))),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(Error::parse(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(Error::parse(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(Error::parse(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::parse("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| Error::parse("truncated \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::parse("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| Error::parse("bad \\u escape"))?;
                            // BMP only; surrogate pairs are not needed for
                            // our manifests/configs and are rejected.
                            let c = char::from_u32(code)
                                .ok_or_else(|| Error::parse("surrogate \\u escape"))?;
                            s.push(c);
                            self.pos += 4;
                        }
                        other => {
                            return Err(Error::parse(format!(
                                "bad escape {:?}",
                                other.map(|c| c as char)
                            )))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| Error::parse("invalid utf-8 in string"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::parse(format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\"}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"m":{"p":20522,"layers":[{"n":"w","s":[5,5,1,8]}],"f":0.5,"t":true}}"#;
        let v = parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(parse(&compact).unwrap(), v);
        let pretty = v.to_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn unicode_and_escapes_roundtrip() {
        let v = Json::Str("tab\t nl\n quote\" π".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn usize_vec_accessor() {
        let v = parse("[5, 5, 1, 8]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![5, 5, 1, 8]);
        assert!(parse("[1.5]").unwrap().as_usize_vec().is_err());
        assert!(parse("[-1]").unwrap().as_usize_vec().is_err());
    }

    #[test]
    fn missing_key_names_the_key() {
        let v = parse("{}").unwrap();
        let err = v.get("gamma").unwrap_err().to_string();
        assert!(err.contains("gamma"));
    }

    #[test]
    fn integers_emit_without_decimal_point() {
        assert_eq!(Json::num(20522.0).to_string(), "20522");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }
}
