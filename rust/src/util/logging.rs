//! Minimal stderr logger wired to the `log` facade.
//!
//! `FEDMASK_LOG=debug|info|warn|error` selects the level (default `info`).

use std::io::Write;

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let tag = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            let mut err = std::io::stderr().lock();
            let _ = writeln!(err, "[{tag}] {}", record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger once; subsequent calls are no-ops.
pub fn init() {
    let level = match std::env::var("FEDMASK_LOG").as_deref() {
        Ok("trace") => LevelFilter::Trace,
        Ok("debug") => LevelFilter::Debug,
        Ok("warn") => LevelFilter::Warn,
        Ok("error") => LevelFilter::Error,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER).map(|()| log::set_max_level(level));
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
