//! `fedmask` CLI — the L3 leader entrypoint.
//!
//! ```text
//! fedmask figure <table1|fig3..fig9> [--out csv] [--rounds N] [--clients M]
//! fedmask run --config exp.json [--out csv]
//! fedmask eq6 --c0 1.0 --beta 0.1 --gamma 0.5 --rounds 50
//! fedmask inspect [--artifacts dir]
//! fedmask help [command]
//! ```

use fedmask::config::experiment::ExperimentConfig;
use fedmask::figures;
use fedmask::fl::chaos::{FaultPlan, Scenario};
use fedmask::fl::server::Server;
use fedmask::runtime::manifest::Manifest;
use fedmask::transport::codec::Encoding;
use fedmask::transport::cost::eq6_cost;
use fedmask::transport::link::TransportKind;
use fedmask::util::cli::{render_help, Args, OptSpec};
use fedmask::util::error::Result;
use fedmask::util::logging;

const RUN_OPTS: &[OptSpec] = &[
    OptSpec::value("config", "experiment JSON config path"),
    OptSpec::value("out", "write per-round CSV here"),
    OptSpec::value("save-config", "write the resolved config JSON here"),
    OptSpec::value("transport", "upload wire: inproc|tcp|uds (overrides config)"),
    OptSpec::value(
        "encoding",
        "wire encoding: dense|sparse|sparse-delta|auto|auto-q8|auto-q4|sparse-cached|grouped-q8 \
         (overrides config)",
    ),
    OptSpec::flag(
        "downlink-delta",
        "ship the broadcast as an encoded delta over the downlink wire (overrides config)",
    ),
    OptSpec::value(
        "agg-shards",
        "aggregation tree width: 1 = single-threaded fold, N>1 = N shard workers (overrides config)",
    ),
    OptSpec::value(
        "drain-poll-ms",
        "upload drain poll interval in milliseconds (overrides config)",
    ),
    OptSpec::value(
        "max-conns",
        "socket reactor admission cap: max concurrent connections (overrides config)",
    ),
    OptSpec::value(
        "scenario",
        "failure scenario: a JSON file path or a built-in name (clean|lossy-uplink|duplicator|flaky-sessions|byzantine-one|chaos-soup|scrambled-arrivals|malformed-peers|spoofed-tokens); applied before other flags",
    ),
    OptSpec::value("ack-prob", "client availability: ACK probability in [0,1] (overrides config)"),
    OptSpec::value(
        "straggler-prob",
        "probability an ACKed client straggles past the deadline (overrides config)",
    ),
    OptSpec::value(
        "compute-jitter",
        "±fractional compute-time jitter in [0,1]; orders deliveries under the simulated network",
    ),
    OptSpec::value("chaos-seed", "fault-injection seed (any --chaos-* flag enables the harness)"),
    OptSpec::value("chaos-drop", "per-(round,client) upload drop probability"),
    OptSpec::value("chaos-dup", "per-(round,client) upload duplication probability"),
    OptSpec::value("chaos-corrupt", "per-(round,client) payload corruption probability"),
    OptSpec::value("chaos-delay", "per-(round,client) past-the-round delay probability"),
    OptSpec::value("chaos-disconnect-uplink", "mid-round uplink disconnect probability"),
    OptSpec::value("chaos-disconnect-downlink", "mid-round downlink disconnect probability"),
    OptSpec::value(
        "chaos-byzantine",
        "comma-separated client ids that upload well-formed wrong payloads every round",
    ),
    OptSpec::flag("chaos-reorder", "buffer and shuffle upload arrivals in seeded windows"),
];

const EQ6_OPTS: &[OptSpec] = &[
    OptSpec::value("c0", "initial sampling rate C (default 1.0)"),
    OptSpec::value("beta", "decay coefficient (default 0.1)"),
    OptSpec::value("gamma", "masking rate (default 1.0)"),
    OptSpec::value("rounds", "communication rounds R (default 50)"),
];

const INSPECT_OPTS: &[OptSpec] = &[OptSpec::value("artifacts", "artifacts directory")];

fn usage() -> String {
    let figs = figures::ALL.join("|");
    format!(
        "fedmask — communication-efficient federated learning (Ji et al. 2020 reproduction)\n\n\
         usage:\n\
         \x20 fedmask figure <{figs}> [options]   regenerate a paper table/figure\n\
         \x20 fedmask run --config exp.json        run one experiment from JSON\n\
         \x20 fedmask eq6 [options]                evaluate the Eq. 6 cost closed form\n\
         \x20 fedmask inspect                      describe the loaded artifacts\n\
         \x20 fedmask help <command>               detailed options\n"
    )
}

fn cmd_figure(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv.to_vec(), figures::common::FIGURE_OPTS)?;
    let id = args
        .positional
        .first()
        .ok_or_else(|| fedmask::Error::invalid(format!("figure id required: {}", figures::ALL.join(", "))))?;
    figures::run(id, &args)
}

fn cmd_run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv.to_vec(), RUN_OPTS)?;
    let config_path = args
        .get("config")
        .ok_or_else(|| fedmask::Error::invalid("--config is required"))?;
    let mut cfg = ExperimentConfig::load(std::path::Path::new(config_path))?;
    // a scenario rewrites the failure environment wholesale; individual
    // flags below then override its pieces
    if let Some(spec) = args.get("scenario") {
        Scenario::resolve(spec)?.apply(&mut cfg);
    }
    if let Some(spec) = args.get("transport") {
        cfg.transport = TransportKind::parse(spec)?;
    }
    if let Some(spec) = args.get("encoding") {
        cfg.encoding = Encoding::parse(spec)?;
    }
    if args.has_flag("downlink-delta") {
        cfg.downlink_delta = true;
    }
    if let Some(spec) = args.get("agg-shards") {
        cfg.agg_shards = spec
            .parse::<usize>()
            .map_err(|_| fedmask::Error::invalid(format!("--agg-shards: not a count: {spec}")))?;
    }
    if let Some(spec) = args.get("drain-poll-ms") {
        cfg.drain_poll_ms = spec
            .parse::<u64>()
            .map_err(|_| fedmask::Error::invalid(format!("--drain-poll-ms: not a duration: {spec}")))?;
    }
    if let Some(spec) = args.get("max-conns") {
        cfg.max_conns = spec
            .parse::<usize>()
            .map_err(|_| fedmask::Error::invalid(format!("--max-conns: not a count: {spec}")))?;
    }
    let prob = |flag: &str| -> Result<Option<f64>> {
        args.get(flag)
            .map(|spec| {
                spec.parse::<f64>()
                    .map_err(|_| fedmask::Error::invalid(format!("--{flag}: not a probability: {spec}")))
            })
            .transpose()
    };
    if let Some(v) = prob("ack-prob")? {
        cfg.ack_prob = v;
    }
    if let Some(v) = prob("straggler-prob")? {
        cfg.straggler_prob = v;
    }
    if let Some(v) = prob("compute-jitter")? {
        cfg.compute_jitter = v;
    }
    // any --chaos-* flag activates (or extends the scenario's) fault plan
    {
        fn plan(cfg: &mut ExperimentConfig) -> &mut FaultPlan {
            cfg.chaos.get_or_insert_with(FaultPlan::default)
        }
        if let Some(spec) = args.get("chaos-seed") {
            plan(&mut cfg).seed = spec
                .parse::<u64>()
                .map_err(|_| fedmask::Error::invalid(format!("--chaos-seed: not a seed: {spec}")))?;
        }
        if let Some(v) = prob("chaos-drop")? {
            plan(&mut cfg).drop_prob = v;
        }
        if let Some(v) = prob("chaos-dup")? {
            plan(&mut cfg).dup_prob = v;
        }
        if let Some(v) = prob("chaos-corrupt")? {
            plan(&mut cfg).corrupt_prob = v;
        }
        if let Some(v) = prob("chaos-delay")? {
            plan(&mut cfg).delay_prob = v;
        }
        if let Some(v) = prob("chaos-disconnect-uplink")? {
            plan(&mut cfg).disconnect_uplink_prob = v;
        }
        if let Some(v) = prob("chaos-disconnect-downlink")? {
            plan(&mut cfg).disconnect_downlink_prob = v;
        }
        if let Some(spec) = args.get("chaos-byzantine") {
            plan(&mut cfg).byzantine_clients = spec
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse::<u32>().map_err(|_| {
                        fedmask::Error::invalid(format!("--chaos-byzantine: not a client id: {s}"))
                    })
                })
                .collect::<Result<Vec<u32>>>()?;
        }
        if args.has_flag("chaos-reorder") {
            plan(&mut cfg).reorder = true;
        }
    }
    // overrides bypass load-time validation; re-check the merged config
    cfg.validate()?;
    if let Some(path) = args.get("save-config") {
        cfg.save(std::path::Path::new(path))?;
    }
    let manifest = Manifest::load("artifacts")?;
    let outcome = Server::new(cfg, &manifest)?.run()?;
    println!("{}", outcome.recorder.summary());
    if let Some(path) = args.get("out") {
        outcome.recorder.write_csv(std::path::Path::new(path))?;
        eprintln!("wrote {path}");
    } else {
        outcome.recorder.table().print();
    }
    Ok(())
}

fn cmd_eq6(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv.to_vec(), EQ6_OPTS)?;
    let c0 = args.get_or("c0", 1.0f64)?;
    let beta = args.get_or("beta", 0.1f64)?;
    let gamma = args.get_or("gamma", 1.0f64)?;
    let rounds = args.get_or("rounds", 50usize)?;
    let cost = eq6_cost(c0, beta, gamma, rounds);
    println!(
        "f(beta={beta}, gamma={gamma}) over R={rounds} rounds with C={c0}: \
         {cost:.6} units/round/client ({:.2}% of static dense)",
        100.0 * cost / (c0 * 1.0)
    );
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv.to_vec(), INSPECT_OPTS)?;
    let manifest = Manifest::load(args.get("artifacts").unwrap_or("artifacts"))?;
    print!("{}", fedmask::model::describe_manifest(&manifest));
    Ok(())
}

fn cmd_help(argv: &[String]) {
    match argv.first().map(String::as_str) {
        Some("figure") => print!(
            "{}",
            render_help("fedmask figure", "regenerate a paper table/figure", figures::common::FIGURE_OPTS)
        ),
        Some("run") => print!("{}", render_help("fedmask run", "run one experiment", RUN_OPTS)),
        Some("eq6") => print!("{}", render_help("fedmask eq6", "Eq. 6 closed form", EQ6_OPTS)),
        Some("inspect") => print!(
            "{}",
            render_help("fedmask inspect", "describe loaded artifacts", INSPECT_OPTS)
        ),
        _ => print!("{}", usage()),
    }
}

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            print!("{}", usage());
            std::process::exit(2);
        }
    };
    let result = match cmd {
        "figure" => cmd_figure(&rest),
        "run" => cmd_run(&rest),
        "eq6" => cmd_eq6(&rest),
        "inspect" => cmd_inspect(&rest),
        "help" | "--help" | "-h" => {
            cmd_help(&rest);
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
