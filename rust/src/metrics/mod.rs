//! Metrics: per-round records, run summaries, CSV output.

pub mod csv;
pub mod recorder;

pub use recorder::{RoundRecord, RunRecorder};
