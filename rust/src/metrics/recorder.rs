//! Per-round experiment records and the run-level recorder.

use std::path::Path;

use crate::fl::chaos::FaultLog;
use crate::metrics::csv::{fmt, Table};
use crate::util::error::Result;

/// Everything the coordinator knows at the end of one federated round.
/// `PartialEq` is part of the chaos harness's determinism contract: two
/// runs with the same seeds must produce equal records, fault log
/// included.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    /// Sampling rate used this round (c in the paper).
    pub sample_rate: f64,
    /// Clients actually aggregated.
    pub clients: usize,
    /// Mean local training loss across selected clients.
    pub train_loss: f64,
    /// Test metrics (NaN if this round was not evaluated).
    pub test_loss: f64,
    pub test_accuracy: f64,
    pub test_perplexity: f64,
    /// Cumulative uplink cost in full-model units (paper metric).
    pub uplink_units: f64,
    /// Cumulative uplink bytes (codec-accurate).
    pub uplink_bytes: u64,
    /// Cumulative downlink bytes (codec-accurate; shrinks under
    /// delta-encoded broadcasts).
    pub downlink_bytes: u64,
    /// Max |reconstructed - global| of this round's broadcast under
    /// `downlink_delta` (0.0 for dense broadcasts). The server asserts it
    /// stays within the codec's quantizer half-step; the figure sweeps
    /// record it so flipping the delta-downlink default is data-backed.
    pub downlink_recon_err: f64,
    /// Virtual wall-clock seconds elapsed.
    pub virtual_time_s: f64,
    /// Faults the chaos harness injected this round (empty when the
    /// harness is off) — drops, duplicates, corruptions, disconnects,
    /// Byzantine uploads, in canonical (client, kind) order.
    pub faults: FaultLog,
}

/// Collects round records and renders them as CSV / summaries.
#[derive(Debug, Clone, Default)]
pub struct RunRecorder {
    pub label: String,
    pub rounds: Vec<RoundRecord>,
}

impl RunRecorder {
    pub fn new(label: impl Into<String>) -> RunRecorder {
        RunRecorder {
            label: label.into(),
            rounds: Vec::new(),
        }
    }

    pub fn push(&mut self, rec: RoundRecord) {
        self.rounds.push(rec);
    }

    pub fn last(&self) -> Option<&RoundRecord> {
        self.rounds.last()
    }

    /// Last round that carried an evaluation.
    pub fn last_evaluated(&self) -> Option<&RoundRecord> {
        self.rounds.iter().rev().find(|r| !r.test_loss.is_nan())
    }

    /// Final test accuracy (image tasks).
    pub fn final_accuracy(&self) -> f64 {
        self.last_evaluated().map(|r| r.test_accuracy).unwrap_or(f64::NAN)
    }

    /// Final test perplexity (LM tasks).
    pub fn final_perplexity(&self) -> f64 {
        self.last_evaluated().map(|r| r.test_perplexity).unwrap_or(f64::NAN)
    }

    /// Total uplink units spent (cumulative of the last round).
    pub fn total_uplink_units(&self) -> f64 {
        self.last().map(|r| r.uplink_units).unwrap_or(0.0)
    }

    /// CSV with one row per round.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&[
            "label",
            "round",
            "sample_rate",
            "clients",
            "train_loss",
            "test_loss",
            "test_accuracy",
            "test_perplexity",
            "uplink_units",
            "uplink_bytes",
            "downlink_bytes",
            "downlink_recon_err",
            "virtual_time_s",
            "faults",
        ]);
        for r in &self.rounds {
            t.push(vec![
                self.label.clone(),
                r.round.to_string(),
                fmt(r.sample_rate),
                r.clients.to_string(),
                fmt(r.train_loss),
                fmt(r.test_loss),
                fmt(r.test_accuracy),
                fmt(r.test_perplexity),
                fmt(r.uplink_units),
                r.uplink_bytes.to_string(),
                r.downlink_bytes.to_string(),
                fmt(r.downlink_recon_err),
                fmt(r.virtual_time_s),
                r.faults.events.len().to_string(),
            ]);
        }
        t
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        self.table().write(path)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        match self.last_evaluated() {
            Some(r) => format!(
                "{}: round {} acc {:.4} ppl {:.2} loss {:.4} | uplink {:.2} units / {} bytes",
                self.label,
                r.round,
                r.test_accuracy,
                r.test_perplexity,
                r.test_loss,
                self.total_uplink_units(),
                self.last().map(|l| l.uplink_bytes).unwrap_or(0),
            ),
            None => format!("{}: no evaluated rounds", self.label),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: f64, units: f64) -> RoundRecord {
        RoundRecord {
            round,
            sample_rate: 1.0,
            clients: 10,
            train_loss: 1.0,
            test_loss: if acc.is_nan() { f64::NAN } else { 1.0 - acc },
            test_accuracy: acc,
            test_perplexity: f64::NAN,
            uplink_units: units,
            uplink_bytes: (units * 1000.0) as u64,
            downlink_bytes: (units * 4000.0) as u64,
            downlink_recon_err: 0.0,
            virtual_time_s: round as f64,
            faults: FaultLog::default(),
        }
    }

    #[test]
    fn tracks_last_evaluated_round() {
        let mut r = RunRecorder::new("test");
        r.push(rec(1, 0.5, 10.0));
        r.push(rec(2, f64::NAN, 20.0)); // unevaluated round
        assert_eq!(r.last_evaluated().unwrap().round, 1);
        assert!((r.final_accuracy() - 0.5).abs() < 1e-12);
        assert!((r.total_uplink_units() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn csv_has_row_per_round() {
        let mut r = RunRecorder::new("lbl");
        r.push(rec(1, 0.1, 1.0));
        r.push(rec(2, 0.2, 2.0));
        let rendered = r.table().render();
        assert_eq!(rendered.lines().count(), 3);
        assert!(rendered.starts_with("label,round"));
        assert!(rendered.contains("lbl,2"));
    }

    #[test]
    fn summary_mentions_label_and_accuracy() {
        let mut r = RunRecorder::new("fig3-static");
        r.push(rec(5, 0.87, 50.0));
        let s = r.summary();
        assert!(s.contains("fig3-static"));
        assert!(s.contains("0.87"));
    }

    #[test]
    fn empty_recorder_is_graceful() {
        let r = RunRecorder::new("empty");
        assert!(r.final_accuracy().is_nan());
        assert_eq!(r.total_uplink_units(), 0.0);
        assert!(r.summary().contains("no evaluated rounds"));
    }
}
