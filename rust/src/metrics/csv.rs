//! Tiny CSV writer (RFC 4180 quoting) — figure drivers emit their series
//! through this so results diff cleanly and plot with any tool.

use std::io::Write;
use std::path::Path;

use crate::util::error::Result;

/// Quote a field if it contains a delimiter, quote, or newline.
pub fn escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// In-memory CSV table with a fixed header.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Push a row; panics if the arity differs from the header (a driver
    /// bug we want loud, not silently ragged CSV).
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(
            row.len(),
            self.header.len(),
            "row arity {} != header {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|f| escape(f))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|f| escape(f)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.render().as_bytes())?;
        Ok(())
    }

    /// Also print to stdout (figure drivers do both).
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with enough precision for plotting without noise.
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1e6 || v.abs() < 1e-4 {
        format!("{v:.6e}")
    } else {
        format!("{v:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut t = Table::new(&["round", "acc"]);
        t.push(vec!["1".into(), "0.5".into()]);
        t.push(vec!["2".into(), "0.75".into()]);
        assert_eq!(t.render(), "round,acc\n1,0.5\n2,0.75\n");
    }

    #[test]
    fn escapes_delimiters_and_quotes() {
        assert_eq!(escape("a,b"), "\"a,b\"");
        assert_eq!(escape("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(escape("plain"), "plain");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn ragged_rows_panic() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["1".into()]);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(0.5), "0.500000");
        assert!(fmt(1e-7).contains('e'));
        assert!(fmt(3e9).contains('e'));
    }

    #[test]
    fn writes_to_disk() {
        let path = std::env::temp_dir()
            .join(format!("fedmask_csv_{}", std::process::id()))
            .join("t.csv");
        let mut t = Table::new(&["x"]);
        t.push(vec!["1".into()]);
        t.write(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "x\n1\n");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }
}
