//! `artifacts/manifest.json` — the contract between the python compile path
//! and the rust runtime.
//!
//! The manifest describes, per model: the flat parameter count `P`, the
//! batching geometry baked into the `train`/`eval` artifacts, the per-layer
//! table (name/shape/offset/size/masked) mirroring the L1 kernel's segment
//! metadata, and the artifact file names.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::json::{self, Json};

/// Version this runtime understands; bumped in lockstep with `aot.py`.
pub const SUPPORTED_VERSION: usize = 2;

/// One parameter tensor inside the flat vector.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    /// Eligible for masking (ndim >= 2 weight matrices, per Alg. 2/4).
    pub masked: bool,
}

/// Manifest entry for one model.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    /// Flat parameter count P.
    pub p: usize,
    /// "image" | "lm".
    pub task: String,
    /// Per-batch sample count B.
    pub batch: usize,
    /// Batches per train-epoch artifact call.
    pub nb_train: usize,
    /// Batches per eval-chunk artifact call.
    pub nb_eval: usize,
    /// Per-sample input shape.
    pub x_elem_shape: Vec<usize>,
    /// "f32" | "i32".
    pub x_dtype: String,
    /// Per-sample label shape (empty for image classification).
    pub y_elem_shape: Vec<usize>,
    pub layers: Vec<LayerInfo>,
    /// kind ("init"/"train"/"eval"/"mask") -> artifact file name.
    pub artifacts: BTreeMap<String, String>,
    /// Free-form metadata (vocab size etc.).
    pub meta: BTreeMap<String, Json>,
}

impl ModelManifest {
    /// Samples consumed by one train-epoch call.
    pub fn train_chunk_samples(&self) -> usize {
        self.nb_train * self.batch
    }

    /// Samples consumed by one eval-chunk call.
    pub fn eval_chunk_samples(&self) -> usize {
        self.nb_eval * self.batch
    }

    /// Elements per input sample.
    pub fn x_elem_len(&self) -> usize {
        self.x_elem_shape.iter().product::<usize>().max(1)
    }

    /// Elements per label sample.
    pub fn y_elem_len(&self) -> usize {
        self.y_elem_shape.iter().product::<usize>().max(1)
    }

    /// Number of maskable parameters (weights; biases pass through).
    pub fn maskable_params(&self) -> usize {
        self.layers.iter().filter(|l| l.masked).map(|l| l.size).sum()
    }

    /// Vocab size for LM models (from meta), if present.
    pub fn vocab(&self) -> Option<usize> {
        self.meta.get("vocab").and_then(|v| v.as_usize().ok())
    }

    fn validate(&self) -> Result<()> {
        let mut offset = 0;
        for l in &self.layers {
            if l.offset != offset {
                return Err(Error::invalid(format!(
                    "{}: layer '{}' offset {} != expected {offset}",
                    self.name, l.name, l.offset
                )));
            }
            let shape_size: usize = l.shape.iter().product();
            if shape_size != l.size {
                return Err(Error::invalid(format!(
                    "{}: layer '{}' shape/size mismatch",
                    self.name, l.name
                )));
            }
            offset += l.size;
        }
        if offset != self.p {
            return Err(Error::invalid(format!(
                "{}: layer sizes sum {offset} != p {}",
                self.name, self.p
            )));
        }
        for kind in ["init", "train", "eval", "mask"] {
            if !self.artifacts.contains_key(kind) {
                return Err(Error::invalid(format!(
                    "{}: missing artifact '{kind}'",
                    self.name
                )));
            }
        }
        Ok(())
    }
}

/// The whole artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Invalid(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let root = json::parse(&text)?;
        Self::from_json(&root, dir)
    }

    /// Parse from an already-loaded JSON document (tests use this).
    pub fn from_json(root: &Json, dir: PathBuf) -> Result<Manifest> {
        let version = root.get("version")?.as_usize()?;
        if version != SUPPORTED_VERSION {
            return Err(Error::invalid(format!(
                "manifest version {version} != supported {SUPPORTED_VERSION}; re-run `make artifacts`"
            )));
        }
        let mut models = BTreeMap::new();
        for (name, entry) in root.get("models")?.as_obj()? {
            let mm = parse_model(name, entry)?;
            mm.validate()?;
            models.insert(name.clone(), mm);
        }
        if models.is_empty() {
            return Err(Error::invalid("manifest has no models"));
        }
        Ok(Manifest { dir, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models.get(name).ok_or_else(|| {
            Error::invalid(format!(
                "model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            ))
        })
    }

    /// Absolute path of an artifact file for (model, kind).
    pub fn artifact_path(&self, model: &str, kind: &str) -> Result<PathBuf> {
        let mm = self.model(model)?;
        let fname = mm
            .artifacts
            .get(kind)
            .ok_or_else(|| Error::invalid(format!("{model}: no artifact kind '{kind}'")))?;
        Ok(self.dir.join(fname))
    }
}

fn parse_model(name: &str, entry: &Json) -> Result<ModelManifest> {
    let layers = entry
        .get("layers")?
        .as_arr()?
        .iter()
        .map(|l| {
            Ok(LayerInfo {
                name: l.get("name")?.as_str()?.to_string(),
                shape: l.get("shape")?.as_usize_vec()?,
                offset: l.get("offset")?.as_usize()?,
                size: l.get("size")?.as_usize()?,
                masked: l.get("masked")?.as_bool()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let artifacts = entry
        .get("artifacts")?
        .as_obj()?
        .iter()
        .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
        .collect::<Result<BTreeMap<_, _>>>()?;
    let meta = entry
        .opt("meta")
        .and_then(|m| m.as_obj().ok())
        .map(|m| m.clone())
        .unwrap_or_default();
    Ok(ModelManifest {
        name: name.to_string(),
        p: entry.get("p")?.as_usize()?,
        task: entry.get("task")?.as_str()?.to_string(),
        batch: entry.get("batch")?.as_usize()?,
        nb_train: entry.get("nb_train")?.as_usize()?,
        nb_eval: entry.get("nb_eval")?.as_usize()?,
        x_elem_shape: entry.get("x_elem_shape")?.as_usize_vec()?,
        x_dtype: entry.get("x_dtype")?.as_str()?.to_string(),
        y_elem_shape: entry.get("y_elem_shape")?.as_usize_vec()?,
        layers,
        artifacts,
        meta,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> String {
        r#"{
          "version": 2,
          "models": {
            "toy": {
              "p": 6,
              "task": "image",
              "batch": 2,
              "nb_train": 3,
              "nb_eval": 1,
              "x_elem_shape": [2],
              "x_dtype": "f32",
              "y_elem_shape": [],
              "layers": [
                {"name": "w", "shape": [2, 2], "offset": 0, "size": 4, "masked": true},
                {"name": "b", "shape": [2], "offset": 4, "size": 2, "masked": false}
              ],
              "meta": {"vocab": 100},
              "artifacts": {"init": "t_i.hlo.txt", "train": "t_t.hlo.txt",
                            "eval": "t_e.hlo.txt", "mask": "t_m.hlo.txt"}
            }
          }
        }"#
        .to_string()
    }

    #[test]
    fn parses_and_validates() {
        let root = json::parse(&sample_json()).unwrap();
        let m = Manifest::from_json(&root, PathBuf::from("/tmp/a")).unwrap();
        let toy = m.model("toy").unwrap();
        assert_eq!(toy.p, 6);
        assert_eq!(toy.maskable_params(), 4);
        assert_eq!(toy.train_chunk_samples(), 6);
        assert_eq!(toy.vocab(), Some(100));
        assert_eq!(
            m.artifact_path("toy", "train").unwrap(),
            PathBuf::from("/tmp/a/t_t.hlo.txt")
        );
    }

    #[test]
    fn rejects_wrong_version() {
        let src = sample_json().replace("\"version\": 2", "\"version\": 1");
        let root = json::parse(&src).unwrap();
        let err = Manifest::from_json(&root, PathBuf::from("/tmp")).unwrap_err();
        assert!(err.to_string().contains("version"));
    }

    #[test]
    fn rejects_bad_offsets() {
        let src = sample_json().replace("\"offset\": 4", "\"offset\": 5");
        let root = json::parse(&src).unwrap();
        assert!(Manifest::from_json(&root, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_missing_artifact_kind() {
        let src = sample_json().replace("\"mask\": \"t_m.hlo.txt\"", "\"other\": \"x\"");
        let root = json::parse(&src).unwrap();
        let err = Manifest::from_json(&root, PathBuf::from("/tmp")).unwrap_err();
        assert!(err.to_string().contains("mask"));
    }

    #[test]
    fn unknown_model_lists_available() {
        let root = json::parse(&sample_json()).unwrap();
        let m = Manifest::from_json(&root, PathBuf::from("/tmp")).unwrap();
        let err = m.model("lenet").unwrap_err().to_string();
        assert!(err.contains("toy"));
    }
}
