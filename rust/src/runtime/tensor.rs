//! Host-side batch containers matching the artifact input shapes.
//!
//! The train artifact takes `xs[NB, B, ...]` / `ys[NB, B, ...]`; this module
//! owns those flattened buffers plus the dtype tag, and converts them into
//! `xla::Literal`s at the engine boundary.

use crate::runtime::manifest::ModelManifest;
use crate::util::error::{Error, Result};

/// Element type of the input tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemType {
    F32,
    I32,
}

impl ElemType {
    pub fn parse(s: &str) -> Result<ElemType> {
        match s {
            "f32" => Ok(ElemType::F32),
            "i32" => Ok(ElemType::I32),
            other => Err(Error::invalid(format!("unsupported dtype '{other}'"))),
        }
    }
}

/// Raw input data, either f32 (images) or i32 (token ids).
#[derive(Debug, Clone, PartialEq)]
pub enum XData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl XData {
    pub fn len(&self) -> usize {
        match self {
            XData::F32(v) => v.len(),
            XData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn elem_type(&self) -> ElemType {
        match self {
            XData::F32(_) => ElemType::F32,
            XData::I32(_) => ElemType::I32,
        }
    }
}

/// One artifact-call worth of batches: `nb` batches of `batch` samples.
///
/// Invariants (checked by [`Batches::new`]):
/// * `xs.len() == nb * batch * x_elem_len`
/// * `ys.len() == nb * batch * y_elem_len`
#[derive(Debug, Clone, PartialEq)]
pub struct Batches {
    pub nb: usize,
    pub batch: usize,
    pub x_elem_shape: Vec<usize>,
    pub y_elem_shape: Vec<usize>,
    pub xs: XData,
    pub ys: Vec<i32>,
}

impl Batches {
    pub fn new(
        nb: usize,
        batch: usize,
        x_elem_shape: Vec<usize>,
        y_elem_shape: Vec<usize>,
        xs: XData,
        ys: Vec<i32>,
    ) -> Result<Batches> {
        let x_elem: usize = x_elem_shape.iter().product::<usize>().max(1);
        let y_elem: usize = y_elem_shape.iter().product::<usize>().max(1);
        if xs.len() != nb * batch * x_elem {
            return Err(Error::invalid(format!(
                "xs len {} != nb*batch*x_elem {}",
                xs.len(),
                nb * batch * x_elem
            )));
        }
        if ys.len() != nb * batch * y_elem {
            return Err(Error::invalid(format!(
                "ys len {} != nb*batch*y_elem {}",
                ys.len(),
                nb * batch * y_elem
            )));
        }
        Ok(Batches {
            nb,
            batch,
            x_elem_shape,
            y_elem_shape,
            xs,
            ys,
        })
    }

    /// Total sample count in this chunk.
    pub fn samples(&self) -> usize {
        self.nb * self.batch
    }

    /// Full xs dims for the literal: `[nb, batch, ...x_elem_shape]`.
    pub fn x_dims(&self) -> Vec<i64> {
        let mut d = vec![self.nb as i64, self.batch as i64];
        d.extend(self.x_elem_shape.iter().map(|&s| s as i64));
        d
    }

    /// Full ys dims for the literal: `[nb, batch, ...y_elem_shape]`.
    pub fn y_dims(&self) -> Vec<i64> {
        let mut d = vec![self.nb as i64, self.batch as i64];
        d.extend(self.y_elem_shape.iter().map(|&s| s as i64));
        d
    }

    /// Check this chunk is compatible with a model's train artifact.
    pub fn check_train(&self, mm: &ModelManifest) -> Result<()> {
        self.check(mm, mm.nb_train, "train")
    }

    /// Check this chunk is compatible with a model's eval artifact.
    pub fn check_eval(&self, mm: &ModelManifest) -> Result<()> {
        self.check(mm, mm.nb_eval, "eval")
    }

    fn check(&self, mm: &ModelManifest, nb: usize, kind: &str) -> Result<()> {
        if self.nb != nb || self.batch != mm.batch {
            return Err(Error::invalid(format!(
                "{kind} chunk geometry ({}, {}) != artifact ({nb}, {})",
                self.nb, self.batch, mm.batch
            )));
        }
        if self.x_elem_shape != mm.x_elem_shape {
            return Err(Error::invalid(format!(
                "{kind} x_elem_shape {:?} != artifact {:?}",
                self.x_elem_shape, mm.x_elem_shape
            )));
        }
        let want = ElemType::parse(&mm.x_dtype)?;
        if self.xs.elem_type() != want {
            return Err(Error::invalid(format!("{kind} dtype mismatch")));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_invariants_enforced() {
        let xs = XData::F32(vec![0.0; 2 * 3 * 4]);
        let ys = vec![0i32; 2 * 3];
        let b = Batches::new(2, 3, vec![4], vec![], xs, ys).unwrap();
        assert_eq!(b.samples(), 6);
        assert_eq!(b.x_dims(), vec![2, 3, 4]);
        assert_eq!(b.y_dims(), vec![2, 3]);
    }

    #[test]
    fn wrong_lengths_rejected() {
        let xs = XData::F32(vec![0.0; 5]);
        assert!(Batches::new(2, 3, vec![4], vec![], xs, vec![0; 6]).is_err());
        let xs = XData::F32(vec![0.0; 24]);
        assert!(Batches::new(2, 3, vec![4], vec![], xs, vec![0; 5]).is_err());
    }

    #[test]
    fn lm_label_shape() {
        let xs = XData::I32(vec![0; 2 * 3 * 8]);
        let ys = vec![0i32; 2 * 3 * 8];
        let b = Batches::new(2, 3, vec![8], vec![8], xs, ys).unwrap();
        assert_eq!(b.y_dims(), vec![2, 3, 8]);
    }

    #[test]
    fn elem_type_parse() {
        assert_eq!(ElemType::parse("f32").unwrap(), ElemType::F32);
        assert_eq!(ElemType::parse("i32").unwrap(), ElemType::I32);
        assert!(ElemType::parse("f64").is_err());
    }
}
