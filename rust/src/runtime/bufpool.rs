//! A shared pool of reusable `Vec<u8>` payload buffers — the encode-side
//! analog of the decoder's `DecodeScratch` discipline.
//!
//! Every client upload used to allocate its wire frame fresh
//! (`Vec::with_capacity` in the encoder) and drop it after the server
//! fold — one allocation plus one deallocation per client per round, on
//! the hottest path the simulation has. The pool closes that loop:
//! workers [`BufferPool::take`] a buffer before encoding, the payload
//! travels through the transport as a plain owned `Vec<u8>` (no wrapper
//! type, so the `UploadSink`/`Transport` signatures are untouched), and
//! the round driver [`BufferPool::put`]s it back once the fold consumed
//! it. After the first round every buffer in steady state has warmed to
//! the largest frame its slot has seen, and the encode path performs
//! zero heap allocation — pinned by `tests/alloc_count.rs`, and described
//! in `docs/SCALE.md` §"Hot path & memory".
//!
//! Design constraints, in order:
//!
//! * **Unintrusive** — `take` hands out a plain `Vec<u8>` (cleared, with
//!   whatever capacity its previous life earned); `put` accepts any
//!   `Vec<u8>`, including ones the pool never issued. Payloads that exit
//!   through a path that cannot return them (a sharded aggregation
//!   worker, a socket writer) are simply dropped — the pool refills
//!   lazily; recycling is an optimization, never a correctness
//!   obligation.
//! * **Bounded** — at most [`BufferPool::MAX_POOLED`] buffers are
//!   retained; beyond that `put` drops. A burst can therefore never pin
//!   unbounded memory on the pool.
//! * **Panic-free** — this type sits on the upload hot path next to
//!   untrusted-input code, so it observes the same `fedlint` panic-free
//!   discipline (`lint::panic_free::SCOPE`): a poisoned mutex degrades to
//!   allocate-fresh / drop, never a panic.

use std::sync::Mutex;

/// A bounded, mutex-guarded stack of cleared `Vec<u8>` buffers shared by
/// every worker of an engine pool and the round driver's drain loop.
#[derive(Debug, Default)]
pub struct BufferPool {
    slots: Mutex<Vec<Vec<u8>>>,
}

impl BufferPool {
    /// Retention bound: `put` beyond this many pooled buffers drops the
    /// buffer instead. Sized to the largest worker fan-out the engine
    /// pool reaches plus in-flight frames in the drain loop.
    pub const MAX_POOLED: usize = 64;

    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    /// Check out a cleared buffer: a recycled one when available (keeping
    /// the capacity it earned in earlier rounds), a fresh empty `Vec`
    /// otherwise. A poisoned pool degrades to the fresh path.
    pub fn take(&self) -> Vec<u8> {
        match self.slots.lock() {
            Ok(mut slots) => slots.pop().unwrap_or_default(),
            Err(_) => Vec::new(),
        }
    }

    /// Return a buffer to the pool. The buffer is cleared here (length
    /// zero, capacity kept) so a future `take` can never observe stale
    /// bytes. Zero-capacity buffers and overflow beyond
    /// [`Self::MAX_POOLED`] are dropped; a poisoned pool drops too.
    pub fn put(&self, mut buf: Vec<u8>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        if let Ok(mut slots) = self.slots.lock() {
            if slots.len() < Self::MAX_POOLED {
                slots.push(buf);
            }
        }
    }

    /// Buffers currently pooled (diagnostics / tests).
    pub fn pooled(&self) -> usize {
        match self.slots.lock() {
            Ok(slots) => slots.len(),
            Err(_) => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_from_empty_pool_is_a_fresh_buffer() {
        let pool = BufferPool::new();
        let b = pool.take();
        assert!(b.is_empty());
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn put_take_roundtrip_preserves_capacity_and_clears_contents() {
        let pool = BufferPool::new();
        let mut b = pool.take();
        b.extend_from_slice(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let cap = b.capacity();
        pool.put(b);
        assert_eq!(pool.pooled(), 1);
        let b = pool.take();
        assert!(b.is_empty(), "recycled buffer must come back cleared");
        assert_eq!(b.capacity(), cap, "recycled buffer must keep its capacity");
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn zero_capacity_buffers_are_not_pooled() {
        let pool = BufferPool::new();
        pool.put(Vec::new());
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn retention_is_bounded() {
        let pool = BufferPool::new();
        for _ in 0..BufferPool::MAX_POOLED + 10 {
            pool.put(Vec::with_capacity(16));
        }
        assert_eq!(pool.pooled(), BufferPool::MAX_POOLED);
    }

    #[test]
    fn foreign_buffers_are_accepted() {
        // the drain loop returns payloads the pool never issued (e.g. a
        // socket transport's read buffer) — that must just work
        let pool = BufferPool::new();
        pool.put(vec![9u8; 100]);
        assert_eq!(pool.pooled(), 1);
        assert_eq!(pool.take().capacity(), 100);
    }
}
