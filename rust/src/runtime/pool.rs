//! Multi-worker engine pool.
//!
//! The PJRT wrappers are not `Send`, so an [`Engine`] can never cross
//! threads. The pool instead spawns `workers` threads that each construct
//! their **own** engine (own PJRT client + compiled executables) and pull
//! jobs from a shared channel. Client-local training within a federated
//! round fans out across workers; results come back over per-job reply
//! channels.
//!
//! Each worker also owns a [`WorkerScratch`] — reusable buffers (masking
//! arena, wire-encode temporaries) that live as long as the worker thread,
//! so steady-state rounds stop allocating per client job. Scratch-aware
//! jobs receive it via [`EnginePool::map_unordered_with`]; the plain
//! `submit`/`map`/`map_unordered` entry points keep the engine-only
//! signature for callers that don't need it.
//!
//! The pool is transport-agnostic by design: a federated client job
//! uploads its encoded payload through the round's
//! [`UploadSink`](crate::transport::link::UploadSink) (an `Arc` captured
//! by the closure) from the worker thread, and only sideband metadata
//! rides the pool's own reply channel — which is what lets
//! `Server::run_round` be generic over in-process, TCP, and UDS wires
//! without the pool knowing sockets exist.
//!
//! Compilation cost is paid once per worker at startup; the figure drivers
//! amortize it over hundreds of rounds.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::fl::masking::MaskScratch;
use crate::runtime::bufpool::BufferPool;
use crate::runtime::engine::Engine;
use crate::runtime::manifest::Manifest;
use crate::transport::codec::{EncodeScratch, MaskedStream};
use crate::util::error::{Error, Result};

/// Per-worker reusable buffers, created once per worker thread and threaded
/// through every scratch-aware job it runs.
#[derive(Debug)]
pub struct WorkerScratch {
    /// Selective-masking arena (per-segment deltas + partition workspace).
    pub mask: MaskScratch,
    /// Wire-encode temporaries (q8 value gather, set-delta, code buffer).
    pub encode: EncodeScratch,
    /// The fused pipeline's kept-pairs + census-sideband stream
    /// (`fl::pipeline` fills it, `encode_masked` drains it).
    pub stream: MaskedStream,
    /// Payload-frame pool shared by every worker of the pool and the round
    /// driver's drain loop (take before encode, put after fold). Defaults
    /// to a private pool so standalone scratches still recycle per-worker.
    pub buffers: Arc<BufferPool>,
}

impl Default for WorkerScratch {
    fn default() -> WorkerScratch {
        WorkerScratch {
            mask: MaskScratch::default(),
            encode: EncodeScratch::default(),
            stream: MaskedStream::default(),
            buffers: Arc::new(BufferPool::new()),
        }
    }
}

type Job = Box<dyn FnOnce(&Engine, &mut WorkerScratch) + Send + 'static>;

/// A pool of engine-owning worker threads.
pub struct EnginePool {
    tx: Sender<Job>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    /// The payload-frame pool every worker's [`WorkerScratch`] shares;
    /// the server hands the same `Arc` to the round driver so drained
    /// payloads flow back to the encoders.
    buffers: Arc<BufferPool>,
}

impl EnginePool {
    /// Spawn `workers` threads, each compiling `models` from `manifest`.
    /// Fails fast if any worker fails to build its engine.
    pub fn new(manifest: &Manifest, models: &[&str], workers: usize) -> Result<EnginePool> {
        assert!(workers >= 1, "need at least one worker");
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let buffers = Arc::new(BufferPool::new());
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let rx = Arc::clone(&rx);
            let ready = ready_tx.clone();
            let manifest = manifest.clone();
            let models: Vec<String> = models.iter().map(|s| s.to_string()).collect();
            let worker_buffers = Arc::clone(&buffers);
            handles.push(std::thread::spawn(move || {
                let model_refs: Vec<&str> = models.iter().map(String::as_str).collect();
                let engine = match Engine::load(&manifest, &model_refs) {
                    Ok(e) => {
                        let _ = ready.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                log::debug!("engine pool worker {wid} ready");
                let mut scratch = WorkerScratch {
                    buffers: worker_buffers,
                    ..WorkerScratch::default()
                };
                loop {
                    // Hold the lock only while receiving, not while running.
                    let job = match rx.lock() {
                        Ok(guard) => guard.recv(),
                        Err(_) => break,
                    };
                    match job {
                        Ok(job) => job(&engine, &mut scratch),
                        Err(_) => break, // sender dropped: shutdown
                    }
                }
            }));
        }
        drop(ready_tx);
        for _ in 0..workers {
            ready_rx
                .recv()
                .map_err(|_| Error::Engine("worker died during startup".into()))??;
        }
        Ok(EnginePool {
            tx,
            handles,
            workers,
            buffers,
        })
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The shared payload-frame pool — hand this to the round driver
    /// ([`crate::fl::driver::RoundDriver::attach_buffer_pool`]) so frames
    /// drained by the serial fold loop return to the encode side.
    pub fn buffer_pool(&self) -> &Arc<BufferPool> {
        &self.buffers
    }

    /// Submit a job; returns a receiver for its result.
    pub fn submit<R, F>(&self, f: F) -> Receiver<R>
    where
        R: Send + 'static,
        F: FnOnce(&Engine) -> R + Send + 'static,
    {
        let (tx, rx) = channel();
        let job: Job = Box::new(move |engine, _scratch| {
            let _ = tx.send(f(engine));
        });
        // Send fails only if all workers are gone; surfaced on recv.
        let _ = self.tx.send(job);
        rx
    }

    /// Run a batch of jobs and collect results **in input order**.
    pub fn map<R, F>(&self, jobs: Vec<F>) -> Result<Vec<R>>
    where
        R: Send + 'static,
        F: FnOnce(&Engine) -> R + Send + 'static,
    {
        let receivers: Vec<Receiver<R>> = jobs.into_iter().map(|f| self.submit(f)).collect();
        receivers
            .into_iter()
            .map(|rx| {
                rx.recv()
                    .map_err(|_| Error::Engine("worker dropped job (thread died?)".into()))
            })
            .collect()
    }

    /// Run a batch of jobs and yield `(input index, result)` pairs **in
    /// completion order** over a single channel, so the caller can start
    /// consuming results while the slowest jobs are still running (the
    /// server folds aggregation in here instead of barriering on the
    /// cohort). The channel closes once every job has reported; if worker
    /// threads die mid-batch, iteration ends early and the caller sees
    /// fewer than `jobs.len()` results.
    pub fn map_unordered<R, F>(&self, jobs: Vec<F>) -> Receiver<(usize, R)>
    where
        R: Send + 'static,
        F: FnOnce(&Engine) -> R + Send + 'static,
    {
        self.map_unordered_with(
            jobs.into_iter()
                .map(|f| move |e: &Engine, _s: &mut WorkerScratch| f(e))
                .collect(),
        )
    }

    /// [`Self::map_unordered`] for scratch-aware jobs: each closure also
    /// receives its worker's long-lived [`WorkerScratch`], so per-job
    /// buffers (mask arena, encode temporaries) are reused across the whole
    /// run instead of allocated per client per round.
    pub fn map_unordered_with<R, F>(&self, jobs: Vec<F>) -> Receiver<(usize, R)>
    where
        R: Send + 'static,
        F: FnOnce(&Engine, &mut WorkerScratch) -> R + Send + 'static,
    {
        let (tx, rx) = channel();
        for (i, f) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            let job: Job = Box::new(move |engine, scratch| {
                let _ = tx.send((i, f(engine, scratch)));
            });
            // Send fails only if all workers are gone; the caller observes
            // the shortfall when the result channel closes early.
            let _ = self.tx.send(job);
        }
        // Drop the seed sender so the channel closes when the last
        // worker-held clone is done.
        drop(tx);
        rx
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        // Close the channel; workers exit their recv loop and join.
        let (tx, _) = channel();
        drop(std::mem::replace(&mut self.tx, tx));
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
