//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the coordinator hot path.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, layer tables).
//! * [`tensor`] — host-side batch containers matching artifact input shapes.
//! * [`engine`] — one PJRT CPU client + compiled executables; the four
//!   entry points (`init` / `train_epoch` / `eval_chunk` / `mask`).
//! * [`pool`] — a multi-worker engine pool (PJRT wrappers are not `Send`,
//!   so each worker thread owns a full engine; jobs fan out over a channel).
//! * [`bufpool`] — the shared upload-frame buffer pool backing the
//!   zero-allocation encode path (see `docs/SCALE.md` §"Hot path & memory").
//!
//! Python never runs here: the rust binary is self-contained once
//! `make artifacts` has produced the HLO text.

pub mod bufpool;
pub mod engine;
pub mod manifest;
pub mod pool;
pub mod tensor;

pub use bufpool::BufferPool;
pub use engine::Engine;
pub use manifest::{LayerInfo, Manifest, ModelManifest};
pub use pool::EnginePool;
pub use tensor::{Batches, ElemType};
