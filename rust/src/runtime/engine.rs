//! PJRT engine: owns one CPU client plus the compiled executables for a set
//! of models, and exposes the four artifact entry points.
//!
//! HLO *text* is the interchange format (`HloModuleProto::from_text_file`):
//! jax >= 0.5 serialized protos carry 64-bit instruction ids that this
//! xla_extension rejects, while the text parser reassigns ids cleanly.
//!
//! `Engine` is deliberately **not** `Send`/`Sync` (the underlying PJRT
//! wrappers hold raw pointers); cross-thread use goes through
//! [`crate::runtime::pool::EnginePool`], which gives each worker thread its
//! own engine.
//!
//! Building without the default `xla` cargo feature swaps in a stub engine
//! with the same API whose `load` always errors: everything that does not
//! touch PJRT (the wire, sessions, chaos, lints) builds and tests on a
//! machine with no xla_extension toolchain.

#[cfg(feature = "xla")]
use std::collections::BTreeMap;

use crate::runtime::manifest::{Manifest, ModelManifest};
#[cfg(feature = "xla")]
use crate::runtime::tensor::XData;
use crate::runtime::tensor::Batches;
use crate::util::error::{Error, Result};

/// Eval-chunk output: summed loss / metric / sample count.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EvalSums {
    pub loss_sum: f64,
    pub metric_sum: f64,
    pub count: f64,
}

impl EvalSums {
    pub fn add(&mut self, other: EvalSums) {
        self.loss_sum += other.loss_sum;
        self.metric_sum += other.metric_sum;
        self.count += other.count;
    }

    /// Mean loss per sample (cross-entropy; exp of this is LM perplexity).
    pub fn mean_loss(&self) -> f64 {
        if self.count > 0.0 {
            self.loss_sum / self.count
        } else {
            f64::NAN
        }
    }

    /// Accuracy (image) / next-token accuracy (LM).
    pub fn accuracy(&self) -> f64 {
        if self.count > 0.0 {
            self.metric_sum / self.count
        } else {
            f64::NAN
        }
    }

    /// Perplexity = exp(mean token NLL); only meaningful for LM models.
    pub fn perplexity(&self) -> f64 {
        self.mean_loss().exp()
    }
}

#[cfg(feature = "xla")]
struct ModelExes {
    init: xla::PjRtLoadedExecutable,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    mask: xla::PjRtLoadedExecutable,
}

/// One PJRT client + compiled executables for a set of models.
#[cfg(feature = "xla")]
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: BTreeMap<String, ModelExes>,
}

#[cfg(feature = "xla")]
impl Engine {
    /// Build a CPU engine and compile the artifacts for `models` (all
    /// manifest models if empty).
    pub fn load(manifest: &Manifest, models: &[&str]) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        let names: Vec<String> = if models.is_empty() {
            manifest.models.keys().cloned().collect()
        } else {
            models.iter().map(|s| s.to_string()).collect()
        };
        let mut exes = BTreeMap::new();
        for name in &names {
            manifest.model(name)?; // validates existence
            let compile = |kind: &str| -> Result<xla::PjRtLoadedExecutable> {
                let path = manifest.artifact_path(name, kind)?;
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str()
                        .ok_or_else(|| Error::invalid("non-utf8 artifact path"))?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                Ok(client.compile(&comp)?)
            };
            exes.insert(
                name.clone(),
                ModelExes {
                    init: compile("init")?,
                    train: compile("train")?,
                    eval: compile("eval")?,
                    mask: compile("mask")?,
                },
            );
            log::debug!("engine: compiled artifacts for {name}");
        }
        Ok(Engine {
            client,
            manifest: manifest.clone(),
            exes,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.manifest.model(name)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn exes(&self, model: &str) -> Result<&ModelExes> {
        self.exes
            .get(model)
            .ok_or_else(|| Error::invalid(format!("model '{model}' not loaded in engine")))
    }

    // ------------------------------------------------------------------
    // Literal plumbing
    // ------------------------------------------------------------------

    fn params_literal(&self, model: &str, params: &[f32]) -> Result<xla::Literal> {
        let p = self.model(model)?.p;
        if params.len() != p {
            return Err(Error::invalid(format!(
                "{model}: params len {} != P {p}",
                params.len()
            )));
        }
        Ok(xla::Literal::vec1(params))
    }

    fn x_literal(&self, b: &Batches) -> Result<xla::Literal> {
        let lit = match &b.xs {
            XData::F32(v) => xla::Literal::vec1(v.as_slice()),
            XData::I32(v) => xla::Literal::vec1(v.as_slice()),
        };
        Ok(lit.reshape(&b.x_dims())?)
    }

    fn y_literal(&self, b: &Batches) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(b.ys.as_slice()).reshape(&b.y_dims())?)
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = exe.execute::<xla::Literal>(args)?;
        let out = bufs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Engine("executable returned no outputs".into()))?
            .to_literal_sync()?;
        // Artifacts are lowered with return_tuple=True.
        Ok(out.to_tuple()?)
    }

    fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
        Ok(lit.to_vec::<f32>()?[0])
    }

    // ------------------------------------------------------------------
    // Artifact entry points
    // ------------------------------------------------------------------

    /// `init(seed) -> params` — fresh global model parameters.
    pub fn init(&self, model: &str, seed: i32) -> Result<Vec<f32>> {
        let outs = self.run(&self.exes(model)?.init, &[xla::Literal::scalar(seed)])?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    /// `train_epoch(params, xs, ys, lr) -> (params', mean_loss)` — one local
    /// epoch (NB scanned mini-batch SGD steps) on a client shard.
    pub fn train_epoch(
        &self,
        model: &str,
        params: &[f32],
        chunk: &Batches,
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        chunk.check_train(self.model(model)?)?;
        let args = [
            self.params_literal(model, params)?,
            self.x_literal(chunk)?,
            self.y_literal(chunk)?,
            xla::Literal::scalar(lr),
        ];
        let outs = self.run(&self.exes(model)?.train, &args)?;
        if outs.len() != 2 {
            return Err(Error::Engine(format!(
                "train artifact returned {} outputs, want 2",
                outs.len()
            )));
        }
        let new_params = outs[0].to_vec::<f32>()?;
        let loss = Self::scalar_f32(&outs[1])?;
        Ok((new_params, loss))
    }

    /// `eval_chunk(params, xs, ys) -> (loss_sum, metric_sum, count)`.
    pub fn eval_chunk(&self, model: &str, params: &[f32], chunk: &Batches) -> Result<EvalSums> {
        chunk.check_eval(self.model(model)?)?;
        let args = [
            self.params_literal(model, params)?,
            self.x_literal(chunk)?,
            self.y_literal(chunk)?,
        ];
        let outs = self.run(&self.exes(model)?.eval, &args)?;
        if outs.len() != 3 {
            return Err(Error::Engine(format!(
                "eval artifact returned {} outputs, want 3",
                outs.len()
            )));
        }
        Ok(EvalSums {
            loss_sum: Self::scalar_f32(&outs[0])? as f64,
            metric_sum: Self::scalar_f32(&outs[1])? as f64,
            count: Self::scalar_f32(&outs[2])? as f64,
        })
    }

    /// `mask(w_new, w_old, gamma) -> masked` — the L1 Pallas selective-mask
    /// kernel (per-layer top-k by |delta|, threshold bisection).
    pub fn mask(&self, model: &str, w_new: &[f32], w_old: &[f32], gamma: f32) -> Result<Vec<f32>> {
        if !(0.0..=1.0).contains(&gamma) {
            return Err(Error::invalid(format!("gamma {gamma} out of [0,1]")));
        }
        let args = [
            self.params_literal(model, w_new)?,
            self.params_literal(model, w_old)?,
            xla::Literal::scalar(gamma),
        ];
        let outs = self.run(&self.exes(model)?.mask, &args)?;
        Ok(outs[0].to_vec::<f32>()?)
    }
}

/// Stub engine for builds without the `xla` feature: identical surface,
/// but `load` always fails, so no other method is ever reachable. This is
/// what lets CI runners without an xla_extension/PJRT toolchain build,
/// clippy, and test the non-engine parts of the crate.
#[cfg(not(feature = "xla"))]
pub struct Engine {
    manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
impl Engine {
    fn unavailable<T>() -> Result<T> {
        Err(Error::Engine(
            "fedmask was built without the `xla` feature: PJRT engine unavailable".into(),
        ))
    }

    /// Always fails: there is no PJRT client in a stub build.
    pub fn load(_manifest: &Manifest, _models: &[&str]) -> Result<Engine> {
        Self::unavailable()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.manifest.model(name)
    }

    pub fn platform(&self) -> String {
        "stub (built without the xla feature)".to_string()
    }

    /// Unreachable in practice (`load` never constructs a stub engine).
    pub fn init(&self, _model: &str, _seed: i32) -> Result<Vec<f32>> {
        Self::unavailable()
    }

    /// Unreachable in practice (`load` never constructs a stub engine).
    pub fn train_epoch(
        &self,
        _model: &str,
        _params: &[f32],
        _chunk: &Batches,
        _lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        Self::unavailable()
    }

    /// Unreachable in practice (`load` never constructs a stub engine).
    pub fn eval_chunk(&self, _model: &str, _params: &[f32], _chunk: &Batches) -> Result<EvalSums> {
        Self::unavailable()
    }

    /// Unreachable in practice (`load` never constructs a stub engine).
    pub fn mask(
        &self,
        _model: &str,
        _w_new: &[f32],
        _w_old: &[f32],
        _gamma: f32,
    ) -> Result<Vec<f32>> {
        Self::unavailable()
    }
}
