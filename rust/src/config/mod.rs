//! Experiment configuration system.

pub mod experiment;

pub use experiment::{ExperimentConfig, NetworkKind};
