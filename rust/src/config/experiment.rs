//! Experiment configuration: one struct describing a full federated run.
//!
//! Configs serialize to/from JSON (`fedmask run --config exp.json`), carry
//! paper-aligned defaults per model, and validate eagerly so figure sweeps
//! fail before any engine compiles. Every stochastic element of a run
//! derives from `seed`.

use std::path::Path;

use crate::data::loader::DatasetSpec;
use crate::data::partition::Scheme;
use crate::fl::chaos::FaultPlan;
use crate::fl::masking::{MaskPolicy, MaskTarget};
use crate::sim::availability::AvailabilityModel;
use crate::fl::sampling::SamplingSchedule;
use crate::transport::codec::Encoding;
use crate::transport::link::TransportKind;
use crate::util::error::{Error, Result};
use crate::util::json::{self, Json};

/// Which network model the virtual clock uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetworkKind {
    /// Instantaneous transfers (the paper's setting).
    Ideal,
    /// The default mobile-fleet bandwidth/latency profile.
    Simulated,
}

/// Server-side aggregation rule (constructed per round via
/// [`crate::fl::aggregate::make_aggregator`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AggregatorKind {
    /// Sample-weighted FedAvg (paper Eq. 2; default) — streamed with O(p)
    /// server memory.
    FedAvg,
    /// Attentive aggregation (Ji et al. [11]) with softmax temperature —
    /// buffers the cohort (O(k*p)), inherent to the rule.
    Attentive { temp: f64 },
}

/// Full description of one federated experiment.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Label used in CSV output and logs.
    pub label: String,
    /// Model key: `lenet` | `vggmini` | `gru`.
    pub model: String,
    /// Registered client count M.
    pub clients: usize,
    /// Communication rounds R.
    pub rounds: usize,
    /// Local epochs E per selected client per round.
    pub local_epochs: usize,
    /// Local SGD learning rate eta.
    pub lr: f32,
    /// Client sampling schedule (static / dynamic).
    pub sampling: SamplingSchedule,
    /// Floor on selected clients (paper: 2 for dynamic schedules).
    pub min_clients: usize,
    /// Upload masking policy.
    pub masking: MaskPolicy,
    /// Mask the weights (paper-literal) or the delta (ablation).
    pub mask_target: MaskTarget,
    /// Data partitioning scheme.
    pub partition: Scheme,
    /// Synthetic dataset sizing (ignored if real data present).
    pub n_train: usize,
    pub n_test: usize,
    /// Master seed.
    pub seed: u64,
    /// Evaluate every k rounds (1 = every round).
    pub eval_every: usize,
    /// Cap on eval chunks per evaluation (0 = full test set).
    pub eval_max_chunks: usize,
    /// Client availability (1.0 = paper's always-on setting).
    pub ack_prob: f64,
    pub straggler_prob: f64,
    /// Mean local compute time per epoch (virtual seconds).
    pub compute_mean_s: f64,
    /// Multiplicative compute-time jitter (±fraction of the mean); under
    /// the simulated network this heterogeneity also orders deliveries.
    pub compute_jitter: f64,
    /// Seed for the availability/compute model; `None` derives it from
    /// the master seed (`seed ^ 0xacc`, the historical wiring).
    pub availability_seed: Option<u64>,
    /// Network model for virtual-time accounting.
    pub network: NetworkKind,
    /// Wire encoding for uploads.
    pub encoding: Encoding,
    /// Which wire uploads travel: in-process channels (default), framed
    /// TCP on localhost, or a unix-domain socket. The aggregate is bitwise
    /// identical on every transport; sockets add real I/O and framing.
    pub transport: TransportKind,
    /// Delta-encode the downlink broadcast against the previous round's
    /// global model through the same codec (sparse when masked cohorts
    /// leave most coordinates untouched). Off by default: the reconstructed
    /// broadcast `w_old + (w_new - w_old)` differs from `w_new` by f32
    /// rounding, so this trades bitwise parity with the dense broadcast for
    /// downlink savings.
    pub downlink_delta: bool,
    /// Server aggregation rule.
    pub aggregator: AggregatorKind,
    /// Engine pool width.
    pub workers: usize,
    /// Server drain-loop poll granularity in milliseconds: how long one
    /// bounded wire wait lasts before the round loop re-checks its worker
    /// results. Smaller = lower fold latency, more wakeups.
    pub drain_poll_ms: u64,
    /// Aggregation shards: 1 (default) folds serially on the round loop;
    /// > 1 routes undecoded payloads to that many shard-local worker
    /// folds, merged bitwise-exactly at the root (see `fl::tree`).
    pub agg_shards: usize,
    /// Socket-server admission cap: the most simultaneous connections the
    /// reactor keeps open; over-cap connects are refused by immediate
    /// close, before any handshake. Sessions persist across rounds, so
    /// size this to the whole fleet, not one cohort. Ignored by the
    /// in-process transport.
    pub max_conns: usize,
    /// Seeded fault-injection plan (`None` or an inactive plan = clean
    /// wire). See [`crate::fl::chaos`] and `docs/CHAOS.md`.
    pub chaos: Option<FaultPlan>,
}

impl ExperimentConfig {
    /// Paper-aligned defaults for a model (lr / epochs per §5).
    pub fn defaults(model: &str) -> Result<ExperimentConfig> {
        let (lr, n_train, n_test) = match model {
            "lenet" => (0.05f32, 4_000, 1_024),
            "vggmini" => (0.05f32, 1_200, 512),
            "gru" => (0.5f32, 120_000, 12_000),
            other => return Err(Error::invalid(format!("unknown model '{other}'"))),
        };
        Ok(ExperimentConfig {
            label: format!("{model}-default"),
            model: model.to_string(),
            clients: 20,
            rounds: 10,
            local_epochs: 1,
            lr,
            sampling: SamplingSchedule::Static { c0: 1.0 },
            min_clients: 1,
            masking: MaskPolicy::None,
            // Delta semantics by default: dropped positions keep W_t
            // server-side. Alg. 2/4 read literally zero the weights, but
            // that contradicts the paper's own Fig. 4/6 results (selective
            // masking stays usable at gamma = 0.1, impossible when 90% of
            // weights are zeroed); see DESIGN.md §4. `mask_target =
            // "weights"` selects the literal reading as an ablation.
            mask_target: MaskTarget::Delta,
            partition: Scheme::Iid,
            n_train,
            n_test,
            seed: 42,
            eval_every: 1,
            eval_max_chunks: 4,
            ack_prob: 1.0,
            straggler_prob: 0.0,
            compute_mean_s: 1.0,
            compute_jitter: 0.0,
            availability_seed: None,
            network: NetworkKind::Ideal,
            encoding: Encoding::Auto,
            transport: TransportKind::InProcess,
            downlink_delta: false,
            aggregator: AggregatorKind::FedAvg,
            workers: default_workers(),
            drain_poll_ms: 25,
            agg_shards: 1,
            max_conns: 4096,
            chaos: None,
        })
    }

    /// The availability/compute model this config describes, on its own
    /// seed lane so availability draws never collide with sampling or
    /// data shuffles.
    pub fn availability(&self) -> AvailabilityModel {
        AvailabilityModel::with_compute(
            self.ack_prob,
            self.straggler_prob,
            self.compute_mean_s,
            self.compute_jitter,
            self.availability_seed.unwrap_or(self.seed ^ 0xacc),
        )
    }

    /// Dataset spec implied by this config.
    pub fn dataset_spec(&self) -> Result<DatasetSpec> {
        let mut spec = DatasetSpec::for_model(&self.model, self.seed)?;
        spec.n_train = self.n_train;
        spec.n_test = self.n_test;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        if self.clients < 2 {
            return Err(Error::invalid("need at least 2 clients"));
        }
        if self.rounds == 0 || self.local_epochs == 0 {
            return Err(Error::invalid("rounds and local_epochs must be >= 1"));
        }
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            return Err(Error::invalid(format!("lr {} must be positive", self.lr)));
        }
        if self.min_clients == 0 || self.min_clients > self.clients {
            return Err(Error::invalid(format!(
                "min_clients {} out of range [1, {}]",
                self.min_clients, self.clients
            )));
        }
        if self.eval_every == 0 {
            return Err(Error::invalid("eval_every must be >= 1"));
        }
        if !(0.0..=1.0).contains(&self.ack_prob) || !(0.0..=1.0).contains(&self.straggler_prob) {
            return Err(Error::invalid("probabilities must be in [0, 1]"));
        }
        if !(self.compute_mean_s.is_finite() && self.compute_mean_s >= 0.0) {
            return Err(Error::invalid(format!(
                "compute_mean_s {} must be finite and >= 0",
                self.compute_mean_s
            )));
        }
        if !(0.0..=1.0).contains(&self.compute_jitter) {
            return Err(Error::invalid(format!(
                "compute_jitter {} must be in [0, 1]",
                self.compute_jitter
            )));
        }
        if let Some(plan) = &self.chaos {
            plan.validate()?;
        }
        if self.workers == 0 {
            return Err(Error::invalid("workers must be >= 1"));
        }
        if self.drain_poll_ms == 0 {
            return Err(Error::invalid("drain_poll_ms must be >= 1"));
        }
        if self.agg_shards == 0 {
            return Err(Error::invalid("agg_shards must be >= 1"));
        }
        if self.max_conns == 0 {
            return Err(Error::invalid("max_conns must be >= 1"));
        }
        self.sampling.validate()?;
        self.masking.validate()?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // JSON round trip
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let (samp_kind, samp_param, samp_every) = match &self.sampling {
            SamplingSchedule::Static { .. } => ("static", 0.0, 10),
            SamplingSchedule::DynamicExp { beta, .. } => ("dynamic-exp", *beta, 10),
            SamplingSchedule::DynamicLinear { slope, .. } => ("dynamic-linear", *slope, 10),
            SamplingSchedule::DynamicStep { factor, every, .. } => {
                ("dynamic-step", *factor, *every)
            }
        };
        let (mask_kind, gamma) = match &self.masking {
            MaskPolicy::None => ("none", 1.0f32),
            MaskPolicy::Random { gamma } => ("random", *gamma),
            MaskPolicy::Selective { gamma, engine, scope } => (
                match (engine, scope) {
                    (crate::fl::masking::MaskEngine::Hlo, crate::fl::masking::MaskScope::PerLayer) => "selective",
                    (crate::fl::masking::MaskEngine::Rust, crate::fl::masking::MaskScope::PerLayer) => "selective-rust",
                    (_, crate::fl::masking::MaskScope::Global) => "selective-global",
                },
                *gamma,
            ),
        };
        let mut pairs = vec![
            ("label", Json::str(&self.label)),
            ("model", Json::str(&self.model)),
            ("clients", Json::num(self.clients as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("local_epochs", Json::num(self.local_epochs as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("sampling", Json::str(samp_kind)),
            ("sampling_c0", Json::num(self.sampling.c0())),
            ("sampling_param", Json::num(samp_param)),
            ("sampling_every", Json::num(samp_every as f64)),
            ("min_clients", Json::num(self.min_clients as f64)),
            ("masking", Json::str(mask_kind)),
            ("gamma", Json::num(gamma as f64)),
            (
                "mask_target",
                Json::str(match self.mask_target {
                    MaskTarget::Weights => "weights",
                    MaskTarget::Delta => "delta",
                }),
            ),
            (
                "partition",
                Json::str(match self.partition {
                    Scheme::Iid => "iid".to_string(),
                    Scheme::NonIidShards { shards_per_client } => {
                        format!("noniid-{shards_per_client}")
                    }
                }),
            ),
            ("n_train", Json::num(self.n_train as f64)),
            ("n_test", Json::num(self.n_test as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("eval_max_chunks", Json::num(self.eval_max_chunks as f64)),
            ("ack_prob", Json::num(self.ack_prob)),
            ("straggler_prob", Json::num(self.straggler_prob)),
            ("compute_mean_s", Json::num(self.compute_mean_s)),
            ("compute_jitter", Json::num(self.compute_jitter)),
            (
                "network",
                Json::str(match self.network {
                    NetworkKind::Ideal => "ideal",
                    NetworkKind::Simulated => "simulated",
                }),
            ),
            ("encoding", Json::str(self.encoding.as_str())),
            ("transport", Json::str(self.transport.as_str())),
            ("downlink_delta", Json::Bool(self.downlink_delta)),
            (
                "aggregator",
                Json::str(match self.aggregator {
                    AggregatorKind::FedAvg => "fedavg".to_string(),
                    AggregatorKind::Attentive { temp } => format!("attentive-{temp}"),
                }),
            ),
            ("workers", Json::num(self.workers as f64)),
            ("drain_poll_ms", Json::num(self.drain_poll_ms as f64)),
            ("agg_shards", Json::num(self.agg_shards as f64)),
            ("max_conns", Json::num(self.max_conns as f64)),
        ];
        if let Some(seed) = self.availability_seed {
            pairs.push(("availability_seed", Json::num(seed as f64)));
        }
        if let Some(plan) = &self.chaos {
            pairs.push(("chaos", plan.to_json()));
        }
        Json::obj(pairs)
    }

    pub fn from_json(root: &Json) -> Result<ExperimentConfig> {
        let model = root.get("model")?.as_str()?.to_string();
        let mut cfg = ExperimentConfig::defaults(&model)?;
        let get_usize = |k: &str, d: usize| -> Result<usize> {
            match root.opt(k) {
                Some(v) => v.as_usize(),
                None => Ok(d),
            }
        };
        let get_f64 = |k: &str, d: f64| -> Result<f64> {
            match root.opt(k) {
                Some(v) => v.as_f64(),
                None => Ok(d),
            }
        };
        if let Some(v) = root.opt("label") {
            cfg.label = v.as_str()?.to_string();
        }
        cfg.clients = get_usize("clients", cfg.clients)?;
        cfg.rounds = get_usize("rounds", cfg.rounds)?;
        cfg.local_epochs = get_usize("local_epochs", cfg.local_epochs)?;
        cfg.lr = get_f64("lr", cfg.lr as f64)? as f32;
        let samp_kind = root
            .opt("sampling")
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "static".into());
        let c0 = get_f64("sampling_c0", 1.0)?;
        let sp = get_f64("sampling_param", 0.0)?;
        let se = get_usize("sampling_every", 10)?;
        cfg.sampling = SamplingSchedule::from_config(&samp_kind, c0, sp, se)?;
        cfg.min_clients = get_usize("min_clients", cfg.sampling.default_min_clients())?;
        let mask_kind = root
            .opt("masking")
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "none".into());
        let gamma = get_f64("gamma", 1.0)? as f32;
        cfg.masking = MaskPolicy::from_config(&mask_kind, gamma)?;
        cfg.mask_target = match root.opt("mask_target").map(|v| v.as_str()).transpose()? {
            None | Some("delta") => MaskTarget::Delta,
            Some("weights") => MaskTarget::Weights,
            Some(other) => return Err(Error::invalid(format!("bad mask_target '{other}'"))),
        };
        cfg.partition = match root.opt("partition").map(|v| v.as_str()).transpose()? {
            None | Some("iid") => Scheme::Iid,
            Some(s) if s.starts_with("noniid-") => Scheme::NonIidShards {
                shards_per_client: s[7..]
                    .parse()
                    .map_err(|_| Error::invalid(format!("bad partition '{s}'")))?,
            },
            Some(other) => return Err(Error::invalid(format!("bad partition '{other}'"))),
        };
        cfg.n_train = get_usize("n_train", cfg.n_train)?;
        cfg.n_test = get_usize("n_test", cfg.n_test)?;
        cfg.seed = get_f64("seed", cfg.seed as f64)? as u64;
        cfg.eval_every = get_usize("eval_every", cfg.eval_every)?;
        cfg.eval_max_chunks = get_usize("eval_max_chunks", cfg.eval_max_chunks)?;
        cfg.ack_prob = get_f64("ack_prob", cfg.ack_prob)?;
        cfg.straggler_prob = get_f64("straggler_prob", cfg.straggler_prob)?;
        cfg.compute_mean_s = get_f64("compute_mean_s", cfg.compute_mean_s)?;
        cfg.compute_jitter = get_f64("compute_jitter", cfg.compute_jitter)?;
        if let Some(v) = root.opt("availability_seed") {
            cfg.availability_seed = Some(v.as_f64()? as u64);
        }
        if let Some(v) = root.opt("chaos") {
            cfg.chaos = Some(FaultPlan::from_json(v)?);
        }
        cfg.network = match root.opt("network").map(|v| v.as_str()).transpose()? {
            None | Some("ideal") => NetworkKind::Ideal,
            Some("simulated") => NetworkKind::Simulated,
            Some(other) => return Err(Error::invalid(format!("bad network '{other}'"))),
        };
        cfg.encoding = match root.opt("encoding").map(|v| v.as_str()).transpose()? {
            None => Encoding::Auto,
            Some(s) => Encoding::parse(s)?,
        };
        cfg.transport = match root.opt("transport").map(|v| v.as_str()).transpose()? {
            None => TransportKind::InProcess,
            Some(s) => TransportKind::parse(s)?,
        };
        cfg.downlink_delta = match root.opt("downlink_delta") {
            Some(v) => v.as_bool()?,
            None => false,
        };
        cfg.aggregator = match root.opt("aggregator").map(|v| v.as_str()).transpose()? {
            None | Some("fedavg") => AggregatorKind::FedAvg,
            Some(s) if s == "attentive" => AggregatorKind::Attentive { temp: 1.0 },
            Some(s) if s.starts_with("attentive-") => AggregatorKind::Attentive {
                temp: s[10..]
                    .parse()
                    .map_err(|_| Error::invalid(format!("bad aggregator '{s}'")))?,
            },
            Some(other) => return Err(Error::invalid(format!("bad aggregator '{other}'"))),
        };
        cfg.workers = get_usize("workers", cfg.workers)?;
        cfg.drain_poll_ms = get_usize("drain_poll_ms", cfg.drain_poll_ms as usize)? as u64;
        cfg.agg_shards = get_usize("agg_shards", cfg.agg_shards)?;
        cfg.max_conns = get_usize("max_conns", cfg.max_conns)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&json::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_pretty())?;
        Ok(())
    }
}

/// Default pool width: physical-ish core count, capped — engine compilation
/// is paid per worker, so more isn't always better for short sweeps.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        for m in ["lenet", "vggmini", "gru"] {
            ExperimentConfig::defaults(m).unwrap().validate().unwrap();
        }
        assert!(ExperimentConfig::defaults("resnet").is_err());
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.label = "fig3-dynamic".into();
        cfg.sampling = SamplingSchedule::DynamicExp { c0: 0.7, beta: 0.1 };
        cfg.min_clients = 2;
        cfg.masking = MaskPolicy::selective(0.3);
        cfg.mask_target = MaskTarget::Delta;
        cfg.partition = Scheme::NonIidShards { shards_per_client: 2 };
        cfg.rounds = 50;
        cfg.network = NetworkKind::Simulated;
        cfg.transport = TransportKind::Uds;
        cfg.downlink_delta = true;
        cfg.encoding = Encoding::SparseDelta;
        cfg.aggregator = AggregatorKind::Attentive { temp: 0.5 };
        cfg.drain_poll_ms = 7;
        cfg.agg_shards = 4;
        cfg.max_conns = 128;
        cfg.compute_mean_s = 2.5;
        cfg.compute_jitter = 0.4;
        cfg.availability_seed = Some(1234);
        cfg.chaos = Some(FaultPlan {
            seed: 9,
            drop_prob: 0.2,
            dup_prob: 0.1,
            byzantine_clients: vec![3],
            reorder: true,
            ..FaultPlan::default()
        });
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.label, cfg.label);
        assert_eq!(back.sampling, cfg.sampling);
        assert_eq!(back.masking, cfg.masking);
        assert_eq!(back.mask_target, cfg.mask_target);
        assert_eq!(back.partition, cfg.partition);
        assert_eq!(back.rounds, 50);
        assert_eq!(back.network, NetworkKind::Simulated);
        assert_eq!(back.transport, TransportKind::Uds);
        assert!(back.downlink_delta);
        assert_eq!(back.encoding, Encoding::SparseDelta);
        assert_eq!(back.aggregator, AggregatorKind::Attentive { temp: 0.5 });
        assert_eq!(back.drain_poll_ms, 7);
        assert_eq!(back.agg_shards, 4);
        assert_eq!(back.max_conns, 128);
        assert_eq!(back.compute_mean_s, 2.5);
        assert_eq!(back.compute_jitter, 0.4);
        assert_eq!(back.availability_seed, Some(1234));
        assert_eq!(back.chaos, cfg.chaos);
    }

    #[test]
    fn availability_model_reflects_config_and_seed_override() {
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.ack_prob = 0.8;
        cfg.compute_jitter = 0.5;
        let derived = cfg.availability();
        assert_eq!(derived.ack_prob, 0.8);
        assert_eq!(derived.compute_jitter, 0.5);
        // the default lane is seed ^ 0xacc: same config, same draws
        assert_eq!(derived.state(3, 7), cfg.availability().state(3, 7));
        // an explicit availability seed changes the lane without touching
        // the master seed
        cfg.availability_seed = Some(cfg.seed ^ 0xacc);
        let pinned = cfg.availability();
        for r in 0..5 {
            for c in 0..10 {
                assert_eq!(pinned.state(r, c), derived.state(r, c));
            }
        }
    }

    #[test]
    fn chaos_and_compute_fields_are_validated() {
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.compute_jitter = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.compute_mean_s = f64::NAN;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.chaos = Some(FaultPlan { drop_prob: 0.9, dup_prob: 0.9, ..FaultPlan::default() });
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn every_encoding_spelling_round_trips_through_json() {
        for &enc in Encoding::ALL {
            let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
            cfg.encoding = enc;
            let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back.encoding, enc);
        }
        let root = json::parse(r#"{"model": "lenet", "encoding": "auto-q4"}"#).unwrap();
        assert_eq!(
            ExperimentConfig::from_json(&root).unwrap().encoding,
            Encoding::AutoQ4
        );
        let root = json::parse(r#"{"model": "lenet", "encoding": "gzip"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&root).is_err());
    }

    #[test]
    fn transport_defaults_to_in_process_and_rejects_junk() {
        let root = json::parse(r#"{"model": "lenet"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&root).unwrap();
        assert_eq!(cfg.transport, TransportKind::InProcess);
        let root = json::parse(r#"{"model": "lenet", "transport": "tcp"}"#).unwrap();
        assert_eq!(
            ExperimentConfig::from_json(&root).unwrap().transport,
            TransportKind::Tcp
        );
        let root = json::parse(r#"{"model": "lenet", "transport": "avian"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&root).is_err());
    }

    #[test]
    fn validation_catches_bad_fields() {
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.clients = 1;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.lr = -0.1;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.min_clients = 100;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.drain_poll_ms = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.agg_shards = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.max_conns = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn from_json_applies_defaults_for_missing_keys() {
        let root = json::parse(r#"{"model": "gru"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&root).unwrap();
        assert_eq!(cfg.model, "gru");
        assert_eq!(cfg.lr, 0.5);
        assert_eq!(cfg.masking, MaskPolicy::None);
        assert_eq!(cfg.drain_poll_ms, 25);
        assert_eq!(cfg.agg_shards, 1);
        assert_eq!(cfg.max_conns, 4096);
    }

    #[test]
    fn step_schedule_period_round_trips_and_is_validated() {
        // the configurable period survives the JSON round trip (it used
        // to be silently replaced by 10)
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.sampling = SamplingSchedule::DynamicStep { c0: 1.0, every: 7, factor: 0.5 };
        cfg.min_clients = 2;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.sampling, cfg.sampling);
        // explicit key wins over the default
        let root = json::parse(
            r#"{"model": "lenet", "sampling": "dynamic-step", "sampling_c0": 1.0,
                "sampling_param": 0.5, "sampling_every": 4, "min_clients": 2}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&root).unwrap();
        assert_eq!(
            cfg.sampling,
            SamplingSchedule::DynamicStep { c0: 1.0, every: 4, factor: 0.5 }
        );
        // missing key keeps the historical default of 10
        let root = json::parse(
            r#"{"model": "lenet", "sampling": "dynamic-step", "sampling_c0": 1.0,
                "sampling_param": 0.5, "min_clients": 2}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&root).unwrap();
        assert_eq!(
            cfg.sampling,
            SamplingSchedule::DynamicStep { c0: 1.0, every: 10, factor: 0.5 }
        );
        // a zero period is rejected at parse time
        let root = json::parse(
            r#"{"model": "lenet", "sampling": "dynamic-step", "sampling_c0": 1.0,
                "sampling_param": 0.5, "sampling_every": 0, "min_clients": 2}"#,
        )
        .unwrap();
        assert!(ExperimentConfig::from_json(&root).is_err());
    }

    #[test]
    fn min_clients_defaults_to_two_for_dynamic() {
        let root = json::parse(
            r#"{"model": "lenet", "sampling": "dynamic-exp", "sampling_c0": 1.0, "sampling_param": 0.1}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&root).unwrap();
        assert_eq!(cfg.min_clients, 2, "paper §4.1 floor");
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("fedmask_cfg_{}", std::process::id()));
        let path = dir.join("exp.json");
        let cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.save(&path).unwrap();
        let back = ExperimentConfig::load(&path).unwrap();
        assert_eq!(back.model, "lenet");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dataset_spec_respects_overrides() {
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.n_train = 123;
        let spec = cfg.dataset_spec().unwrap();
        assert_eq!(spec.n_train, 123);
        assert_eq!(spec.name, "mnist");
    }
}
