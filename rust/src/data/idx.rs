//! MNIST IDX format parser (the real `train-images-idx3-ubyte` files).
//!
//! Format: big-endian magic (0x00000803 images / 0x00000801 labels), dim
//! sizes, then raw u8 payload. Pixels are scaled to [0, 1] and standardized
//! with the canonical MNIST mean/std so real data plugs into the same
//! LeNet artifact as the synthetic generator.

use std::io::Read;
use std::path::Path;

use crate::data::ImageData;
use crate::util::error::{Error, Result};

const MAGIC_IMAGES: u32 = 0x0000_0803;
const MAGIC_LABELS: u32 = 0x0000_0801;

fn read_u32_be(data: &[u8], at: usize) -> Result<u32> {
    data.get(at..at + 4)
        .map(|b| u32::from_be_bytes([b[0], b[1], b[2], b[3]]))
        .ok_or_else(|| Error::parse("idx: truncated header"))
}

/// Parse an IDX3 image file into (pixels u8, rows, cols).
pub fn parse_images(data: &[u8]) -> Result<(Vec<u8>, usize, usize)> {
    if read_u32_be(data, 0)? != MAGIC_IMAGES {
        return Err(Error::parse("idx: bad image magic"));
    }
    let n = read_u32_be(data, 4)? as usize;
    let rows = read_u32_be(data, 8)? as usize;
    let cols = read_u32_be(data, 12)? as usize;
    let want = 16 + n * rows * cols;
    if data.len() != want {
        return Err(Error::parse(format!(
            "idx: image payload {} != expected {want}",
            data.len()
        )));
    }
    Ok((data[16..].to_vec(), rows, cols))
}

/// Parse an IDX1 label file.
pub fn parse_labels(data: &[u8]) -> Result<Vec<u8>> {
    if read_u32_be(data, 0)? != MAGIC_LABELS {
        return Err(Error::parse("idx: bad label magic"));
    }
    let n = read_u32_be(data, 4)? as usize;
    if data.len() != 8 + n {
        return Err(Error::parse("idx: label payload size mismatch"));
    }
    Ok(data[8..].to_vec())
}

/// Load an (images, labels) IDX pair into [`ImageData`], standardized.
pub fn load_pair(images_path: &Path, labels_path: &Path) -> Result<ImageData> {
    let mut img_bytes = Vec::new();
    std::fs::File::open(images_path)?.read_to_end(&mut img_bytes)?;
    let mut lbl_bytes = Vec::new();
    std::fs::File::open(labels_path)?.read_to_end(&mut lbl_bytes)?;

    let (pixels, rows, cols) = parse_images(&img_bytes)?;
    let labels = parse_labels(&lbl_bytes)?;
    if pixels.len() != labels.len() * rows * cols {
        return Err(Error::parse("idx: image/label count mismatch"));
    }
    // canonical MNIST standardization
    const MEAN: f32 = 0.1307;
    const STD: f32 = 0.3081;
    let x: Vec<f32> = pixels
        .iter()
        .map(|&p| (p as f32 / 255.0 - MEAN) / STD)
        .collect();
    let y: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
    let data = ImageData {
        x,
        y,
        elem_shape: vec![rows, cols, 1],
        classes: 10,
    };
    data.validate()?;
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny in-memory IDX pair.
    fn fake_idx(n: usize, rows: usize, cols: usize) -> (Vec<u8>, Vec<u8>) {
        let mut img = Vec::new();
        img.extend_from_slice(&MAGIC_IMAGES.to_be_bytes());
        img.extend_from_slice(&(n as u32).to_be_bytes());
        img.extend_from_slice(&(rows as u32).to_be_bytes());
        img.extend_from_slice(&(cols as u32).to_be_bytes());
        for i in 0..n * rows * cols {
            img.push((i % 251) as u8);
        }
        let mut lbl = Vec::new();
        lbl.extend_from_slice(&MAGIC_LABELS.to_be_bytes());
        lbl.extend_from_slice(&(n as u32).to_be_bytes());
        for i in 0..n {
            lbl.push((i % 10) as u8);
        }
        (img, lbl)
    }

    #[test]
    fn parses_valid_pair() {
        let (img, lbl) = fake_idx(5, 28, 28);
        let (pixels, r, c) = parse_images(&img).unwrap();
        assert_eq!((r, c), (28, 28));
        assert_eq!(pixels.len(), 5 * 28 * 28);
        let labels = parse_labels(&lbl).unwrap();
        assert_eq!(labels, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn rejects_bad_magic() {
        let (mut img, _) = fake_idx(2, 4, 4);
        img[3] = 0x99;
        assert!(parse_images(&img).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let (mut img, _) = fake_idx(2, 4, 4);
        img.truncate(img.len() - 3);
        assert!(parse_images(&img).is_err());
    }

    #[test]
    fn load_pair_roundtrip_via_tempfiles() {
        let dir = std::env::temp_dir().join(format!("fedmask_idx_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (img, lbl) = fake_idx(6, 28, 28);
        let ip = dir.join("images");
        let lp = dir.join("labels");
        std::fs::write(&ip, &img).unwrap();
        std::fs::write(&lp, &lbl).unwrap();
        let data = load_pair(&ip, &lp).unwrap();
        assert_eq!(data.len(), 6);
        assert_eq!(data.elem_shape, vec![28, 28, 1]);
        // standardized values are finite and zero pixel maps to -mean/std
        assert!((data.x[0] - (0.0 - 0.1307) / 0.3081).abs() < 1e-5);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
