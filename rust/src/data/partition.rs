//! Decentralized data partitioning (paper §5.1.2).
//!
//! The paper follows McMahan et al.'s partitioning: sample the dataset
//! I.I.D. into `M` client shards, and applies the same rule to WikiText-2.
//! We implement that default plus the pathological **non-IID shard split**
//! from the same source (sort by label, deal 2 shards per client) as an
//! extension exercised by the ablation benches.

use std::ops::Range;

use crate::sim::rng::Rng;
use crate::util::error::{Error, Result};

/// Partitioning scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Uniform random split (the paper's setting).
    Iid,
    /// Label-sorted shard split: each client sees ~`shards_per_client`
    /// label-contiguous shards (McMahan et al.'s pathological non-IID).
    NonIidShards { shards_per_client: usize },
}

/// Split `n` image samples into `m` client index shards.
pub fn partition_images(
    labels: &[i32],
    m: usize,
    scheme: Scheme,
    rng: &mut Rng,
) -> Result<Vec<Vec<usize>>> {
    let n = labels.len();
    if m == 0 || n < m {
        return Err(Error::invalid(format!("cannot split {n} samples into {m} clients")));
    }
    match scheme {
        Scheme::Iid => {
            let mut idx: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut idx);
            Ok(deal(idx, m))
        }
        Scheme::NonIidShards { shards_per_client } => {
            if shards_per_client == 0 {
                return Err(Error::invalid("shards_per_client must be >= 1"));
            }
            // sort indices by label (stable on index for determinism)
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by_key(|&i| (labels[i], i));
            // cut into m * spc shards, deal spc random shards per client
            let total_shards = m * shards_per_client;
            let shard_len = n / total_shards;
            if shard_len == 0 {
                return Err(Error::invalid("too many shards for dataset size"));
            }
            let mut shard_ids: Vec<usize> = (0..total_shards).collect();
            rng.shuffle(&mut shard_ids);
            let mut out = vec![Vec::new(); m];
            for (pos, &sid) in shard_ids.iter().enumerate() {
                let client = pos % m;
                let start = sid * shard_len;
                let end = if sid == total_shards - 1 { n } else { start + shard_len };
                out[client].extend(start..end);
                // map shard positions back to label-sorted sample indices
                let len = out[client].len();
                let slice = &mut out[client][len - (end - start)..];
                for v in slice.iter_mut() {
                    *v = idx[*v];
                }
            }
            Ok(out)
        }
    }
}

fn deal(idx: Vec<usize>, m: usize) -> Vec<Vec<usize>> {
    let n = idx.len();
    let base = n / m;
    let extra = n % m;
    let mut out = Vec::with_capacity(m);
    let mut at = 0;
    for c in 0..m {
        let len = base + usize::from(c < extra);
        out.push(idx[at..at + len].to_vec());
        at += len;
    }
    out
}

/// Split a token stream into `m` contiguous client ranges (the standard LM
/// federated split: each device owns a contiguous slice of corpus).
pub fn partition_text(n_tokens: usize, m: usize) -> Result<Vec<Range<usize>>> {
    if m == 0 || n_tokens < m {
        return Err(Error::invalid(format!("cannot split {n_tokens} tokens into {m} clients")));
    }
    let base = n_tokens / m;
    let extra = n_tokens % m;
    let mut out = Vec::with_capacity(m);
    let mut at = 0;
    for c in 0..m {
        let len = base + usize::from(c < extra);
        out.push(at..at + len);
        at += len;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize) -> Vec<i32> {
        (0..n).map(|i| (i % 10) as i32).collect()
    }

    #[test]
    fn iid_covers_all_indices_exactly_once() {
        let mut rng = Rng::new(0);
        let shards = partition_images(&labels(103), 10, Scheme::Iid, &mut rng).unwrap();
        assert_eq!(shards.len(), 10);
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        // balanced within 1
        let sizes: Vec<usize> = shards.iter().map(Vec::len).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn iid_shards_are_label_diverse() {
        let mut rng = Rng::new(1);
        let lab = labels(1000);
        let shards = partition_images(&lab, 10, Scheme::Iid, &mut rng).unwrap();
        for shard in &shards {
            let distinct: std::collections::HashSet<i32> =
                shard.iter().map(|&i| lab[i]).collect();
            assert!(distinct.len() >= 8, "IID shard should see most classes");
        }
    }

    #[test]
    fn noniid_shards_are_label_concentrated() {
        let mut rng = Rng::new(2);
        let lab = labels(1000);
        let shards = partition_images(
            &lab,
            10,
            Scheme::NonIidShards { shards_per_client: 2 },
            &mut rng,
        )
        .unwrap();
        // every index assigned exactly once
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
        // each client sees few distinct labels (2 shards -> <= ~4 labels)
        for shard in &shards {
            let distinct: std::collections::HashSet<i32> =
                shard.iter().map(|&i| lab[i]).collect();
            assert!(
                distinct.len() <= 4,
                "non-IID shard too diverse: {}",
                distinct.len()
            );
        }
    }

    #[test]
    fn text_ranges_are_contiguous_and_exhaustive() {
        let ranges = partition_text(1003, 7).unwrap();
        assert_eq!(ranges.len(), 7);
        let mut at = 0;
        for r in &ranges {
            assert_eq!(r.start, at);
            at = r.end;
        }
        assert_eq!(at, 1003);
    }

    #[test]
    fn errors_on_degenerate_inputs() {
        let mut rng = Rng::new(0);
        assert!(partition_images(&labels(5), 10, Scheme::Iid, &mut rng).is_err());
        assert!(partition_images(&labels(0), 0, Scheme::Iid, &mut rng).is_err());
        assert!(partition_text(3, 10).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let a = partition_images(&labels(100), 5, Scheme::Iid, &mut Rng::new(9)).unwrap();
        let b = partition_images(&labels(100), 5, Scheme::Iid, &mut Rng::new(9)).unwrap();
        assert_eq!(a, b);
    }
}
