//! Dataset resolution: real files if present, synthetic otherwise.
//!
//! `DatasetSpec` names one of the paper's three datasets plus sizing knobs.
//! `load` looks for the original files under `data_dir` (default `data/`)
//! and falls back to the synthetic generator, logging which source was
//! used — so dropping the real corpora into the tree upgrades every figure
//! driver without code changes.

use std::path::{Path, PathBuf};

use crate::data::{cifar_bin, idx, synth, tokenizer, Dataset};
use crate::util::error::{Error, Result};

/// Which dataset, plus synthetic sizing (ignored when real files exist).
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetSpec {
    pub name: String,
    pub n_train: usize,
    pub n_test: usize,
    /// LM vocab (must match the model artifact's vocab).
    pub vocab: usize,
    pub seed: u64,
}

impl DatasetSpec {
    /// Paper-aligned defaults, CPU-scaled (DESIGN.md §2): the real datasets
    /// are 60k/50k samples; default synthetic sizing keeps figure sweeps
    /// tractable while `--paper-scale` style overrides restore full size.
    pub fn named(name: &str, seed: u64) -> Result<DatasetSpec> {
        let (n_train, n_test, vocab) = match name {
            "mnist" => (4_000, 1_024, 0),
            "cifar10" => (1_200, 512, 0),
            "wikitext2" => (120_000, 12_000, 2_000),
            other => {
                return Err(Error::invalid(format!(
                    "unknown dataset '{other}' (mnist | cifar10 | wikitext2)"
                )))
            }
        };
        Ok(DatasetSpec {
            name: name.to_string(),
            n_train,
            n_test,
            vocab,
            seed,
        })
    }

    /// The dataset the paper pairs with each model.
    pub fn for_model(model: &str, seed: u64) -> Result<DatasetSpec> {
        match model {
            "lenet" => Self::named("mnist", seed),
            "vggmini" => Self::named("cifar10", seed),
            "gru" => Self::named("wikitext2", seed),
            other => Err(Error::invalid(format!("no default dataset for model '{other}'"))),
        }
    }

    /// Paper-scale sizes (Table 1).
    pub fn paper_scale(mut self) -> DatasetSpec {
        match self.name.as_str() {
            "mnist" => {
                self.n_train = 60_000;
                self.n_test = 10_000;
            }
            "cifar10" => {
                self.n_train = 50_000;
                self.n_test = 10_000;
            }
            "wikitext2" => {
                self.n_train = 2_088_628;
                self.n_test = 245_569;
            }
            _ => {}
        }
        self
    }
}

/// Load `spec`, preferring real files under `data_dir`.
pub fn load(spec: &DatasetSpec, data_dir: &Path) -> Result<Dataset> {
    let ds = match spec.name.as_str() {
        "mnist" => load_mnist_real(&data_dir.join("mnist")).unwrap_or_else(|| {
            log::info!(
                "mnist: real IDX files not found under {}; using synthetic ({} train)",
                data_dir.display(),
                spec.n_train
            );
            synth::mnist_like(spec.n_train, spec.n_test, spec.seed)
        }),
        "cifar10" => load_cifar_real(&data_dir.join("cifar10")).unwrap_or_else(|| {
            log::info!(
                "cifar10: real binary batches not found; using synthetic ({} train)",
                spec.n_train
            );
            synth::cifar_like(spec.n_train, spec.n_test, spec.seed)
        }),
        "wikitext2" => load_wikitext_real(&data_dir.join("wikitext2"), spec.vocab)
            .unwrap_or_else(|| {
                log::info!(
                    "wikitext2: real corpus not found; using synthetic Markov corpus ({} tokens)",
                    spec.n_train
                );
                synth::markov_text(spec.n_train, spec.n_test, spec.vocab, spec.seed)
            }),
        other => return Err(Error::invalid(format!("unknown dataset '{other}'"))),
    };
    ds.validate()?;
    Ok(ds)
}

fn load_mnist_real(dir: &Path) -> Option<Dataset> {
    let files = [
        dir.join("train-images-idx3-ubyte"),
        dir.join("train-labels-idx1-ubyte"),
        dir.join("t10k-images-idx3-ubyte"),
        dir.join("t10k-labels-idx1-ubyte"),
    ];
    if !files.iter().all(|f| f.exists()) {
        return None;
    }
    let train = idx::load_pair(&files[0], &files[1]).ok()?;
    let test = idx::load_pair(&files[2], &files[3]).ok()?;
    log::info!("mnist: loaded real IDX data ({} train / {} test)", train.len(), test.len());
    Some(Dataset::Image { train, test })
}

fn load_cifar_real(dir: &Path) -> Option<Dataset> {
    let train_paths: Vec<PathBuf> = (1..=5).map(|i| dir.join(format!("data_batch_{i}.bin"))).collect();
    let test_path = dir.join("test_batch.bin");
    if !train_paths.iter().all(|p| p.exists()) || !test_path.exists() {
        return None;
    }
    let train_refs: Vec<&Path> = train_paths.iter().map(PathBuf::as_path).collect();
    let train = cifar_bin::load_batches(&train_refs).ok()?;
    let test = cifar_bin::load_batches(&[test_path.as_path()]).ok()?;
    log::info!("cifar10: loaded real binary data ({} train / {} test)", train.len(), test.len());
    Some(Dataset::Image { train, test })
}

fn load_wikitext_real(dir: &Path, vocab: usize) -> Option<Dataset> {
    let train_path = dir.join("wiki.train.tokens");
    let test_path = dir.join("wiki.test.tokens");
    if !train_path.exists() || !test_path.exists() {
        return None;
    }
    let train_text = std::fs::read_to_string(train_path).ok()?;
    let test_text = std::fs::read_to_string(test_path).ok()?;
    let (train, test, _) = tokenizer::tokenize_corpus(&train_text, &test_text, vocab);
    log::info!(
        "wikitext2: loaded real corpus ({} train / {} test tokens)",
        train.len(),
        test.len()
    );
    Some(Dataset::Text { train, test })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_specs() {
        let s = DatasetSpec::named("mnist", 0).unwrap();
        assert_eq!(s.n_train, 4_000);
        assert!(DatasetSpec::named("imagenet", 0).is_err());
        let p = s.paper_scale();
        assert_eq!(p.n_train, 60_000);
    }

    #[test]
    fn model_pairing_matches_paper() {
        assert_eq!(DatasetSpec::for_model("lenet", 0).unwrap().name, "mnist");
        assert_eq!(DatasetSpec::for_model("vggmini", 0).unwrap().name, "cifar10");
        assert_eq!(DatasetSpec::for_model("gru", 0).unwrap().name, "wikitext2");
    }

    #[test]
    fn falls_back_to_synthetic() {
        let spec = DatasetSpec {
            name: "mnist".into(),
            n_train: 100,
            n_test: 40,
            vocab: 0,
            seed: 3,
        };
        let ds = load(&spec, Path::new("/nonexistent")).unwrap();
        assert_eq!(ds.train_len(), 100);
        assert_eq!(ds.test_len(), 40);
    }

    #[test]
    fn real_mnist_used_when_present() {
        // build a fake-but-valid IDX tree and confirm it is preferred
        let dir = std::env::temp_dir().join(format!("fedmask_loader_{}", std::process::id()));
        let mdir = dir.join("mnist");
        std::fs::create_dir_all(&mdir).unwrap();
        let write_idx = |n: usize, img: &Path, lbl: &Path| {
            let mut b = Vec::new();
            b.extend_from_slice(&0x0000_0803u32.to_be_bytes());
            b.extend_from_slice(&(n as u32).to_be_bytes());
            b.extend_from_slice(&28u32.to_be_bytes());
            b.extend_from_slice(&28u32.to_be_bytes());
            b.extend(std::iter::repeat(7u8).take(n * 784));
            std::fs::write(img, &b).unwrap();
            let mut l = Vec::new();
            l.extend_from_slice(&0x0000_0801u32.to_be_bytes());
            l.extend_from_slice(&(n as u32).to_be_bytes());
            l.extend((0..n).map(|i| (i % 10) as u8));
            std::fs::write(lbl, &l).unwrap();
        };
        write_idx(
            12,
            &mdir.join("train-images-idx3-ubyte"),
            &mdir.join("train-labels-idx1-ubyte"),
        );
        write_idx(
            4,
            &mdir.join("t10k-images-idx3-ubyte"),
            &mdir.join("t10k-labels-idx1-ubyte"),
        );
        let spec = DatasetSpec::named("mnist", 0).unwrap();
        let ds = load(&spec, &dir).unwrap();
        assert_eq!(ds.train_len(), 12, "real data must win over synthetic");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
