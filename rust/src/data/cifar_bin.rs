//! CIFAR-10 binary format parser (`data_batch_{1..5}.bin`, `test_batch.bin`).
//!
//! Each record is 3073 bytes: 1 label byte + 3072 pixel bytes in CHW order
//! (1024 R, 1024 G, 1024 B). We convert to the HWC layout the VGG artifact
//! expects and standardize with the canonical per-channel CIFAR-10 stats.

use std::io::Read;
use std::path::Path;

use crate::data::ImageData;
use crate::util::error::{Error, Result};

pub const RECORD_BYTES: usize = 3073;
const SIDE: usize = 32;
const PLANE: usize = SIDE * SIDE;

const MEAN: [f32; 3] = [0.4914, 0.4822, 0.4465];
const STD: [f32; 3] = [0.2470, 0.2435, 0.2616];

/// Parse one binary batch buffer into (labels, HWC standardized pixels).
pub fn parse_batch(data: &[u8]) -> Result<(Vec<i32>, Vec<f32>)> {
    if data.is_empty() || data.len() % RECORD_BYTES != 0 {
        return Err(Error::parse(format!(
            "cifar: payload {} not a multiple of {RECORD_BYTES}",
            data.len()
        )));
    }
    let n = data.len() / RECORD_BYTES;
    let mut labels = Vec::with_capacity(n);
    let mut pixels = Vec::with_capacity(n * 3 * PLANE);
    for rec in data.chunks_exact(RECORD_BYTES) {
        let label = rec[0];
        if label > 9 {
            return Err(Error::parse(format!("cifar: label {label} > 9")));
        }
        labels.push(label as i32);
        let body = &rec[1..];
        // CHW -> HWC with standardization
        for pix in 0..PLANE {
            for ch in 0..3 {
                let v = body[ch * PLANE + pix] as f32 / 255.0;
                pixels.push((v - MEAN[ch]) / STD[ch]);
            }
        }
    }
    Ok((labels, pixels))
}

/// Load several batch files into one [`ImageData`].
pub fn load_batches(paths: &[&Path]) -> Result<ImageData> {
    let mut x = Vec::new();
    let mut y = Vec::new();
    for path in paths {
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        let (labels, pixels) = parse_batch(&bytes)?;
        y.extend(labels);
        x.extend(pixels);
    }
    let data = ImageData {
        x,
        y,
        elem_shape: vec![SIDE, SIDE, 3],
        classes: 10,
    };
    data.validate()?;
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_record(label: u8, fill: u8) -> Vec<u8> {
        let mut rec = vec![label];
        rec.extend(std::iter::repeat(fill).take(3072));
        rec
    }

    #[test]
    fn parses_records() {
        let mut buf = fake_record(3, 128);
        buf.extend(fake_record(7, 0));
        let (labels, pixels) = parse_batch(&buf).unwrap();
        assert_eq!(labels, vec![3, 7]);
        assert_eq!(pixels.len(), 2 * 3072);
        // second image all-zero pixels standardize to -mean/std per channel
        let r = pixels[3072];
        assert!((r - (0.0 - MEAN[0]) / STD[0]).abs() < 1e-5);
    }

    #[test]
    fn chw_to_hwc_layout() {
        // distinct per-channel fills: R=255, G=0, B=0
        let mut rec = vec![0u8];
        rec.extend(std::iter::repeat(255u8).take(PLANE)); // R plane
        rec.extend(std::iter::repeat(0u8).take(2 * PLANE)); // G,B planes
        let (_, pixels) = parse_batch(&rec).unwrap();
        // HWC: first three values are (R,G,B) of pixel 0
        assert!(pixels[0] > 0.0, "R should be high");
        assert!(pixels[1] < 0.0, "G should be low");
        assert!(pixels[2] < 0.0, "B should be low");
    }

    #[test]
    fn rejects_bad_sizes_and_labels() {
        assert!(parse_batch(&[0u8; 100]).is_err());
        let rec = fake_record(12, 0);
        assert!(parse_batch(&rec).is_err());
    }
}
