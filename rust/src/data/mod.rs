//! Dataset substrate.
//!
//! The paper evaluates on MNIST, CIFAR-10 and WikiText-2. This environment
//! has no network access, so each dataset has two sources:
//!
//! * **real-format loaders** — [`idx`] parses MNIST IDX files, [`cifar_bin`]
//!   parses the CIFAR-10 binary batches, [`tokenizer`] builds a word-level
//!   vocab from any raw-text corpus. Drop the original files under
//!   `data/{mnist,cifar10,wikitext2}/` and they are used automatically.
//! * **procedural synthetic generators** ([`synth`]) — class-conditional
//!   image distributions and a Zipf/Markov corpus with the same tensor
//!   geometry and learnability profile (DESIGN.md §2 substitution table).
//!
//! [`partition`] implements the I.I.D. split of McMahan et al. (plus the
//! pathological non-IID shard split as an extension) and [`batcher`] turns
//! client shards into the fixed-geometry chunks the train artifact expects.

pub mod batcher;
pub mod cifar_bin;
pub mod idx;
pub mod loader;
pub mod partition;
pub mod synth;
pub mod tokenizer;

/// Image dataset half (train or test): row-major `[n, elem...]` pixels
/// (already scaled/standardized) + integer labels.
#[derive(Debug, Clone)]
pub struct ImageData {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub elem_shape: Vec<usize>,
    pub classes: usize,
}

impl ImageData {
    pub fn elem_len(&self) -> usize {
        self.elem_shape.iter().product()
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Sanity invariant: x length matches labels * elem size.
    pub fn validate(&self) -> crate::Result<()> {
        if self.x.len() != self.y.len() * self.elem_len() {
            return Err(crate::Error::invalid(format!(
                "image data x len {} != n {} * elem {}",
                self.x.len(),
                self.y.len(),
                self.elem_len()
            )));
        }
        if let Some(&bad) = self.y.iter().find(|&&c| c < 0 || c as usize >= self.classes) {
            return Err(crate::Error::invalid(format!("label {bad} out of range")));
        }
        Ok(())
    }
}

/// Token-stream dataset half for language modeling.
#[derive(Debug, Clone)]
pub struct TextData {
    pub tokens: Vec<i32>,
    pub vocab: usize,
}

impl TextData {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn validate(&self) -> crate::Result<()> {
        if let Some(&bad) = self
            .tokens
            .iter()
            .find(|&&t| t < 0 || t as usize >= self.vocab)
        {
            return Err(crate::Error::invalid(format!("token {bad} out of vocab")));
        }
        Ok(())
    }
}

/// Train+test pair for one task.
#[derive(Debug, Clone)]
pub enum Dataset {
    Image { train: ImageData, test: ImageData },
    Text { train: TextData, test: TextData },
}

impl Dataset {
    pub fn train_len(&self) -> usize {
        match self {
            Dataset::Image { train, .. } => train.len(),
            Dataset::Text { train, .. } => train.len(),
        }
    }

    pub fn test_len(&self) -> usize {
        match self {
            Dataset::Image { test, .. } => test.len(),
            Dataset::Text { test, .. } => test.len(),
        }
    }

    pub fn validate(&self) -> crate::Result<()> {
        match self {
            Dataset::Image { train, test } => {
                train.validate()?;
                test.validate()
            }
            Dataset::Text { train, test } => {
                train.validate()?;
                test.validate()
            }
        }
    }
}
