//! Word-level tokenizer + vocabulary builder (the WikiText-2 pipeline).
//!
//! Mirrors the standard word-level LM preprocessing: whitespace tokens,
//! lowercasing, frequency-ranked vocab capped at the model's vocab size,
//! out-of-vocab words mapped to `<unk>`, newlines to `<eos>`. If a real
//! `wiki.train.tokens` is dropped under `data/wikitext2/`, this is the path
//! that ingests it; the synthetic Markov corpus bypasses tokenization.

use std::collections::HashMap;

use crate::data::TextData;

pub const UNK: &str = "<unk>";
pub const EOS: &str = "<eos>";

/// Frequency-ranked word vocabulary.
#[derive(Debug, Clone)]
pub struct Vocab {
    id_of: HashMap<String, i32>,
    words: Vec<String>,
}

impl Vocab {
    /// Build from a corpus: rank words by frequency (ties broken
    /// lexicographically for determinism), cap at `max_size` including the
    /// reserved `<unk>`/`<eos>` entries.
    pub fn build(text: &str, max_size: usize) -> Vocab {
        assert!(max_size >= 3, "vocab too small");
        let mut freq: HashMap<&str, usize> = HashMap::new();
        for line in text.lines() {
            for w in line.split_whitespace() {
                *freq.entry(w).or_default() += 1;
            }
        }
        let mut ranked: Vec<(&str, usize)> = freq
            .into_iter()
            .filter(|(w, _)| *w != UNK && *w != EOS)
            .collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));

        let mut words = vec![UNK.to_string(), EOS.to_string()];
        words.extend(
            ranked
                .into_iter()
                .take(max_size - 2)
                .map(|(w, _)| w.to_string()),
        );
        let id_of = words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Vocab { id_of, words }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn id(&self, word: &str) -> i32 {
        *self.id_of.get(word).unwrap_or(&0) // 0 == <unk>
    }

    pub fn word(&self, id: i32) -> &str {
        self.words
            .get(id as usize)
            .map(String::as_str)
            .unwrap_or(UNK)
    }

    /// Encode a corpus: words to ids, line breaks to `<eos>`.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::new();
        for line in text.lines() {
            for w in line.split_whitespace() {
                out.push(self.id(w));
            }
            out.push(self.id(EOS));
        }
        out
    }
}

/// Tokenize a (train, test) corpus pair with a train-derived vocab.
pub fn tokenize_corpus(train_text: &str, test_text: &str, vocab_size: usize) -> (TextData, TextData, Vocab) {
    let vocab = Vocab::build(train_text, vocab_size);
    let train = TextData {
        tokens: vocab.encode(train_text),
        vocab: vocab.len().max(vocab_size),
    };
    let test = TextData {
        tokens: vocab.encode(test_text),
        vocab: train.vocab,
    };
    (train, test, vocab)
}

#[cfg(test)]
mod tests {
    use super::*;

    const CORPUS: &str = "the cat sat on the mat\nthe dog sat on the log\n";

    #[test]
    fn vocab_ranks_by_frequency() {
        let v = Vocab::build(CORPUS, 50);
        // "the" (4x) must be the first non-reserved word
        assert_eq!(v.word(2), "the");
        assert_eq!(v.id("the"), 2);
        assert_eq!(v.id(UNK), 0);
        assert_eq!(v.id(EOS), 1);
    }

    #[test]
    fn oov_maps_to_unk() {
        let v = Vocab::build(CORPUS, 50);
        assert_eq!(v.id("zebra"), 0);
    }

    #[test]
    fn cap_keeps_most_frequent() {
        let v = Vocab::build(CORPUS, 4); // unk, eos + 2 words
        assert_eq!(v.len(), 4);
        assert_eq!(v.word(2), "the");
        // "sat"/"on" (2x each, tie broken lexicographically: "on" < "sat")
        assert_eq!(v.word(3), "on");
        assert!(v.id("cat") == 0); // evicted -> unk
    }

    #[test]
    fn encode_inserts_eos_per_line() {
        let v = Vocab::build(CORPUS, 50);
        let ids = v.encode("the cat\nthe dog\n");
        let eos = v.id(EOS);
        assert_eq!(ids.iter().filter(|&&i| i == eos).count(), 2);
        assert_eq!(ids.len(), 6);
    }

    #[test]
    fn tokenize_corpus_shares_vocab() {
        let (train, test, vocab) = tokenize_corpus(CORPUS, "the zebra\n", 50);
        assert_eq!(train.vocab, test.vocab);
        assert_eq!(test.tokens[0], vocab.id("the"));
        assert_eq!(test.tokens[1], 0); // zebra -> unk
        train.validate().unwrap();
        test.validate().unwrap();
    }

    #[test]
    fn deterministic_ranking_on_ties() {
        let a = Vocab::build(CORPUS, 10);
        let b = Vocab::build(CORPUS, 10);
        assert_eq!(a.words, b.words);
    }
}
