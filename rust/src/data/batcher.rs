//! Chunk assembly: client shards -> the fixed-geometry [`Batches`] the
//! train/eval artifacts expect.
//!
//! The train artifact consumes `nb_train * batch` samples per call (one
//! scanned local epoch); a client whose shard is smaller wraps around its
//! own shard (standard epoch semantics with replacement at the tail), and a
//! larger shard yields multiple chunks per epoch. Shard order is reshuffled
//! per (client, round, epoch) from the experiment seed.

use std::ops::Range;

use crate::data::{ImageData, TextData};
use crate::runtime::manifest::ModelManifest;
use crate::runtime::tensor::{Batches, XData};
use crate::sim::rng::Rng;
use crate::util::error::Result;

/// Build one train-epoch's worth of chunks from an image shard.
pub fn image_train_chunks(
    data: &ImageData,
    shard: &[usize],
    mm: &ModelManifest,
    rng: &mut Rng,
) -> Result<Vec<Batches>> {
    assert!(!shard.is_empty(), "empty client shard");
    let chunk_samples = mm.train_chunk_samples();
    let n_chunks = (shard.len() + chunk_samples - 1) / chunk_samples;
    let mut order: Vec<usize> = shard.to_vec();
    rng.shuffle(&mut order);
    let elem = data.elem_len();
    let mut chunks = Vec::with_capacity(n_chunks);
    for c in 0..n_chunks {
        let mut xs = Vec::with_capacity(chunk_samples * elem);
        let mut ys = Vec::with_capacity(chunk_samples);
        for s in 0..chunk_samples {
            // wrap within the shard for the final partial chunk
            let idx = order[(c * chunk_samples + s) % order.len()];
            xs.extend_from_slice(&data.x[idx * elem..(idx + 1) * elem]);
            ys.push(data.y[idx]);
        }
        chunks.push(Batches::new(
            mm.nb_train,
            mm.batch,
            mm.x_elem_shape.clone(),
            mm.y_elem_shape.clone(),
            XData::F32(xs),
            ys,
        )?);
    }
    Ok(chunks)
}

/// Build eval chunks covering (a prefix of) the test set; `max_chunks`
/// bounds eval cost for the figure sweeps (0 = cover everything).
pub fn image_eval_chunks(
    data: &ImageData,
    mm: &ModelManifest,
    max_chunks: usize,
) -> Result<Vec<Batches>> {
    let chunk_samples = mm.eval_chunk_samples();
    let mut n_chunks = data.len() / chunk_samples;
    if max_chunks > 0 {
        n_chunks = n_chunks.min(max_chunks);
    }
    assert!(n_chunks > 0, "test set smaller than one eval chunk");
    let elem = data.elem_len();
    let mut chunks = Vec::with_capacity(n_chunks);
    for c in 0..n_chunks {
        let start = c * chunk_samples;
        let xs = data.x[start * elem..(start + chunk_samples) * elem].to_vec();
        let ys = data.y[start..start + chunk_samples].to_vec();
        chunks.push(Batches::new(
            mm.nb_eval,
            mm.batch,
            mm.x_elem_shape.clone(),
            mm.y_elem_shape.clone(),
            XData::F32(xs),
            ys,
        )?);
    }
    Ok(chunks)
}

/// Sequence windows for LM training: non-overlapping `seq+1` windows from
/// the client's contiguous token range, shuffled; x = w[..seq], y = w[1..].
pub fn text_train_chunks(
    data: &TextData,
    range: &Range<usize>,
    mm: &ModelManifest,
    rng: &mut Rng,
) -> Result<Vec<Batches>> {
    let seq = mm.x_elem_shape[0];
    let window = seq + 1;
    let tokens = &data.tokens[range.clone()];
    let n_windows = tokens.len() / window;
    assert!(n_windows > 0, "client token range smaller than one window");
    let mut order: Vec<usize> = (0..n_windows).collect();
    rng.shuffle(&mut order);

    let chunk_samples = mm.train_chunk_samples();
    let n_chunks = (n_windows + chunk_samples - 1) / chunk_samples;
    let mut chunks = Vec::with_capacity(n_chunks);
    for c in 0..n_chunks {
        let mut xs = Vec::with_capacity(chunk_samples * seq);
        let mut ys = Vec::with_capacity(chunk_samples * seq);
        for s in 0..chunk_samples {
            let w = order[(c * chunk_samples + s) % order.len()];
            let at = w * window;
            xs.extend_from_slice(&tokens[at..at + seq]);
            ys.extend_from_slice(&tokens[at + 1..at + 1 + seq]);
        }
        chunks.push(Batches::new(
            mm.nb_train,
            mm.batch,
            mm.x_elem_shape.clone(),
            mm.y_elem_shape.clone(),
            XData::I32(xs),
            ys,
        )?);
    }
    Ok(chunks)
}

/// Eval windows over the test stream (sequential, non-overlapping).
pub fn text_eval_chunks(data: &TextData, mm: &ModelManifest, max_chunks: usize) -> Result<Vec<Batches>> {
    let seq = mm.x_elem_shape[0];
    let window = seq + 1;
    let chunk_samples = mm.eval_chunk_samples();
    let n_windows = data.tokens.len() / window;
    let mut n_chunks = n_windows / chunk_samples;
    if max_chunks > 0 {
        n_chunks = n_chunks.min(max_chunks);
    }
    assert!(n_chunks > 0, "test stream smaller than one eval chunk");
    let mut chunks = Vec::with_capacity(n_chunks);
    for c in 0..n_chunks {
        let mut xs = Vec::with_capacity(chunk_samples * seq);
        let mut ys = Vec::with_capacity(chunk_samples * seq);
        for s in 0..chunk_samples {
            let at = (c * chunk_samples + s) * window;
            xs.extend_from_slice(&data.tokens[at..at + seq]);
            ys.extend_from_slice(&data.tokens[at + 1..at + 1 + seq]);
        }
        chunks.push(Batches::new(
            mm.nb_eval,
            mm.batch,
            mm.x_elem_shape.clone(),
            mm.y_elem_shape.clone(),
            XData::I32(xs),
            ys,
        )?);
    }
    Ok(chunks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ModelManifest;
    use std::collections::BTreeMap;

    fn image_mm() -> ModelManifest {
        ModelManifest {
            name: "toy".into(),
            p: 4,
            task: "image".into(),
            batch: 4,
            nb_train: 2,
            nb_eval: 2,
            x_elem_shape: vec![3],
            x_dtype: "f32".into(),
            y_elem_shape: vec![],
            layers: vec![],
            artifacts: BTreeMap::new(),
            meta: BTreeMap::new(),
        }
    }

    fn lm_mm() -> ModelManifest {
        ModelManifest {
            name: "toylm".into(),
            p: 4,
            task: "lm".into(),
            batch: 2,
            nb_train: 2,
            nb_eval: 2,
            x_elem_shape: vec![4],
            x_dtype: "i32".into(),
            y_elem_shape: vec![4],
            layers: vec![],
            artifacts: BTreeMap::new(),
            meta: BTreeMap::new(),
        }
    }

    fn image_data(n: usize) -> ImageData {
        ImageData {
            x: (0..n * 3).map(|i| i as f32).collect(),
            y: (0..n).map(|i| (i % 10) as i32).collect(),
            elem_shape: vec![3],
            classes: 10,
        }
    }

    #[test]
    fn image_chunks_cover_shard_with_wrap() {
        let data = image_data(50);
        let shard: Vec<usize> = (10..23).collect(); // 13 samples, chunk=8
        let mut rng = Rng::new(0);
        let chunks = image_train_chunks(&data, &shard, &image_mm(), &mut rng).unwrap();
        assert_eq!(chunks.len(), 2); // ceil(13/8)
        for ch in &chunks {
            assert_eq!(ch.samples(), 8);
            // labels must come from the shard
            for &y in &ch.ys {
                let idx = y as usize; // label == idx % 10; just check range
                assert!(idx < 10);
            }
        }
        // all shard samples appear at least once across the epoch
        let mut seen = std::collections::HashSet::new();
        for ch in &chunks {
            let XData::F32(xs) = &ch.xs else { panic!() };
            for s in 0..ch.samples() {
                // reconstruct the sample index from its first feature value
                let v = xs[s * 3] as usize / 3;
                seen.insert(v);
            }
        }
        for i in &shard {
            assert!(seen.contains(i), "sample {i} missing from epoch");
        }
    }

    #[test]
    fn eval_chunks_sequential_cap() {
        let data = image_data(40);
        let chunks = image_eval_chunks(&data, &image_mm(), 3).unwrap();
        assert_eq!(chunks.len(), 3);
        let all = image_eval_chunks(&data, &image_mm(), 0).unwrap();
        assert_eq!(all.len(), 5); // 40 / 8
        // first chunk is the first 8 samples in order
        assert_eq!(all[0].ys, (0..8).map(|i| (i % 10) as i32).collect::<Vec<_>>());
    }

    #[test]
    fn text_chunks_shift_labels_by_one() {
        let data = TextData {
            tokens: (0..200).map(|i| (i % 50) as i32).collect(),
            vocab: 50,
        };
        let mut rng = Rng::new(1);
        let chunks = text_train_chunks(&data, &(0..200), &lm_mm(), &mut rng).unwrap();
        for ch in &chunks {
            let XData::I32(xs) = &ch.xs else { panic!() };
            for s in 0..ch.samples() {
                for t in 0..3 {
                    // y[t] == x[t+1] within a window
                    assert_eq!(ch.ys[s * 4 + t], xs[s * 4 + t + 1]);
                }
            }
        }
    }

    #[test]
    fn text_eval_deterministic_and_ordered() {
        let data = TextData {
            tokens: (0..500).map(|i| (i % 50) as i32).collect(),
            vocab: 50,
        };
        let a = text_eval_chunks(&data, &lm_mm(), 2).unwrap();
        let b = text_eval_chunks(&data, &lm_mm(), 2).unwrap();
        assert_eq!(a, b);
        let XData::I32(xs) = &a[0].xs else { panic!() };
        assert_eq!(&xs[..4], &[0, 1, 2, 3]);
    }

    #[test]
    fn reshuffle_changes_order_not_content() {
        let data = image_data(64);
        let shard: Vec<usize> = (0..16).collect();
        let a = image_train_chunks(&data, &shard, &image_mm(), &mut Rng::new(1)).unwrap();
        let b = image_train_chunks(&data, &shard, &image_mm(), &mut Rng::new(2)).unwrap();
        assert_ne!(a[0].ys, b[0].ys, "different seeds should reorder");
        let mut ya: Vec<i32> = a.iter().flat_map(|c| c.ys.clone()).collect();
        let mut yb: Vec<i32> = b.iter().flat_map(|c| c.ys.clone()).collect();
        ya.sort_unstable();
        yb.sort_unstable();
        assert_eq!(ya, yb, "same multiset of labels");
    }
}
