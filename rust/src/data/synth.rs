//! Procedural synthetic datasets (DESIGN.md §2 substitution table).
//!
//! Offline stand-ins for the paper's three corpora, built to exercise the
//! identical code paths and produce the same *relative* dynamics:
//!
//! * `mnist_like`  — 28x28x1, 10 classes. Each class is a low-rank "stroke"
//!   template; samples add spatial shift + pixel noise. A LeNet reaches
//!   high accuracy in a few federated rounds, from a ~10% random-guess
//!   start, matching real-MNIST curve shape.
//! * `cifar_like`  — 32x32x3, 10 classes. Class-conditional smooth color
//!   fields + texture noise; deliberately harder (lower SNR) so conv-net
//!   accuracy climbs slowly, like real CIFAR.
//! * `markov_text` — Zipf unigram marginals with order-1 Markov structure
//!   and per-token successor sparsity; a GRU LM's perplexity falls from
//!   ~vocab to a low plateau, like word-level WikiText-2.

use crate::data::{Dataset, ImageData, TextData};
use crate::sim::rng::Rng;

/// Smooth per-class template of `elem` pixels built from `k` random
/// cosine "strokes" — low-rank, so classes are separable but overlapping.
fn class_template(rng: &mut Rng, h: usize, w: usize, c: usize, strokes: usize) -> Vec<f32> {
    let mut img = vec![0.0f32; h * w * c];
    for _ in 0..strokes {
        let fx = 0.5 + 2.5 * rng.next_f32();
        let fy = 0.5 + 2.5 * rng.next_f32();
        let px = rng.next_f32() * std::f32::consts::PI * 2.0;
        let py = rng.next_f32() * std::f32::consts::PI * 2.0;
        let chan = rng.next_below(c as u64) as usize;
        let amp = 0.5 + 0.5 * rng.next_f32();
        for y in 0..h {
            for x in 0..w {
                let v = amp
                    * ((fx * x as f32 / w as f32 * std::f32::consts::TAU + px).cos()
                        * (fy * y as f32 / h as f32 * std::f32::consts::TAU + py).cos());
                img[(y * w + x) * c + chan] += v;
            }
        }
    }
    img
}

#[allow(clippy::too_many_arguments)]
fn gen_images(
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    classes: usize,
    noise: f32,
    max_shift: usize,
    template_seed: u64,
    sample_seed: u64,
) -> ImageData {
    // Templates depend ONLY on template_seed so the train and test halves
    // of one dataset share the same class-conditional distribution.
    let mut trng = Rng::new(template_seed).fork(0x7e17);
    let templates: Vec<Vec<f32>> = (0..classes)
        .map(|cl| class_template(&mut trng, h, w, c, 6 + cl % 3))
        .collect();
    let mut rng = Rng::new(sample_seed).fork(1);
    let elem = h * w * c;
    let mut x = Vec::with_capacity(n * elem);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let cl = rng.next_below(classes as u64) as usize;
        y.push(cl as i32);
        let dx = rng.next_below((2 * max_shift + 1) as u64) as isize - max_shift as isize;
        let dy = rng.next_below((2 * max_shift + 1) as u64) as isize - max_shift as isize;
        let t = &templates[cl];
        for py in 0..h {
            for px in 0..w {
                let sy = (py as isize + dy).rem_euclid(h as isize) as usize;
                let sx = (px as isize + dx).rem_euclid(w as isize) as usize;
                for ch in 0..c {
                    let v = t[(sy * w + sx) * c + ch] + noise * rng.next_normal();
                    x.push(v);
                }
            }
        }
    }
    ImageData {
        x,
        y,
        elem_shape: vec![h, w, c],
        classes,
    }
}

/// MNIST-like synthetic dataset (28x28x1, 10 classes).
pub fn mnist_like(n_train: usize, n_test: usize, seed: u64) -> Dataset {
    Dataset::Image {
        train: gen_images(n_train, 28, 28, 1, 10, 2.8, 2, seed, seed),
        test: gen_images(n_test, 28, 28, 1, 10, 2.8, 2, seed, seed ^ 0x5a5a),
    }
}

/// CIFAR-like synthetic dataset (32x32x3, 10 classes, lower SNR).
pub fn cifar_like(n_train: usize, n_test: usize, seed: u64) -> Dataset {
    Dataset::Image {
        train: gen_images(n_train, 32, 32, 3, 10, 2.2, 3, seed.wrapping_add(101), seed.wrapping_add(101)),
        test: gen_images(n_test, 32, 32, 3, 10, 2.2, 3, seed.wrapping_add(101), seed.wrapping_add(101) ^ 0x5a5a),
    }
}

/// Zipf + order-1 Markov token stream (WikiText-2-like dynamics).
///
/// Each token's successor distribution is concentrated on `succ` candidates
/// with Zipf weights, and candidates are themselves Zipf-distributed over
/// the vocab, so unigram frequencies are heavy-tailed like natural text.
pub fn markov_text(n_train: usize, n_test: usize, vocab: usize, seed: u64) -> Dataset {
    let succ = 24usize;
    let mut srng = Rng::new(seed).fork(7);
    // Zipf sampler over the vocab via inverse CDF on precomputed weights.
    let weights: Vec<f64> = (0..vocab).map(|i| 1.0 / (1.0 + i as f64)).collect();
    let total: f64 = weights.iter().sum();
    let mut cdf = Vec::with_capacity(vocab);
    let mut acc = 0.0;
    for w in &weights {
        acc += w / total;
        cdf.push(acc);
    }
    let zipf = |rng: &mut Rng| -> i32 {
        let u = rng.next_f64();
        cdf.partition_point(|&c| c < u).min(vocab - 1) as i32
    };
    // successor tables: token -> [succ] candidates
    let table: Vec<Vec<i32>> = (0..vocab)
        .map(|_| (0..succ).map(|_| zipf(&mut srng)).collect())
        .collect();
    // successor pick: Zipf over the candidate list (first candidates likely)
    let cand_weights: Vec<f64> = (0..succ).map(|i| 1.0 / (1.0 + i as f64)).collect();
    let cand_total: f64 = cand_weights.iter().sum();
    let mut cand_cdf = Vec::with_capacity(succ);
    let mut acc = 0.0;
    for w in &cand_weights {
        acc += w / cand_total;
        cand_cdf.push(acc);
    }
    let gen_stream = |n: usize, stream_seed: u64| -> TextData {
        let mut rng = Rng::new(stream_seed);
        let mut tok = zipf(&mut rng) as usize;
        let mut tokens = Vec::with_capacity(n);
        for _ in 0..n {
            tokens.push(tok as i32);
            // occasional resample keeps the chain mixing over the vocab
            tok = if rng.next_f64() < 0.05 {
                zipf(&mut rng) as usize
            } else {
                let u = rng.next_f64();
                let pick = cand_cdf.partition_point(|&c| c < u).min(succ - 1);
                table[tok][pick] as usize
            };
        }
        TextData { tokens, vocab }
    };
    Dataset::Text {
        train: gen_stream(n_train, seed.wrapping_add(11)),
        test: gen_stream(n_test, seed.wrapping_add(13)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_like_shapes_and_labels() {
        let ds = mnist_like(200, 50, 0);
        ds.validate().unwrap();
        let Dataset::Image { train, test } = &ds else {
            panic!()
        };
        assert_eq!(train.len(), 200);
        assert_eq!(test.len(), 50);
        assert_eq!(train.elem_shape, vec![28, 28, 1]);
        // all 10 classes present in 200 draws (overwhelmingly likely)
        let mut seen = [false; 10];
        for &c in &train.y {
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn cifar_like_is_three_channel() {
        let ds = cifar_like(50, 10, 1);
        ds.validate().unwrap();
        let Dataset::Image { train, .. } = &ds else {
            panic!()
        };
        assert_eq!(train.elem_shape, vec![32, 32, 3]);
        assert_eq!(train.x.len(), 50 * 32 * 32 * 3);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = mnist_like(20, 5, 7);
        let b = mnist_like(20, 5, 7);
        let (Dataset::Image { train: ta, .. }, Dataset::Image { train: tb, .. }) = (&a, &b) else {
            panic!()
        };
        assert_eq!(ta.x, tb.x);
        assert_eq!(ta.y, tb.y);
    }

    #[test]
    fn classes_are_separable() {
        // nearest-template classification on clean means should beat chance
        let ds = mnist_like(400, 0, 3);
        let Dataset::Image { train, .. } = &ds else {
            panic!()
        };
        let elem = train.elem_len();
        // per-class mean
        let mut means = vec![vec![0.0f32; elem]; 10];
        let mut counts = [0usize; 10];
        for i in 0..train.len() {
            let c = train.y[i] as usize;
            counts[c] += 1;
            for j in 0..elem {
                means[c][j] += train.x[i * elem + j];
            }
        }
        for c in 0..10 {
            for v in means[c].iter_mut() {
                *v /= counts[c].max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in 0..train.len() {
            let xi = &train.x[i * elem..(i + 1) * elem];
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = xi.iter().zip(&means[a]).map(|(x, m)| (x - m).powi(2)).sum();
                    let db: f32 = xi.iter().zip(&means[b]).map(|(x, m)| (x - m).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == train.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / train.len() as f64;
        assert!(acc > 0.6, "template separability too low: {acc}");
    }

    #[test]
    fn markov_text_in_vocab_and_predictable() {
        let ds = markov_text(20_000, 2_000, 500, 9);
        ds.validate().unwrap();
        let Dataset::Text { train, .. } = &ds else {
            panic!()
        };
        assert_eq!(train.len(), 20_000);
        // bigram structure: the most frequent successor of a frequent token
        // should appear far above the unigram rate of a random token.
        let mut next_counts = std::collections::HashMap::new();
        for w in train.tokens.windows(2) {
            *next_counts.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
        let max_bigram = next_counts.values().copied().max().unwrap();
        assert!(
            max_bigram > train.len() / 500,
            "no bigram structure: {max_bigram}"
        );
    }

    #[test]
    fn zipf_marginal_is_heavy_tailed() {
        let ds = markov_text(30_000, 0, 1000, 4);
        let Dataset::Text { train, .. } = &ds else {
            panic!()
        };
        let mut counts = vec![0usize; 1000];
        for &t in &train.tokens {
            counts[t as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = counts[..10].iter().sum();
        assert!(
            top10 as f64 > 0.15 * train.len() as f64,
            "marginal not heavy-tailed"
        );
    }
}
