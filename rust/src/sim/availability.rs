//! Client availability / straggler model.
//!
//! Algorithms 1 and 3 of the paper select clients with an ACK handshake:
//! the server keeps requesting until `m` clients have acknowledged. This
//! module decides, per (round, client), whether the device ACKs and how
//! long its local round trip takes — mirroring the cross-device reality
//! (devices are intermittently online, compute at different speeds) that
//! the paper's single-machine simulation abstracts away.

use crate::sim::rng::Rng;

/// Availability status of one client for one round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientState {
    /// Device answers the connection request.
    Available,
    /// Device never ACKs this round (offline / declined).
    Offline,
    /// Device ACKs but would exceed the round deadline (dropped mid-round).
    Straggler,
}

/// Stochastic availability model, evaluated deterministically per
/// (seed, round, client).
#[derive(Debug, Clone)]
pub struct AvailabilityModel {
    /// Probability a client ACKs a connection request.
    pub ack_prob: f64,
    /// Probability an ACKed client then straggles past the deadline.
    pub straggler_prob: f64,
    /// Mean local compute time per epoch (virtual seconds).
    pub compute_mean_s: f64,
    /// Multiplicative jitter spread (+- fraction of the mean).
    pub compute_jitter: f64,
    seed: u64,
}

impl Default for AvailabilityModel {
    /// Default: the paper's idealized setting — everyone available,
    /// homogeneous compute. Figure drivers use this; failure-injection
    /// tests and the ablation benches tighten it.
    fn default() -> Self {
        AvailabilityModel {
            ack_prob: 1.0,
            straggler_prob: 0.0,
            compute_mean_s: 1.0,
            compute_jitter: 0.0,
            seed: 0,
        }
    }
}

impl AvailabilityModel {
    pub fn new(ack_prob: f64, straggler_prob: f64, seed: u64) -> AvailabilityModel {
        assert!((0.0..=1.0).contains(&ack_prob), "ack_prob out of range");
        assert!(
            (0.0..=1.0).contains(&straggler_prob),
            "straggler_prob out of range"
        );
        AvailabilityModel {
            ack_prob,
            straggler_prob,
            seed,
            ..AvailabilityModel::default()
        }
    }

    /// Full constructor: availability *and* compute heterogeneity. The
    /// config layer builds this one so `compute_jitter` reaches the
    /// `Simulated` transport's delivery ordering.
    pub fn with_compute(
        ack_prob: f64,
        straggler_prob: f64,
        compute_mean_s: f64,
        compute_jitter: f64,
        seed: u64,
    ) -> AvailabilityModel {
        let mut m = AvailabilityModel::new(ack_prob, straggler_prob, seed);
        assert!(
            compute_mean_s.is_finite() && compute_mean_s >= 0.0,
            "compute_mean_s out of range"
        );
        assert!((0.0..=1.0).contains(&compute_jitter), "compute_jitter out of range");
        m.compute_mean_s = compute_mean_s;
        m.compute_jitter = compute_jitter;
        m
    }

    fn rng_for(&self, round: u64, client: u64) -> Rng {
        Rng::new(self.seed).fork(round).fork(client)
    }

    /// Does this client ACK, and does it finish in time?
    pub fn state(&self, round: u64, client: u64) -> ClientState {
        let mut rng = self.rng_for(round, client);
        if rng.next_f64() >= self.ack_prob {
            return ClientState::Offline;
        }
        if rng.next_f64() < self.straggler_prob {
            return ClientState::Straggler;
        }
        ClientState::Available
    }

    /// Virtual local-compute duration for `epochs` local epochs.
    pub fn compute_time(&self, round: u64, client: u64, epochs: usize) -> f64 {
        let mut rng = self.rng_for(round, client).fork(0xc0);
        let jitter = 1.0 + self.compute_jitter * (2.0 * rng.next_f64() - 1.0);
        self.compute_mean_s * epochs as f64 * jitter.max(0.05)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_fully_available() {
        let m = AvailabilityModel::default();
        for r in 0..5 {
            for c in 0..20 {
                assert_eq!(m.state(r, c), ClientState::Available);
            }
        }
    }

    #[test]
    fn deterministic_per_round_client() {
        let m = AvailabilityModel::new(0.7, 0.1, 99);
        for r in 0..10 {
            for c in 0..10 {
                assert_eq!(m.state(r, c), m.state(r, c));
            }
        }
    }

    #[test]
    fn ack_rate_tracks_probability() {
        let m = AvailabilityModel::new(0.7, 0.0, 5);
        let n = 20_000;
        let acks = (0..n)
            .filter(|&i| m.state(i / 100, i % 100) == ClientState::Available)
            .count();
        let rate = acks as f64 / n as f64;
        assert!((rate - 0.7).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn straggler_rate_is_conditional_on_ack() {
        let m = AvailabilityModel::new(1.0, 0.25, 5);
        let n = 20_000;
        let stragglers = (0..n)
            .filter(|&i| m.state(i / 100, i % 100) == ClientState::Straggler)
            .count();
        let rate = stragglers as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn compute_time_scales_with_epochs() {
        let mut m = AvailabilityModel::default();
        m.compute_mean_s = 2.0;
        let t1 = m.compute_time(0, 0, 1);
        let t3 = m.compute_time(0, 0, 3);
        assert!((t3 - 3.0 * t1).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "ack_prob")]
    fn rejects_bad_probability() {
        AvailabilityModel::new(1.5, 0.0, 0);
    }
}
