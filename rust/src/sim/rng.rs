//! Deterministic pseudo-random source (splitmix64 core).
//!
//! Every stochastic decision in the coordinator — client sampling, random
//! masking, synthetic data, availability jitter — draws from one of these,
//! derived from the experiment seed, so whole runs replay bit-identically.
//! splitmix64 is tiny, passes BigCrush on the streams we use, and `fork`
//! gives cheap independent substreams per client/round.

/// splitmix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second normal from the Box–Muller pair.
    spare_normal: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng {
            // Avoid the all-zeros fixed point without perturbing other seeds.
            state: seed ^ 0x9e3779b97f4a7c15,
            spare_normal: None,
        }
    }

    /// Derive an independent substream, e.g. per client id or round index.
    /// `fork(a) != fork(b)` streams are decorrelated by the golden-gamma
    /// multiply even for adjacent labels.
    pub fn fork(&self, label: u64) -> Rng {
        let mut base = Rng::new(
            self.state
                .wrapping_add(label.wrapping_mul(0xbf58476d1ce4e5b9))
                .wrapping_add(0x94d049bb133111eb),
        );
        // burn a step so fork(0) != clone
        base.next_u64();
        base
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Uses rejection sampling to kill modulo
    /// bias (matters for the partitioner's permutations).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller (pairs cached).
    pub fn next_normal(&mut self) -> f32 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u in (0,1] to keep ln finite
        let u = 1.0 - self.next_f64();
        let v = self.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some((r * theta.sin()) as f32);
        (r * theta.cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} of {n}");
        let mut pool: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn forks_are_decorrelated() {
        let base = Rng::new(3);
        let mut f0 = base.fork(0);
        let mut f1 = base.fork(1);
        let a: Vec<u64> = (0..8).map(|_| f0.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 40_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(17);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(19);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
