//! Simulation substrate: deterministic RNG, client availability / straggler
//! model, and the virtual clock used by the simulated network.
//!
//! The paper runs its federated setting on a single server and "ignores the
//! communication noise and delay in network" (§5.1.3); this module is what
//! lets `fedmask` additionally *model* those effects (DESIGN.md §2) while
//! keeping every run bit-reproducible from a single seed.

pub mod availability;
pub mod clock;
pub mod rng;

pub use availability::{AvailabilityModel, ClientState};
pub use clock::VirtualClock;
pub use rng::Rng;
