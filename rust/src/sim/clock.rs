//! Virtual clock for the simulated network.
//!
//! The coordinator advances this clock by modeled transfer/compute delays
//! instead of sleeping, so "wall-clock" results in figures are a pure
//! function of the seed and the network model.

/// Monotone virtual time in seconds.
#[derive(Debug, Default, Clone)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Current virtual time (seconds since experiment start).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by `dt` seconds; negative advances are a programming error.
    pub fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0 && dt.is_finite(), "bad clock advance {dt}");
        self.now += dt;
    }

    /// Advance to an absolute time if it is in the future (used when
    /// parallel client uploads complete at max(finish times)).
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let mut c = VirtualClock::new();
        c.advance(1.5);
        c.advance(0.5);
        assert!((c.now() - 2.0).abs() < 1e-12);
        c.advance_to(1.0); // in the past: no-op
        assert!((c.now() - 2.0).abs() < 1e-12);
        c.advance_to(3.0);
        assert!((c.now() - 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn negative_advance_panics() {
        VirtualClock::new().advance(-1.0);
    }
}
