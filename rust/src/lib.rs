//! # fedmask — communication-efficient federated learning
//!
//! A three-layer reproduction of *Dynamic Sampling and Selective Masking for
//! Communication-Efficient Federated Learning* (Ji, Jiang, Walid, Li; cs.LG
//! 2020):
//!
//! * **Layer 3 (this crate)** — the federated runtime: client registry,
//!   per-round sampling scheduler ([`fl::sampling`]), masking policies
//!   ([`fl::masking`]), streaming weighted FedAvg aggregation over decoded
//!   wire payloads ([`fl::aggregate`]), the load-bearing sparse transport
//!   plane + byte accounting ([`transport`]), simulated network and client
//!   availability ([`sim`]), metrics, config, CLI, and the paper-figure
//!   harness ([`figures`]).
//! * **Layer 2 (build-time JAX)** — the client learners (LeNet / VGG-mini /
//!   tied-embedding GRU LM) AOT-lowered to HLO text artifacts that
//!   [`runtime`] loads and executes via PJRT. Python never runs at request
//!   time.
//! * **Layer 1 (build-time Pallas)** — the selective-masking top-k kernel,
//!   threshold-bisection formulation, baked into each model's `*_mask`
//!   artifact.
//!
//! See `DESIGN.md` for the architecture and substitution notes and
//! `EXPERIMENTS.md` for the paper-vs-measured record.

pub mod config;
pub mod data;
pub mod figures;
pub mod fl;
pub mod lint;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod transport;
pub mod util;

pub use util::error::{Error, Result};
