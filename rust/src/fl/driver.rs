//! The round state machine: **sample → broadcast → collect → finalize**.
//!
//! [`RoundDriver`] owns everything one federated round needs from the
//! communication plane — the [`Transport`] (with its per-client
//! authenticated sessions), the delta-downlink reference state, the cost
//! ledger, and the decode scratch — and exposes the round as four
//! explicitly-typed phases:
//!
//! 1. [`RoundDriver::sample`] → [`Cohort`] — the sampling schedule
//!    (Alg. 1/3) and ACK selection loop: which registered,
//!    session-holding clients participate, and which ACKed but straggle.
//! 2. [`RoundDriver::broadcast`] → [`RoundWire`] — encode the round's
//!    downlink (dense, or `w_t − w_{t-1}` through the codec under
//!    `downlink_delta`), **push it through the transport's downlink
//!    half** to every completer (so the broadcast genuinely crosses the
//!    wire — sockets included), bill every ACKer's download, and assert
//!    the reconstruction-fidelity bound.
//! 3. [`RoundDriver::collect`] → [`Collected`] — the streaming drain: a
//!    select-style wait over the pool-result channel and the wire,
//!    folding each upload into the aggregator the moment it lands
//!    ([`drain_round_uploads`]). With `agg_shards > 1`,
//!    [`RoundDriver::collect_sharded`] routes each header-validated
//!    payload to its client's shard-local fold instead
//!    ([`crate::fl::tree::ShardedAggregator`]) — bitwise-identical by
//!    the merge property, parallel in wall-clock.
//! 4. [`RoundDriver::finalize`] → [`RoundCost`] — uplink ledger
//!    accounting in deterministic client-id order.
//!
//! The driver is engine-free by construction: no phase touches PJRT, so
//! the whole cycle — including the delta-downlink reconstruction contract
//! and the dead-client regressions — is pinned by unit tests that drive
//! fake clients over real transports. [`crate::fl::server::Server`] is
//! the only production caller: it owns the engine pool, fans client jobs
//! out between `broadcast` and `collect`, and consumes the phase outputs
//! for the clock and the round record.
//!
//! Determinism: client selection derives from (seed, round); the
//! broadcast bytes are a pure function of the global model and config;
//! the fold is order-independent. The same config therefore reproduces
//! bit-identical rounds on every transport — the socket suite pins it.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::experiment::{ExperimentConfig, NetworkKind};
use crate::fl::aggregate::{Aggregator, Contribution, SparseContribution};
use crate::fl::chaos::{ChaosLog, ChaosTransport, DownlinkFate, FaultLog, FaultPlan, UploadFate};
use crate::fl::tree::ShardedAggregator;
use crate::runtime::bufpool::BufferPool;
use crate::sim::availability::{AvailabilityModel, ClientState};
use crate::sim::rng::Rng;
use crate::transport::codec::{
    decode_update, decode_update_view_cached, encode_update, peek_header, wire_bytes, BodyView,
    DecodeScratch, Encoding, WireView, BROADCAST_DELTA, BROADCAST_FULL, BROADCAST_SENDER,
};
use crate::transport::cost::CostLedger;
use crate::transport::link::{
    DownlinkSource, InProcess, Simulated, Transport, TransportKind, UploadSink,
    DEFAULT_UPLOAD_TIMEOUT,
};
use crate::transport::network::NetworkModel;
use crate::transport::session::IndexCache;
use crate::transport::socket::{Loopback, ServerTuning};
use crate::util::error::{Error, Result};

/// Sideband metadata one client job reports through the pool channel:
/// (train loss, nnz, encoded payload bytes).
pub type JobMeta = (f32, usize, usize);

/// Per-round budget of dropped invalid uploads. Under a socket transport
/// the listener is an open local port, so a stray peer could deliver a
/// well-framed message whose *payload* fails decode or cohort validation
/// (the session layer already rejects anything that fails token
/// verification); those cost the round nothing — but a garbage firehose
/// must not stall the aggregation loop forever.
const MAX_REJECTED_UPLOADS: usize = 64;

/// Where one round's validated uploads land: the single-threaded fold, or
/// the sharded tree ([`ShardedAggregator`]) that routes each payload —
/// still undecoded — to its client's shard worker. Header validation
/// (round, cohort membership, duplicates, width) is identical on both
/// paths and happens on the drain loop either way.
pub(crate) enum RoundFold<'a> {
    Serial(&'a mut dyn Aggregator),
    Sharded(&'a mut ShardedAggregator),
}

impl RoundFold<'_> {
    /// Uploads accepted so far (folded, or routed to a shard).
    fn completed(&self) -> usize {
        match self {
            RoundFold::Serial(agg) => agg.folded(),
            RoundFold::Sharded(tree) => tree.routed(),
        }
    }
}

/// Account one rejected (well-framed but invalid) upload, erroring once
/// the per-round budget is exhausted. On a closed wire (`tolerate` false —
/// in-process channels carry only our own cohort's payloads) an invalid
/// upload can only be an internal bug, so it fails the round precisely and
/// immediately instead of being dropped.
fn reject_upload(rejected: &mut usize, tolerate: bool, why: impl std::fmt::Display) -> Result<()> {
    if !tolerate {
        return Err(Error::invalid(format!("invalid upload: {why}")));
    }
    *rejected += 1;
    log::warn!("transport: dropping invalid upload ({why})");
    if *rejected > MAX_REJECTED_UPLOADS {
        return Err(Error::transport(format!(
            "dropped {rejected} invalid uploads this round; giving up"
        )));
    }
    Ok(())
}

/// Drain one round's uploads: a select-style wait over the **pool-result
/// channel** (job metadata / job errors) and the **wire** (encoded
/// payloads), folding each valid payload into `agg` the moment it lands.
///
/// The two streams are independent — a payload can beat its metadata and
/// vice versa — so the loop alternates: drain every ready pool result
/// (a failed client job surfaces its concrete error *here, immediately*,
/// instead of after the full upload timeout — the wire can never deliver
/// the payload a dead job didn't send), then wait at most `drain_poll`
/// (config: `drain_poll_ms`, default 25) for the next payload. Wire
/// arrivals are matched to the cohort by their own fixed header — peeked
/// without decoding the body ([`peek_header`]): selected client, current
/// round, model dimension, no duplicates; invalid ones are dropped on a
/// bounded budget when the transport `tolerate_strays`, and fail the
/// round precisely otherwise. A header-valid payload then folds serially
/// or is routed, body still encoded, to its shard worker per `fold`.
///
/// `upload_timeout` is an **inactivity** bound, matching the old per-recv
/// semantics: the window restarts whenever the round makes progress (a
/// payload folds or a job reports), so a large cohort legitimately
/// draining for longer than the timeout never trips it — only a round
/// where nothing happens for the whole window does.
///
/// What one round's drain produced: the per-job metadata in input
/// (client-id) order. Duplicate-frame billing deliberately does *not*
/// live here — whether the drain pulls a duplicate's second copy off
/// the wire before the round completes depends on arrival interleaving,
/// so the deterministic count comes from the chaos log instead
/// ([`ChaosLog::round_duplicates`]).
struct Drained {
    metas: Vec<JobMeta>,
    /// Per `selected` index: the sorted non-zero support of that client's
    /// *accepted* upload — the set the session's index cache advances to.
    /// Populated only when the drain ran with `caches` (the index-cache
    /// lifecycle is on); `None` for uploads that never folded, which is
    /// exactly what invalidates the client's cache.
    supports: Vec<Option<Vec<u32>>>,
}

/// Sorted non-zero support of a decoded upload — what a client's index
/// cache advances to after its fold is accepted. Sparse bodies carry it
/// verbatim; dense bodies are scanned (a stateless dense upload still
/// seeds the next round's cache).
fn support_of_view(view: &WireView<'_>) -> Vec<u32> {
    match view.body {
        BodyView::Sparse { indices, .. } => indices.to_vec(),
        BodyView::Dense(params) => params
            .iter()
            .enumerate()
            .filter(|(_, v)| **v != 0.0)
            .map(|(i, _)| i as u32)
            .collect(),
    }
}

/// Returns the per-job metadata in input (client-id) order once every job
/// reported and every expected upload folded. `expect_upload` (same
/// indexing as `selected`) marks which jobs' payloads will actually
/// reach the server — under fault injection a job may run and report
/// metadata while its upload is dropped, corrupted, or forged; the
/// drain must not wait for (or fold) those. `caches` (same indexing
/// again) carries each session's cross-round index cache when the
/// configured encoding uses one: uploads decode against their client's
/// cache, and the accepted supports come back in [`Drained::supports`]
/// for the driver's post-round cache refresh; `None` disables both. Free
/// function by design: it needs no engine, so the dead-client regression
/// tests drive it directly with hand-built channels and transports.
///
/// `pool`: the shared payload-frame [`BufferPool`] to return serially
/// folded payloads to once the fold has consumed them — the downstream
/// half of the zero-allocation encode loop (workers `take` before
/// encoding). `None` (tests, poolless callers) simply drops frames as
/// before. Sharded rounds never return frames: the payload's ownership
/// moves into the shard worker's channel (see `fl::tree`), and recycling
/// is an optimization the pool contract says we may skip.
#[allow(clippy::too_many_arguments)] // round context; precedent: data/synth.rs
fn drain_round_uploads(
    transport: &mut dyn Transport,
    results: &Receiver<(usize, Result<JobMeta>)>,
    fold: &mut RoundFold<'_>,
    scratch: &mut DecodeScratch,
    selected: &[usize],
    expect_upload: &[bool],
    caches: Option<&[Option<Arc<IndexCache>>]>,
    round: usize,
    p: usize,
    tolerate_strays: bool,
    upload_timeout: Duration,
    drain_poll: Duration,
    pool: Option<&BufferPool>,
) -> Result<Drained> {
    let n_jobs = selected.len();
    debug_assert_eq!(expect_upload.len(), n_jobs);
    debug_assert!(caches.map_or(true, |c| c.len() == n_jobs));
    let mut metas: Vec<Option<JobMeta>> = vec![None; n_jobs];
    let mut supports: Vec<Option<Vec<u32>>> = vec![None; n_jobs];
    let mut uploaded = vec![false; n_jobs];
    let mut metas_pending = n_jobs;
    let mut folds_pending = expect_upload.iter().filter(|e| **e).count();
    let mut rejected = 0usize;
    let mut results_open = true;
    // Inactivity deadline: pushed forward on every piece of progress.
    let mut deadline = Instant::now() + upload_timeout;

    while metas_pending > 0 || folds_pending > 0 {
        // 1) Surface every ready job result without blocking. `res?` is the
        //    headline path: a client job that died reports its concrete
        //    error here on the next poll tick.
        while results_open && metas_pending > 0 {
            match results.try_recv() {
                Ok((idx, res)) => {
                    metas[idx] = Some(res?);
                    metas_pending -= 1;
                    deadline = Instant::now() + upload_timeout;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => results_open = false,
            }
        }
        if !results_open && metas_pending > 0 {
            // Every sender is gone but some job never reported: its worker
            // thread died (e.g. a panicking client) — fail now; the wire
            // will never deliver its upload.
            return Err(Error::Engine("worker dropped job (thread died?)".into()));
        }
        if folds_pending == 0 {
            // All payloads folded; only metadata is outstanding. Block on
            // the result channel directly (bounded by the round deadline).
            let window = deadline
                .checked_duration_since(Instant::now())
                .filter(|w| !w.is_zero())
                .ok_or_else(|| {
                    Error::transport(format!(
                        "timed out after {upload_timeout:?} waiting for job results"
                    ))
                })?;
            match results.recv_timeout(window.min(drain_poll)) {
                Ok((idx, res)) => {
                    metas[idx] = Some(res?);
                    metas_pending -= 1;
                    deadline = Instant::now() + upload_timeout;
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => results_open = false,
            }
            continue;
        }

        // 2) Bounded wait for the next wire payload.
        let window = deadline
            .checked_duration_since(Instant::now())
            .filter(|w| !w.is_zero())
            .ok_or_else(|| {
                let missing: Vec<usize> = selected
                    .iter()
                    .zip(expect_upload)
                    .zip(&uploaded)
                    .filter(|((_, exp), up)| **exp && !**up)
                    .map(|((c, _), _)| *c)
                    .collect();
                Error::transport(format!(
                    "timed out after {upload_timeout:?} waiting for uploads from clients {missing:?}"
                ))
            })?;
        let Some(payload) = transport.try_recv_for(window.min(drain_poll))? else {
            continue;
        };

        // 3) Header-validate + fold/route. Cohort matching reads only the
        //    fixed header (no body decode), so it is identical — and
        //    identically cheap — on the serial and sharded paths. Invalid
        //    payloads are dropped on a bounded budget; fold and route
        //    failures stay fatal (a fold error can leave the accumulator
        //    partially updated, and our own cohort's payloads are
        //    codec-clean).
        let Some(header) = peek_header(&payload) else {
            reject_upload(&mut rejected, tolerate_strays, "unparseable update header")?;
            continue;
        };
        if header.round as usize != round {
            reject_upload(
                &mut rejected,
                tolerate_strays,
                format_args!(
                    "client {} names round {}, server is on round {round}",
                    header.client, header.round
                ),
            )?;
            continue;
        }
        let pos = match selected.binary_search(&(header.client as usize)) {
            Ok(pos) => pos,
            Err(_) => {
                reject_upload(
                    &mut rejected,
                    tolerate_strays,
                    format_args!("client {} not in this round's cohort", header.client),
                )?;
                continue;
            }
        };
        if uploaded[pos] {
            // The repeated frame is real uplink traffic, but it is billed
            // from the chaos log at injection time (`Collected::dup_bytes`)
            // — here it only has to be kept out of the fold.
            reject_upload(
                &mut rejected,
                tolerate_strays,
                format_args!("duplicate update from client {}", header.client),
            )?;
            continue;
        }
        if header.p as usize != p {
            reject_upload(
                &mut rejected,
                tolerate_strays,
                format_args!("carries {} params, model has {}", header.p, p),
            )?;
            continue;
        }
        if !expect_upload[pos] {
            // Fault injection declared this client's upload lost or
            // mangled; anything that still lands under its id (e.g. a
            // truncation that kept the fixed header intact) is rejected
            // *before* the fold — the recovery contract for corrupt and
            // Byzantine payloads.
            reject_upload(
                &mut rejected,
                tolerate_strays,
                format_args!("upload from client {} suppressed by fault injection", header.client),
            )?;
            continue;
        }
        let cache = caches.and_then(|cs| cs[pos].clone());
        match fold {
            RoundFold::Serial(agg) => {
                // Serial: decode here, so a corrupt *body* on an open wire
                // is still a rejectable stray rather than a round failure.
                let update = match decode_update_view_cached(&payload, scratch, cache.as_deref()) {
                    Ok(u) => u,
                    Err(e) => {
                        reject_upload(&mut rejected, tolerate_strays, e)?;
                        if let Some(pool) = pool {
                            pool.put(payload);
                        }
                        continue;
                    }
                };
                if caches.is_some() {
                    supports[pos] = Some(support_of_view(&update));
                }
                let client = update.client as usize;
                match update.body {
                    BodyView::Dense(params) => agg.fold(Contribution {
                        client,
                        params,
                        n_samples: update.n_samples,
                    })?,
                    BodyView::Sparse { indices, values } => agg.fold_sparse(SparseContribution {
                        client,
                        p: update.p,
                        indices,
                        values,
                        n_samples: update.n_samples,
                    })?,
                }
                // Fold consumed the frame (views may borrow it, so only
                // now): recycle it to the encode side.
                if let Some(pool) = pool {
                    pool.put(payload);
                }
            }
            // Sharded: ship the body encoded (plus the session's cache);
            // the shard worker decodes on its own thread. A corrupt body
            // past this point fails the round (see `fl::tree` on why that
            // trade is deliberate) — including the extra drain-loop decode
            // below, which only exists to learn the accepted support for
            // the cache refresh without a result channel back from the
            // workers, and follows the same fatal-error policy.
            RoundFold::Sharded(tree) => {
                if caches.is_some() {
                    let update = decode_update_view_cached(&payload, scratch, cache.as_deref())?;
                    supports[pos] = Some(support_of_view(&update));
                }
                tree.route(header.client, payload, cache)?;
            }
        }
        uploaded[pos] = true;
        folds_pending -= 1;
        deadline = Instant::now() + upload_timeout;
    }
    debug_assert_eq!(fold.completed(), expect_upload.iter().filter(|e| **e).count());
    Ok(Drained {
        metas: metas.into_iter().map(|m| m.expect("all jobs accounted")).collect(),
        supports,
    })
}

// ---------------------------------------------------------------------
// Phase types
// ---------------------------------------------------------------------

/// Output of the **sample** phase: who participates in round `round`.
#[derive(Debug, Clone)]
pub struct Cohort {
    /// 1-based round this cohort was drawn for.
    pub round: usize,
    /// The schedule's sampling rate at this round (for the record).
    pub rate: f64,
    /// Clients that ACKed and will complete — sorted, deduplicate-free;
    /// the aggregation loop binary-searches it.
    pub selected: Vec<usize>,
    /// Clients that ACKed (and are billed the broadcast) but miss the
    /// round deadline; sorted.
    pub stragglers: Vec<usize>,
}

/// Output of the **broadcast** phase: the canonical model state clients
/// received, plus what it cost.
pub struct RoundWire {
    /// The model as clients materialize it this round — identical bitwise
    /// to every client's [`crate::fl::client::receive_broadcast`] result,
    /// and the reference the aggregator reconstructs mask targets
    /// against. (Under `downlink_delta` this is the *reconstructed*
    /// broadcast, which may differ from the true global model within the
    /// codec's quantizer half-step; dense broadcasts are bit-exact.)
    pub params: Arc<Vec<f32>>,
    /// Per selected client (same order as `Cohort::selected`): the
    /// previous-broadcast reference that client holds — `Some` iff its
    /// downlink this round is a delta it must reconstruct against.
    pub references: Vec<Option<Arc<Vec<f32>>>>,
    /// Max |reconstructed − global| this round (0.0 for dense) — the
    /// delta-downlink fidelity evidence, asserted against the quantizer
    /// half-step bound before any client trains on it.
    pub recon_err: f64,
    /// Largest single download billed this round (drives the virtual
    /// clock's downlink term).
    pub slowest_download: usize,
    /// Per selected client (same order as `Cohort::selected`): should
    /// the caller spawn this client's training job? `false` only when
    /// fault injection disconnected the client's downlink mid-broadcast
    /// — it never received `w_t`, so it has nothing to train on. All
    /// `true` when the chaos harness is off.
    pub spawn: Vec<bool>,
    /// Per selected client (same order as `Cohort::selected`): the
    /// session's cross-round index cache to encode this round's upload
    /// against — the identical `Arc` the server will decode with, so the
    /// two ends cannot disagree. `None` (and all-`None` whenever the
    /// configured encoding does not use the cache) means a stateless
    /// full-index send.
    pub index_caches: Vec<Option<Arc<IndexCache>>>,
}

/// Output of the **collect** phase: every upload folded, every job
/// accounted.
pub struct Collected {
    /// Per-job metadata in input (client-id) order, spawned jobs only.
    pub metas: Vec<JobMeta>,
    /// Chaos-injected duplicate frames this round, counted at injection
    /// time — the client's radio sent them whether or not the drain
    /// happened to pull the redundant copy before the round completed.
    /// Billed as redundant traffic (bytes and messages, never units).
    pub dup_frames: u64,
    /// Bytes those redundant frames carried.
    pub dup_bytes: u64,
}

/// The driver's pre-round reading of the fault plan: because injection
/// is a pure function of (chaos seed, round, client), the driver can
/// predict — before any byte moves — which jobs to spawn, how many wire
/// deliveries the round produces, and which uploads will survive to
/// fold. The simulated network's cohort barrier and the drain's
/// completion condition both key off this, keeping rounds deterministic
/// under injected loss.
struct ChaosOutlook {
    /// Per `Cohort::selected` index: spawn this client's job?
    spawn: Vec<bool>,
    /// The spawned clients (sorted subset of `Cohort::selected`) — the
    /// id list the drain validates arrivals against.
    spawned: Vec<usize>,
    /// Per `spawned` index: will this client's upload reach the fold
    /// intact (delivered or duplicated), or is it lost/mangled/forged?
    expect: Vec<bool>,
    /// Wire deliveries the transport should expect this round (counts
    /// duplicates twice, drops zero times).
    deliveries: usize,
}

/// Output of the **finalize** phase: the round's uplink accounting.
pub struct RoundCost {
    /// Sum of the cohort's training losses (caller divides by cohort size).
    pub loss_sum: f64,
    /// Encoded upload bytes per client, in client-id order (drives the
    /// virtual clock's uplink term).
    pub upload_sizes: Vec<usize>,
}

// ---------------------------------------------------------------------
// The driver
// ---------------------------------------------------------------------

/// Cross-round communication state + the four-phase round cycle. See the
/// module docs for the phase walk-through.
pub struct RoundDriver {
    cfg: Arc<ExperimentConfig>,
    p: usize,
    /// The wire both directions travel: in-process channels, persistent
    /// authenticated TCP/UDS sessions, or either wrapped in
    /// `NetworkModel`-timed delivery. Held for the driver's lifetime
    /// (socket listeners bind once, sessions persist across rounds).
    transport: Box<dyn Transport>,
    /// The full fleet of client ids eligible for this run — the sampling
    /// universe. Sessions are opened lazily per cohort, not here.
    registered: Vec<u32>,
    /// Which ids have had `register_clients` run (on sockets: hold a live
    /// session). Grows monotonically as cohorts touch new clients.
    connected: Vec<bool>,
    /// The model clients received last round — the delta-downlink
    /// reference (None before the first broadcast or when
    /// `downlink_delta` is off).
    prev_broadcast: Option<Arc<Vec<f32>>>,
    /// Which clients received the **previous round's** broadcast (rebuilt
    /// every round — the delta is `w_t - w_{t-1}`, so a client that sat
    /// out round t-1 holds stale state, cannot apply it, and is sent a
    /// dense catch-up transfer instead).
    has_prev_broadcast: Vec<bool>,
    /// Per-client cross-round index cache (wire v3 `SparseCached`): the
    /// support of each client's last **accepted** upload, epoch-stamped.
    /// Snapshotted into [`RoundWire::index_caches`] at broadcast so the
    /// client encodes and the server decodes against the same `Arc`;
    /// advanced by [`RoundDriver::refresh_index_caches`] only when the
    /// round's upload folded, and dropped on any skip, drop, disconnect,
    /// or mangle — the invalidation rule that makes a desynced delta
    /// impossible. All `None` unless `cfg.encoding.uses_index_cache()`.
    index_caches: Vec<Option<Arc<IndexCache>>>,
    ledger: CostLedger,
    /// The fault-injection plan and its event log, when the chaos
    /// harness is configured (`cfg.chaos` with any fault enabled). The
    /// plan predicts per-round outcomes ([`ChaosOutlook`]); the log is
    /// shared with the [`ChaosTransport`] layer and drained per round
    /// into the [`FaultLog`] the round record carries.
    chaos: Option<(Arc<FaultPlan>, Arc<ChaosLog>)>,
    /// Reusable decode buffers for the streaming aggregation loop — held
    /// across rounds so steady-state decoding never allocates.
    decode_scratch: DecodeScratch,
    upload_timeout: Duration,
    /// Drain-loop poll granularity (config `drain_poll_ms`).
    drain_poll: Duration,
    /// The engine pool's shared payload-frame pool, when the server
    /// attached one: serially folded payloads are `put` back here so the
    /// encode side can `take` them next round — closing the
    /// zero-allocation loop. `None` (engine-free tests) keeps the old
    /// drop-after-fold behavior.
    buffer_pool: Option<Arc<BufferPool>>,
}

impl RoundDriver {
    /// Build the communication plane for a run: construct the configured
    /// transport. Client ids `0..cfg.clients` form the sampling universe,
    /// but **registration is lazy**: a client's session (on sockets: its
    /// persistent duplex connection + token handshake) is opened the
    /// first round it is selected, by [`RoundDriver::broadcast`]. Under
    /// the dynamic schedules most of a large fleet is never sampled, so
    /// the old eager full-registry connect paid thousands of handshakes
    /// for sessions no round used.
    pub fn new(cfg: Arc<ExperimentConfig>, p: usize) -> Result<RoundDriver> {
        let base: Box<dyn Transport> = match cfg.transport {
            TransportKind::InProcess => Box::new(InProcess::new()),
            TransportKind::Tcp | TransportKind::Uds => {
                let tuning = ServerTuning { max_conns: cfg.max_conns, ..ServerTuning::default() };
                Box::new(Loopback::bind_with(cfg.transport, tuning)?)
            }
        };
        // Fault injection sits directly on the base wire, *inside* the
        // simulated network: the Simulated layer then times and barriers
        // on post-chaos deliveries, so its expected-arrival count matches
        // what actually crosses the (faulty) wire.
        let chaos = RoundDriver::chaos_parts(&cfg);
        let wired: Box<dyn Transport> = match &chaos {
            Some((plan, log)) => {
                Box::new(ChaosTransport::new(base, Arc::clone(plan), Arc::clone(log)))
            }
            None => base,
        };
        let transport: Box<dyn Transport> = match cfg.network {
            NetworkKind::Ideal => wired,
            NetworkKind::Simulated => Box::new(Simulated::with_compute(
                wired,
                NetworkModel::default(),
                cfg.availability(),
                cfg.local_epochs,
            )),
        };
        RoundDriver::assemble(cfg, p, transport, chaos)
    }

    /// The configured fault plan, when any fault is actually enabled —
    /// an all-zero plan is equivalent to no plan and costs nothing.
    fn chaos_parts(cfg: &ExperimentConfig) -> Option<(Arc<FaultPlan>, Arc<ChaosLog>)> {
        cfg.chaos
            .as_ref()
            .filter(|plan| plan.is_active())
            .map(|plan| (Arc::new(plan.clone()), Arc::new(ChaosLog::default())))
    }

    /// Driver over a caller-built transport (tests wire in short-timeout
    /// or pre-wrapped transports). If the config carries an active fault
    /// plan the caller's transport is wrapped in a [`ChaosTransport`];
    /// no sessions are opened yet — see [`RoundDriver::new`] on lazy
    /// registration.
    pub fn with_transport(
        cfg: Arc<ExperimentConfig>,
        p: usize,
        transport: Box<dyn Transport>,
    ) -> Result<RoundDriver> {
        let chaos = RoundDriver::chaos_parts(&cfg);
        let transport: Box<dyn Transport> = match &chaos {
            Some((plan, log)) => {
                Box::new(ChaosTransport::new(transport, Arc::clone(plan), Arc::clone(log)))
            }
            None => transport,
        };
        RoundDriver::assemble(cfg, p, transport, chaos)
    }

    fn assemble(
        cfg: Arc<ExperimentConfig>,
        p: usize,
        transport: Box<dyn Transport>,
        chaos: Option<(Arc<FaultPlan>, Arc<ChaosLog>)>,
    ) -> Result<RoundDriver> {
        let registered: Vec<u32> = (0..cfg.clients as u32).collect();
        log::debug!(
            "[{}] full-duplex rounds travel via {} ({} clients eligible, sessions lazy)",
            cfg.label,
            transport.label(),
            registered.len()
        );
        let clients = cfg.clients;
        let drain_poll = Duration::from_millis(cfg.drain_poll_ms);
        Ok(RoundDriver {
            cfg,
            p,
            transport,
            registered,
            connected: vec![false; clients],
            prev_broadcast: None,
            has_prev_broadcast: vec![false; clients],
            index_caches: vec![None; clients],
            ledger: CostLedger::new(),
            chaos,
            decode_scratch: DecodeScratch::default(),
            upload_timeout: DEFAULT_UPLOAD_TIMEOUT,
            drain_poll,
            buffer_pool: None,
        })
    }

    /// The sampling universe: every client id eligible for this run.
    pub fn registered(&self) -> &[u32] {
        &self.registered
    }

    /// How many clients hold registrations (on sockets: live sessions) so
    /// far — grows lazily as cohorts touch new clients.
    pub fn connected_clients(&self) -> usize {
        self.connected.iter().filter(|c| **c).count()
    }

    /// Transport name for logs.
    pub fn transport_label(&self) -> &'static str {
        self.transport.label()
    }

    /// Running cost totals.
    pub fn ledger(&self) -> &CostLedger {
        &self.ledger
    }

    /// Override the collect phase's inactivity timeout (tests).
    pub fn set_upload_timeout(&mut self, timeout: Duration) {
        self.upload_timeout = timeout;
    }

    /// Attach the engine pool's shared payload-frame pool
    /// ([`crate::runtime::pool::EnginePool::buffer_pool`]): serially
    /// folded payloads return to it after the fold consumes them, so
    /// workers' next-round encodes reuse the frames instead of
    /// allocating. Purely an optimization — correctness is identical
    /// with or without it.
    pub fn attach_buffer_pool(&mut self, pool: Arc<BufferPool>) {
        self.buffer_pool = Some(pool);
    }

    /// Upload sink client jobs push their encoded payloads through.
    pub fn sink(&self) -> Arc<dyn UploadSink> {
        self.transport.sink()
    }

    /// Downlink handle client jobs receive their broadcast through.
    pub fn downlink(&self) -> Arc<dyn DownlinkSource> {
        self.transport.downlink()
    }

    /// Pre-compute this round's fault outcomes for `cohort`: which jobs
    /// to spawn, how many wire deliveries to expect, which uploads will
    /// survive to fold. Identity (all spawn, all expected) when the
    /// chaos harness is off. Pure — `broadcast` and `collect` call it
    /// independently and read the same schedule.
    fn chaos_outlook(&self, cohort: &Cohort) -> ChaosOutlook {
        let k = cohort.selected.len();
        let Some((plan, _)) = &self.chaos else {
            return ChaosOutlook {
                spawn: vec![true; k],
                spawned: cohort.selected.clone(),
                expect: vec![true; k],
                deliveries: k,
            };
        };
        let t = cohort.round as u32;
        let mut spawn = Vec::with_capacity(k);
        let mut spawned = Vec::with_capacity(k);
        let mut expect = Vec::with_capacity(k);
        let mut deliveries = 0usize;
        for &c in &cohort.selected {
            if plan.downlink_fate(t, c as u32) == DownlinkFate::Disconnect {
                // Never received the broadcast: no job, no upload.
                spawn.push(false);
                continue;
            }
            spawn.push(true);
            let fate = plan.upload_fate(t, c as u32);
            deliveries += plan.deliveries(fate);
            spawned.push(c);
            expect.push(matches!(fate, UploadFate::Deliver | UploadFate::Duplicate));
        }
        ChaosOutlook { spawn, spawned, expect, deliveries }
    }

    /// Drain the fault events the chaos layer logged for round `t`, in
    /// canonical (client, kind) order — empty when the harness is off.
    /// The server folds this into the round record.
    pub fn take_fault_log(&self, t: usize) -> FaultLog {
        self.chaos
            .as_ref()
            .map(|(_, log)| log.take_round(t as u32))
            .unwrap_or_default()
    }

    /// **Phase 1 — sample.** ACK selection loop (Alg. 1/3 lines 9–14):
    /// compute the schedule's target cohort size for round `t`, then walk
    /// a seeded permutation of the registry, requesting connections until
    /// `want` clients ACK. Completers finish the round; stragglers ACKed
    /// (and therefore receive the broadcast, paying downlink) but miss
    /// the deadline and are dropped before aggregation. Both lists sorted
    /// for deterministic aggregation order. Every sampled client is by
    /// construction a member of the eligible fleet; completers that do
    /// not yet hold a session get one at `broadcast`.
    pub fn sample(&self, availability: &AvailabilityModel, t: usize) -> Cohort {
        let rate = self.cfg.sampling.rate(t);
        let want = self.cfg.sampling.num_clients(t, self.cfg.clients, self.cfg.min_clients);
        let mut order: Vec<usize> = (0..self.cfg.clients).collect();
        let mut rng = Rng::new(self.cfg.seed).fork(t as u64).fork(0x5e1);
        rng.shuffle(&mut order);
        let mut completers = Vec::with_capacity(want);
        let mut stragglers = Vec::new();
        for &c in &order {
            if completers.len() + stragglers.len() >= want {
                break;
            }
            match availability.state(t as u64, c as u64) {
                ClientState::Available => completers.push(c),
                ClientState::Straggler => stragglers.push(c),
                ClientState::Offline => {}
            }
        }
        if completers.is_empty() {
            // Degenerate availability: fall back to the first candidate so a
            // run cannot deadlock (logged; the paper assumes full ACK).
            log::warn!("round {t}: no client completed; forcing client {}", order[0]);
            completers.push(order[0]);
            stragglers.retain(|&c| c != order[0]);
        }
        completers.sort_unstable();
        stragglers.sort_unstable();
        debug_assert!(completers
            .iter()
            .chain(&stragglers)
            .all(|&c| self.registered.binary_search(&(c as u32)).is_ok()));
        Cohort {
            round: t,
            rate,
            selected: completers,
            stragglers,
        }
    }

    /// **Phase 2 — broadcast.** Encode this round's downlink and push it
    /// through the transport to every completer, so the broadcast bytes
    /// genuinely cross the wire (the send only enqueues; the socket
    /// transport writes from its own thread, and jobs fanned out after
    /// this call drain it — no deadlock however small the kernel buffer).
    ///
    /// Default: one dense message, clients decode the global model
    /// verbatim (bit-exact). With `downlink_delta`: rounds after the
    /// first ship `w_t − w_{t-1}` through the configured encoding to
    /// every client that holds the previous broadcast, and a dense
    /// catch-up of the canonical reconstructed state to everyone else;
    /// clients reconstruct `w_{t-1} + delta`. The server performs the
    /// identical decode to maintain the canonical fleet state, asserts
    /// the reconstruction error against the codec's quantizer half-step,
    /// and hands the result to the aggregator as the round's reference.
    ///
    /// Stragglers are *billed* their download (the bytes were spent even
    /// though their update misses the deadline) but no wire message is
    /// queued for them — no job of theirs will drain it, and an unread
    /// frame would corrupt their next active round.
    pub fn broadcast(&mut self, params: &Arc<Vec<f32>>, cohort: &Cohort) -> Result<RoundWire> {
        let t = cohort.round;
        // Lazy per-cohort registration: open sessions only for this
        // round's completers that do not hold one yet (stragglers get no
        // wire message, so they need no session to be billed). On sockets
        // this is the connect + token handshake; it is idempotent at the
        // driver level via `connected`.
        let to_connect: Vec<u32> = cohort
            .selected
            .iter()
            .map(|&c| c as u32)
            .filter(|&c| !self.connected[c as usize])
            .collect();
        if !to_connect.is_empty() {
            self.transport.register_clients(&to_connect)?;
            for &c in &to_connect {
                self.connected[c as usize] = true;
            }
        }
        // Under fault injection the wire will see a *predictable* number
        // of deliveries that differs from the cohort size (drops subtract,
        // duplicates add): the transport's round barrier must count what
        // actually arrives.
        let outlook = self.chaos_outlook(cohort);
        self.transport.begin_round(outlook.deliveries);

        // --- canonical state + the (at most two) distinct messages ---
        let prev = if self.cfg.downlink_delta { self.prev_broadcast.clone() } else { None };
        let (received, delta_wire, delta_nnz, recon_err) = match &prev {
            Some(prev_params) => {
                let delta: Vec<f32> = params
                    .iter()
                    .zip(prev_params.iter())
                    .map(|(new, old)| new - old)
                    .collect();
                let nnz = delta.iter().filter(|v| **v != 0.0).count();
                let wire = Arc::new(encode_update(
                    BROADCAST_SENDER,
                    t as u32,
                    BROADCAST_DELTA,
                    &delta,
                    self.cfg.encoding,
                ));
                let decoded = decode_update(&wire)?.into_dense();
                let received: Vec<f32> = decoded
                    .iter()
                    .zip(prev_params.iter())
                    .map(|(d, old)| old + d)
                    .collect();
                // Fidelity check: the reconstructed broadcast may differ
                // from the true global model by (a) the codec's quantizer
                // half-step (zero for lossless encodings) and (b) f32
                // rounding of `old + d`. Anything beyond that bound is a
                // codec-contract violation and must fail loudly rather
                // than silently training the fleet on a drifted model.
                let recon_err = received
                    .iter()
                    .zip(params.iter())
                    .map(|(r, w)| (r - w).abs() as f64)
                    .fold(0.0f64, f64::max);
                let (lo, hi) = delta
                    .iter()
                    .fold((f32::INFINITY, f32::NEG_INFINITY), |(lo, hi), &d| {
                        (lo.min(d), hi.max(d))
                    });
                let half_step = if nnz == 0 {
                    0.0
                } else {
                    self.cfg.encoding.lossy_half_step(lo, hi) as f64
                };
                let max_abs = params.iter().map(|w| w.abs()).fold(0.0f32, f32::max) as f64;
                let bound = half_step + 1e-5 * (1.0 + max_abs);
                if recon_err > bound {
                    return Err(Error::invalid(format!(
                        "round {t}: downlink delta reconstruction error {recon_err:.3e} exceeds \
                         the quantizer half-step bound {bound:.3e} ({})",
                        self.cfg.encoding.as_str()
                    )));
                }
                (Arc::new(received), Some(wire), nnz, recon_err)
            }
            // No delta reference (first broadcast, or delta mode off):
            // the dense f32 wire is bit-exact, so the canonical received
            // state IS the global model and reconstruction error is 0.
            None => (Arc::clone(params), None, self.p, 0.0f64),
        };

        // --- billing (every ACKer) + wire pushes (completers only) ---
        let dense_bytes = wire_bytes(self.p, self.p, Encoding::Dense);
        let delta_bytes = delta_wire.as_ref().map_or(dense_bytes, |w| w.len());
        let mut slowest_download = 0usize;
        let mut next_recipients = vec![false; self.cfg.clients];
        for &c in cohort.selected.iter().chain(&cohort.stragglers) {
            let (nnz, bytes) = if delta_wire.is_some() && self.has_prev_broadcast[c] {
                (delta_nnz, delta_bytes)
            } else {
                (self.p, dense_bytes)
            };
            self.ledger.record_download_sparse(self.p, nnz, bytes);
            slowest_download = slowest_download.max(bytes);
            next_recipients[c] = true;
        }
        let mut full_wire: Option<Arc<Vec<u8>>> = None;
        let mut references = Vec::with_capacity(cohort.selected.len());
        for &c in &cohort.selected {
            if delta_wire.is_some() && self.has_prev_broadcast[c] {
                // Arc-shared: the cohort-wide fan-out costs one encode,
                // not one copy per client.
                let wire = Arc::clone(delta_wire.as_ref().expect("delta wire present"));
                self.transport.send_downlink(c as u32, wire)?;
                references.push(Some(Arc::clone(prev.as_ref().expect("delta implies prev"))));
            } else {
                // Catch-up / default path: the full canonical state,
                // dense (bit-exact). Built once, lazily — a steady-state
                // delta round with no catch-ups never encodes it.
                let wire = Arc::clone(full_wire.get_or_insert_with(|| {
                    Arc::new(encode_update(
                        BROADCAST_SENDER,
                        t as u32,
                        BROADCAST_FULL,
                        &received,
                        Encoding::Dense,
                    ))
                }));
                debug_assert_eq!(wire.len(), dense_bytes, "dense wire_bytes is exact");
                self.transport.send_downlink(c as u32, wire)?;
                references.push(None);
            }
        }
        // Only this round's recipients hold w_t; everyone else goes stale
        // and pays dense next time they are sampled. A client whose
        // downlink the fault plan disconnected mid-broadcast paid for the
        // bytes but never materialized w_t — it must get a dense catch-up
        // next round, not a delta it cannot apply.
        for (i, &c) in cohort.selected.iter().enumerate() {
            if !outlook.spawn[i] {
                next_recipients[c] = false;
            }
        }
        self.has_prev_broadcast = next_recipients;
        if self.cfg.downlink_delta {
            self.prev_broadcast = Some(Arc::clone(&received));
        }
        if !cohort.stragglers.is_empty() {
            log::debug!(
                "round {t}: {} stragglers dropped past deadline",
                cohort.stragglers.len()
            );
        }
        // Snapshot the cohort's index caches for this round: the client
        // job encodes its upload against exactly this Arc, and collect's
        // drain decodes against it — taken before any upload can move, so
        // both ends of the session see one consistent epoch.
        let cache_on = self.cfg.encoding.uses_index_cache();
        let index_caches = cohort
            .selected
            .iter()
            .map(|&c| if cache_on { self.index_caches[c].clone() } else { None })
            .collect();
        Ok(RoundWire {
            params: received,
            references,
            recon_err,
            slowest_download,
            spawn: outlook.spawn,
            index_caches,
        })
    }

    /// The cohort's cache slice in `spawned` order for the drain, or
    /// `None` when the configured encoding never touches the cache.
    fn drain_caches(&self, spawned: &[usize]) -> Option<Vec<Option<Arc<IndexCache>>>> {
        if !self.cfg.encoding.uses_index_cache() {
            return None;
        }
        Some(spawned.iter().map(|&c| self.index_caches[c].clone()).collect())
    }

    /// Post-collect cache refresh: every client's cache is dropped unless
    /// its upload folded this round, in which case it advances to the
    /// accepted support (a first-generation cache if the client had
    /// none). A client that sat the round out, straggled, or lost its
    /// upload to a fault therefore sends a full index set next time —
    /// invalidation is the default, staying in sync is the exception
    /// that must be earned by an accepted fold. No-op when the encoding
    /// does not use the cache.
    fn refresh_index_caches(&mut self, spawned: &[usize], mut supports: Vec<Option<Vec<u32>>>) {
        if !self.cfg.encoding.uses_index_cache() {
            return;
        }
        let mut next: Vec<Option<Arc<IndexCache>>> = vec![None; self.cfg.clients];
        for (i, &c) in spawned.iter().enumerate() {
            if let Some(support) = supports[i].take() {
                let cache = match self.index_caches[c].as_deref() {
                    Some(prev) => prev.advance(support),
                    None => IndexCache::first(support),
                };
                next[c] = Some(Arc::new(cache));
            }
        }
        self.index_caches = next;
    }

    /// **Phase 3 — collect.** Stream the cohort's uploads off the wire
    /// into `agg` while surfacing job errors within a poll tick — see
    /// [`drain_round_uploads`] for the full contract.
    pub fn collect(
        &mut self,
        cohort: &Cohort,
        agg: &mut dyn Aggregator,
        results: &Receiver<(usize, Result<JobMeta>)>,
    ) -> Result<Collected> {
        let outlook = self.chaos_outlook(cohort);
        if !outlook.expect.iter().any(|e| *e) {
            return Err(Error::transport(format!(
                "round {}: fault injection left no honest upload to aggregate",
                cohort.round
            )));
        }
        let tolerate_strays = self.transport.accepts_foreign_peers();
        let caches = self.drain_caches(&outlook.spawned);
        let drained = drain_round_uploads(
            self.transport.as_mut(),
            results,
            &mut RoundFold::Serial(agg),
            &mut self.decode_scratch,
            &outlook.spawned,
            &outlook.expect,
            caches.as_deref(),
            cohort.round,
            self.p,
            tolerate_strays,
            self.upload_timeout,
            self.drain_poll,
            self.buffer_pool.as_deref(),
        )?;
        self.refresh_index_caches(&outlook.spawned, drained.supports);
        let (dup_frames, dup_bytes) = self.round_duplicates(cohort.round);
        Ok(Collected { metas: drained.metas, dup_frames, dup_bytes })
    }

    /// **Phase 3, sharded.** Same drain contract as
    /// [`RoundDriver::collect`], but each header-validated payload is
    /// routed — body still encoded — to its client's shard-local fold in
    /// `tree`. The caller finishes the round with
    /// [`ShardedAggregator::finish`], which merges the shard partials
    /// bitwise-exactly; the result is bit-identical to the serial path
    /// (pinned by tests here and the merge property tests).
    pub fn collect_sharded(
        &mut self,
        cohort: &Cohort,
        tree: &mut ShardedAggregator,
        results: &Receiver<(usize, Result<JobMeta>)>,
    ) -> Result<Collected> {
        let outlook = self.chaos_outlook(cohort);
        if !outlook.expect.iter().any(|e| *e) {
            return Err(Error::transport(format!(
                "round {}: fault injection left no honest upload to aggregate",
                cohort.round
            )));
        }
        let tolerate_strays = self.transport.accepts_foreign_peers();
        let caches = self.drain_caches(&outlook.spawned);
        let drained = drain_round_uploads(
            self.transport.as_mut(),
            results,
            &mut RoundFold::Sharded(tree),
            &mut self.decode_scratch,
            &outlook.spawned,
            &outlook.expect,
            caches.as_deref(),
            cohort.round,
            self.p,
            tolerate_strays,
            self.upload_timeout,
            self.drain_poll,
            None, // sharded routing moves payload ownership to the workers
        )?;
        self.refresh_index_caches(&outlook.spawned, drained.supports);
        let (dup_frames, dup_bytes) = self.round_duplicates(cohort.round);
        Ok(Collected { metas: drained.metas, dup_frames, dup_bytes })
    }

    /// Injection-time duplicate accounting for `round`, read off the
    /// chaos log (see [`ChaosLog::round_duplicates`] for why the drain's
    /// own observation would be rerun-dependent). `(0, 0)` when the
    /// harness is off.
    fn round_duplicates(&self, round: usize) -> (u64, u64) {
        self.chaos
            .as_ref()
            .map(|(_, log)| log.round_duplicates(round as u32))
            .unwrap_or((0, 0))
    }

    /// **Phase 4 — finalize.** Uplink ledger accounting in deterministic
    /// client-id order; returns the sums the caller's clock and record
    /// need.
    pub fn finalize(&mut self, collected: &Collected) -> RoundCost {
        let mut upload_sizes = Vec::with_capacity(collected.metas.len());
        let mut loss_sum = 0.0f64;
        for &(train_loss, nnz, bytes) in &collected.metas {
            // Every spawned job is billed — including one whose upload the
            // fault plan then dropped or mangled: the client's radio spent
            // those bytes whether or not the server could use them.
            self.ledger.record_upload(self.p, nnz, bytes);
            upload_sizes.push(bytes);
            loss_sum += train_loss as f64;
        }
        if collected.dup_frames > 0 {
            self.ledger.record_redundant_upload(collected.dup_frames, collected.dup_bytes);
        }
        RoundCost { loss_sum, upload_sizes }
    }
}

#[cfg(test)]
mod tests {
    //! Engine-free tests of the round state machine. Two tiers:
    //!
    //! * `drain_round_uploads` regressions (dead client, failed job,
    //!   scrambled arrivals, missing upload, stray-payload policy) driven
    //!   with hand-built channels — ROADMAP item (c), unchanged contract.
    //! * Full **sample → broadcast → collect → finalize** cycles with
    //!   fake clients on worker threads pulling the broadcast off the
    //!   real downlink and uploading through the real sink — over
    //!   in-process and simulated transports, all encodings, both
    //!   downlink modes; plus the Eq. 3 cohort properties.

    use super::*;
    use crate::config::experiment::AggregatorKind;
    use crate::fl::aggregate::make_aggregator;
    use crate::transport::codec::encode_update_cached;
    use crate::fl::client::receive_broadcast;
    use crate::fl::masking::MaskTarget;
    use crate::fl::sampling::SamplingSchedule;
    use crate::runtime::manifest::LayerInfo;
    use crate::util::prop::check;
    use std::sync::mpsc::channel;

    const P: usize = 16;

    fn layers() -> Vec<LayerInfo> {
        vec![LayerInfo {
            name: "w".into(),
            shape: vec![P],
            offset: 0,
            size: P,
            masked: true,
        }]
    }

    fn payload_for(client: u32, round: u32) -> Vec<u8> {
        let mut params = vec![0.0f32; P];
        params[client as usize] = 1.0 + client as f32;
        encode_update(client, round, 10 + client, &params, Encoding::Auto)
    }

    fn fresh_agg() -> Box<dyn Aggregator> {
        let broadcast = vec![0.0f32; P];
        make_aggregator(AggregatorKind::FedAvg, MaskTarget::Weights, &broadcast, &layers())
            .unwrap()
    }

    /// Build a simulated-network transport over in-process channels — the
    /// configuration whose first recv used to barrier on the whole cohort
    /// and wait out the 300 s upload timeout when a client died.
    fn simulated_transport() -> Simulated {
        Simulated::new(Box::new(InProcess::new()), NetworkModel::default())
    }

    /// Headline regression: under `network = "simulated"`, a client job
    /// that dies (here: its worker panics before sending anything) fails
    /// the round with the pool's error in well under the upload timeout —
    /// the old drain waited out the full 300 s first.
    #[test]
    fn dead_client_fails_the_round_immediately_not_after_the_upload_timeout() {
        let mut transport = simulated_transport();
        let sink = transport.sink();
        let selected = vec![0usize, 1];
        transport.begin_round(selected.len());
        let (tx, results) = channel::<(usize, Result<JobMeta>)>();

        // client 0 completes normally: payload over the wire + metadata
        let payload = payload_for(0, 1);
        let bytes = payload.len();
        sink.send(payload).unwrap();
        tx.send((0, Ok((0.5, 1, bytes)))).unwrap();

        // client 1 "panics": its worker thread unwinds, dropping the reply
        // sender without ever sending a payload or metadata
        let tx1 = tx.clone();
        let victim = std::thread::spawn(move || {
            let _held_until_unwind = tx1;
            panic!("client 1 panicked mid-round");
        });
        assert!(victim.join().is_err());
        drop(tx);

        let started = Instant::now();
        let mut agg = fresh_agg();
        let err = drain_round_uploads(
            &mut transport,
            &results,
            &mut RoundFold::Serial(agg.as_mut()),
            &mut DecodeScratch::default(),
            &selected,
            &[true, true],
            None,
            1,
            P,
            false,
            DEFAULT_UPLOAD_TIMEOUT,
            Duration::from_millis(25),
            None,
        )
        .unwrap_err();
        let elapsed = started.elapsed();
        assert!(matches!(err, Error::Engine(_)), "{err}");
        assert!(
            elapsed < Duration::from_secs(5),
            "dead client took {elapsed:?} to surface (budget 5 s, old behavior 300 s)"
        );
    }

    /// A job that returns a concrete error (rather than dying) surfaces
    /// that exact error immediately, even though its upload never arrives
    /// and the simulated network is still barriering on the cohort.
    #[test]
    fn failed_job_error_beats_the_wire_timeout_and_names_the_cause() {
        let mut transport = simulated_transport();
        let sink = transport.sink();
        let selected = vec![0usize, 1];
        transport.begin_round(selected.len());
        let (tx, results) = channel::<(usize, Result<JobMeta>)>();

        let payload = payload_for(0, 1);
        let bytes = payload.len();
        sink.send(payload).unwrap();
        tx.send((0, Ok((0.5, 1, bytes)))).unwrap();
        tx.send((1, Err(Error::Engine("client 1 exploded".into())))).unwrap();

        let started = Instant::now();
        let mut agg = fresh_agg();
        let err = drain_round_uploads(
            &mut transport,
            &results,
            &mut RoundFold::Serial(agg.as_mut()),
            &mut DecodeScratch::default(),
            &selected,
            &[true, true],
            None,
            1,
            P,
            false,
            DEFAULT_UPLOAD_TIMEOUT,
            Duration::from_millis(25),
            None,
        )
        .unwrap_err();
        assert!(err.to_string().contains("client 1 exploded"), "{err}");
        assert!(started.elapsed() < Duration::from_secs(5));
    }

    /// Healthy rounds still work through the polling drain: payloads and
    /// metadata arriving in scrambled, interleaved order all fold, and the
    /// metadata comes back in input order.
    #[test]
    fn drain_folds_cohort_with_scrambled_arrival_orders() {
        for use_simulated in [false, true] {
            let mut transport: Box<dyn Transport> = if use_simulated {
                Box::new(simulated_transport())
            } else {
                Box::new(InProcess::new())
            };
            let sink = transport.sink();
            let selected = vec![0usize, 1, 2];
            transport.begin_round(selected.len());
            let (tx, results) = channel::<(usize, Result<JobMeta>)>();

            // metadata for 2 lands before its payload; payload order 1,2,0
            let payloads: Vec<Vec<u8>> =
                (0..3).map(|c| payload_for(c as u32, 7)).collect();
            tx.send((2, Ok((0.2, 1, payloads[2].len())))).unwrap();
            sink.send(payloads[1].clone()).unwrap();
            sink.send(payloads[2].clone()).unwrap();
            tx.send((0, Ok((0.0, 1, payloads[0].len())))).unwrap();
            sink.send(payloads[0].clone()).unwrap();
            tx.send((1, Ok((0.1, 1, payloads[1].len())))).unwrap();
            drop(tx);

            let mut agg = fresh_agg();
            let metas = drain_round_uploads(
                transport.as_mut(),
                &results,
                &mut RoundFold::Serial(agg.as_mut()),
                &mut DecodeScratch::default(),
                &selected,
                &[true, true, true],
                None,
                7,
                P,
                false,
                Duration::from_secs(30),
                Duration::from_millis(25),
                None,
            )
            .unwrap()
            .metas;
            assert_eq!(metas.len(), 3);
            for (i, (loss, nnz, bytes)) in metas.iter().enumerate() {
                assert_eq!(*loss, 0.1 * i as f32);
                assert_eq!(*nnz, 1);
                assert_eq!(*bytes, payloads[i].len());
            }
            // the fold saw all three contributions
            let out = agg.finish().unwrap();
            let total: u32 = 10 + 11 + 12;
            for c in 0..3usize {
                let want = (1.0 + c as f32) * (10 + c as u32) as f32 / total as f32;
                assert!(
                    (out[c] - want).abs() < 1e-6,
                    "coord {c}: {} vs {want} (simulated={use_simulated})",
                    out[c]
                );
            }
        }
    }

    /// An upload that never arrives (job reported fine but the payload was
    /// lost) times out with a typed transport error naming the missing
    /// clients — using a short timeout to keep the test fast.
    #[test]
    fn missing_upload_times_out_with_missing_clients_named() {
        let mut transport = InProcess::new();
        let selected = vec![4usize, 9];
        transport.begin_round(selected.len());
        let (tx, results) = channel::<(usize, Result<JobMeta>)>();
        tx.send((0, Ok((0.0, 1, 10)))).unwrap();
        tx.send((1, Ok((0.0, 1, 10)))).unwrap();
        drop(tx);

        let mut agg = fresh_agg();
        let err = drain_round_uploads(
            &mut transport,
            &results,
            &mut RoundFold::Serial(agg.as_mut()),
            &mut DecodeScratch::default(),
            &selected,
            &[true, true],
            None,
            1,
            P,
            false,
            Duration::from_millis(150),
            Duration::from_millis(25),
            None,
        )
        .unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("timed out") && msg.contains('4') && msg.contains('9'), "{msg}");
    }

    /// On a closed (in-process) wire an invalid payload fails the round
    /// precisely; on an open wire it is dropped and the genuine upload
    /// still folds.
    #[test]
    fn stray_payload_policy_follows_the_transport() {
        // closed wire: wrong-round payload is an internal bug -> error
        let mut transport = InProcess::new();
        let sink = transport.sink();
        let selected = vec![0usize];
        transport.begin_round(1);
        let (tx, results) = channel::<(usize, Result<JobMeta>)>();
        let good = payload_for(0, 3);
        tx.send((0, Ok((0.0, 1, good.len())))).unwrap();
        sink.send(payload_for(0, 99)).unwrap();
        let mut agg = fresh_agg();
        let err = drain_round_uploads(
            &mut transport,
            &results,
            &mut RoundFold::Serial(agg.as_mut()),
            &mut DecodeScratch::default(),
            &selected,
            &[true],
            None,
            3,
            P,
            false,
            Duration::from_secs(5),
            Duration::from_millis(25),
            None,
        )
        .unwrap_err();
        assert!(err.to_string().contains("round"), "{err}");

        // open wire: the stray is dropped, the genuine upload folds
        let mut transport = InProcess::new();
        let sink = transport.sink();
        transport.begin_round(1);
        let (tx, results) = channel::<(usize, Result<JobMeta>)>();
        tx.send((0, Ok((0.0, 1, good.len())))).unwrap();
        drop(tx);
        sink.send(payload_for(0, 99)).unwrap();
        sink.send(good).unwrap();
        let mut agg = fresh_agg();
        let metas = drain_round_uploads(
            &mut transport,
            &results,
            &mut RoundFold::Serial(agg.as_mut()),
            &mut DecodeScratch::default(),
            &selected,
            &[true],
            None,
            3,
            P,
            true,
            Duration::from_secs(5),
            Duration::from_millis(25),
            None,
        )
        .unwrap()
        .metas;
        assert_eq!(metas.len(), 1);
        assert_eq!(agg.folded(), 1);
    }

    // -----------------------------------------------------------------
    // Full phase-cycle tests with fake clients on the real wire
    // -----------------------------------------------------------------

    fn driver_cfg(
        transport: TransportKind,
        network: NetworkKind,
        encoding: Encoding,
        downlink_delta: bool,
        clients: usize,
    ) -> Arc<ExperimentConfig> {
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.clients = clients;
        cfg.transport = transport;
        cfg.network = network;
        cfg.encoding = encoding;
        cfg.downlink_delta = downlink_delta;
        Arc::new(cfg)
    }

    fn always_on(seed: u64) -> AvailabilityModel {
        AvailabilityModel::new(1.0, 0.0, seed)
    }

    /// Deterministic fake update for (broadcast, client): a masked-style
    /// sparse vector derived from the broadcast the client decoded, so
    /// any broadcast discrepancy across transports changes the aggregate.
    fn fake_update(global: &[f32], client: usize) -> Vec<f32> {
        global
            .iter()
            .enumerate()
            .map(|(j, g)| {
                if j % 4 == client % 4 {
                    g * 0.5 + (client as f32 + 1.0) * 0.125
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Run one full sample → broadcast → collect → finalize cycle with
    /// fake clients on threads: each receives its broadcast from the
    /// transport's downlink half, derives a deterministic update, and
    /// uploads through the sink. Returns (aggregate, broadcast params,
    /// cohort size).
    fn run_fake_round(
        driver: &mut RoundDriver,
        params: &Arc<Vec<f32>>,
        t: usize,
        target: MaskTarget,
    ) -> (Vec<f32>, Vec<f32>, usize) {
        let availability = always_on(7);
        let cohort = driver.sample(&availability, t);
        let wire = driver.broadcast(params, &cohort).unwrap();
        assert_eq!(wire.references.len(), cohort.selected.len());

        let sink = driver.sink();
        let downlink = driver.downlink();
        let (tx, results) = channel::<(usize, Result<JobMeta>)>();
        let handles: Vec<_> = cohort
            .selected
            .iter()
            .enumerate()
            .filter(|&(i, _)| wire.spawn[i])
            .enumerate()
            .map(|(j, (i, &c))| {
                let sink = Arc::clone(&sink);
                let downlink = Arc::clone(&downlink);
                let reference = wire.references[i].clone();
                // The round's cache snapshot, exactly as a real ClientJob
                // receives it — None unless the configured encoding uses
                // the cache and last round's upload was accepted.
                let cache = wire.index_caches[i].clone();
                let tx = tx.clone();
                std::thread::spawn(move || {
                    let global = receive_broadcast(
                        downlink.as_ref(),
                        c as u32,
                        t as u32,
                        reference.as_deref().map(Vec::as_slice),
                        Duration::from_secs(30),
                    )
                    .unwrap();
                    let update = fake_update(&global, c);
                    let nnz = update.iter().filter(|v| **v != 0.0).count();
                    let payload = encode_update_cached(
                        c as u32,
                        t as u32,
                        10 + c as u32,
                        &update,
                        Encoding::Auto,
                        cache.as_deref(),
                    );
                    let bytes = payload.len();
                    sink.send(payload).unwrap();
                    tx.send((j, Ok((0.25, nnz, bytes)))).unwrap();
                })
            })
            .collect();
        drop(tx);

        let mut agg = make_aggregator(
            AggregatorKind::FedAvg,
            target,
            &wire.params,
            &layers_p(params.len()),
        )
        .unwrap();
        let collected = driver.collect(&cohort, agg.as_mut(), &results).unwrap();
        for h in handles {
            h.join().unwrap();
        }
        let cost = driver.finalize(&collected);
        assert_eq!(cost.upload_sizes.len(), cohort.selected.len());
        let broadcast = (*wire.params).clone();
        (agg.finish().unwrap(), broadcast, cohort.selected.len())
    }

    fn layers_p(p: usize) -> Vec<LayerInfo> {
        vec![LayerInfo {
            name: "w".into(),
            shape: vec![p],
            offset: 0,
            size: p,
            masked: true,
        }]
    }

    /// Two consecutive full-duplex rounds (the second exercising the
    /// delta-downlink reconstruction) are bitwise identical between the
    /// in-process and simulated transports, for every encoding, both
    /// downlink modes, both mask targets.
    #[test]
    fn fake_rounds_are_bitwise_identical_across_in_process_transports() {
        let p = 24usize;
        let params0: Arc<Vec<f32>> =
            Arc::new((0..p).map(|j| (j as f32 * 0.37).sin()).collect());
        for &enc in Encoding::ALL {
            for downlink_delta in [false, true] {
                for target in [MaskTarget::Delta, MaskTarget::Weights] {
                    let mut outcomes = Vec::new();
                    for network in [NetworkKind::Ideal, NetworkKind::Simulated] {
                        let cfg = driver_cfg(
                            TransportKind::InProcess,
                            network,
                            enc,
                            downlink_delta,
                            4,
                        );
                        let mut driver = RoundDriver::new(Arc::clone(&cfg), p).unwrap();
                        driver.set_upload_timeout(Duration::from_secs(30));
                        let (agg1, bcast1, k1) =
                            run_fake_round(&mut driver, &params0, 1, target);
                        assert_eq!(k1, 4, "static C=1 selects everyone");
                        let params1 = Arc::new(agg1.clone());
                        let (agg2, bcast2, _) =
                            run_fake_round(&mut driver, &params1, 2, target);
                        outcomes.push((agg1, bcast1, agg2, bcast2, driver.ledger().clone()));
                    }
                    let (a, b) = (&outcomes[0], &outcomes[1]);
                    assert_eq!(a.0, b.0, "{enc:?}/{downlink_delta}/{target:?}: round-1 aggregate");
                    assert_eq!(a.1, b.1, "{enc:?}: round-1 broadcast");
                    assert_eq!(a.2, b.2, "{enc:?}: round-2 aggregate");
                    assert_eq!(a.3, b.3, "{enc:?}: round-2 broadcast");
                    assert_eq!(a.4.downlink_bytes, b.4.downlink_bytes, "{enc:?}: downlink bytes");
                    assert_eq!(a.4.uplink_bytes, b.4.uplink_bytes, "{enc:?}: uplink bytes");
                }
            }
        }
    }

    /// The delta downlink actually shrinks the second round's billed
    /// downlink bytes when the model barely moves (sparse delta), and the
    /// reconstruction error stays within the lossy bound.
    #[test]
    fn delta_downlink_bills_fewer_bytes_for_a_sparse_model_move() {
        let p = 64usize;
        let cfg = driver_cfg(
            TransportKind::InProcess,
            NetworkKind::Ideal,
            Encoding::Auto,
            true,
            3,
        );
        let mut driver = RoundDriver::new(Arc::clone(&cfg), p).unwrap();
        driver.set_upload_timeout(Duration::from_secs(30));
        let params0: Arc<Vec<f32>> = Arc::new(vec![1.0; p]);
        let availability = always_on(7);

        let cohort = driver.sample(&availability, 1);
        let wire1 = driver.broadcast(&params0, &cohort).unwrap();
        assert_eq!(wire1.recon_err, 0.0, "first broadcast is dense, exact");
        let dense_billed = driver.ledger().downlink_bytes;
        // drain the queued downlinks so round 2's receives are clean
        let dl = driver.downlink();
        for &c in &cohort.selected {
            dl.recv(c as u32, Duration::from_secs(5)).unwrap();
        }

        // the model moves in only 3 coordinates
        let mut moved = (*params0).clone();
        for j in [1usize, 17, 40] {
            moved[j] += 0.5;
        }
        let params1 = Arc::new(moved);
        let cohort2 = driver.sample(&availability, 2);
        let wire2 = driver.broadcast(&params1, &cohort2).unwrap();
        let delta_billed = driver.ledger().downlink_bytes - dense_billed;
        assert!(
            delta_billed < dense_billed,
            "delta round billed {delta_billed} vs dense {dense_billed}"
        );
        assert_eq!(wire2.recon_err, 0.0, "lossless delta reconstructs exactly");
        assert_eq!(&*wire2.params, &*params1);
        for &c in &cohort2.selected {
            dl.recv(c as u32, Duration::from_secs(5)).unwrap();
        }
    }

    // -----------------------------------------------------------------
    // Sampling-schedule properties under the driver (satellite)
    // -----------------------------------------------------------------

    /// Dynamic-exp cohort sizes follow Eq. 3: the target count is
    /// `max(round(M·c0/exp(beta·t)), min_clients, 1)` clamped to M, the
    /// realized cohort (full availability) matches it exactly, and the
    /// sequence is monotone non-increasing within that clamping.
    #[test]
    fn prop_dynamic_exp_cohorts_follow_eq3_and_stay_registered() {
        check("driver cohorts follow Eq. 3", 25, |g| {
            let m = g.usize_in(4, 40);
            let c0 = g.f64_in(0.3, 1.0);
            let beta = g.f64_in(0.01, 0.5);
            let min_clients = g.usize_in(1, 2);
            let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
            cfg.clients = m;
            cfg.sampling = SamplingSchedule::DynamicExp { c0, beta };
            cfg.min_clients = min_clients;
            let cfg = Arc::new(cfg);
            let driver = RoundDriver::new(Arc::clone(&cfg), P).unwrap();
            let availability = always_on(g.seed);

            let mut prev_want = usize::MAX;
            for t in 1..=30 {
                let cohort = driver.sample(&availability, t);
                // Eq. 3 rate, then the Alg. 3 floor/cap
                let rate = c0 / (beta * t as f64).exp();
                assert!((cohort.rate - rate).abs() < 1e-12);
                let want = ((rate * m as f64).round() as usize)
                    .max(1)
                    .max(min_clients)
                    .min(m);
                assert_eq!(
                    cohort.selected.len(),
                    want,
                    "t={t} m={m} c0={c0} beta={beta}"
                );
                assert!(want <= prev_want, "cohort target must not grow");
                prev_want = want;
                // every sampled client is registered (and on sockets would
                // hold a session token): the cohort is a subset of the
                // driver's registry
                assert!(cohort.stragglers.is_empty());
                for &c in &cohort.selected {
                    assert!(
                        driver.registered().binary_search(&(c as u32)).is_ok(),
                        "client {c} sampled but not registered"
                    );
                }
                // sorted + duplicate-free (binary-search contract)
                assert!(cohort.selected.windows(2).all(|w| w[0] < w[1]));
            }
        });
    }

    /// Stragglers are billed the broadcast but receive no wire message
    /// (an unread frame would corrupt their next active round), and
    /// references line up with who holds previous state.
    #[test]
    fn stragglers_are_billed_but_not_wired() {
        let p = 8usize;
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.clients = 6;
        cfg.straggler_prob = 0.5;
        let cfg = Arc::new(cfg);
        let mut driver = RoundDriver::new(Arc::clone(&cfg), p).unwrap();
        // force a mixed cohort by sampling under a straggler-heavy model
        let availability = AvailabilityModel::new(1.0, 0.5, 123);
        let (cohort, t) = (1..50)
            .map(|t| (driver.sample(&availability, t), t))
            .find(|(c, _)| !c.stragglers.is_empty() && !c.selected.is_empty())
            .expect("some round has both completers and stragglers");
        let params: Arc<Vec<f32>> = Arc::new(vec![0.5; p]);
        let wire = driver.broadcast(&params, &cohort).unwrap();
        let billed = driver.ledger().messages;
        assert_eq!(
            billed as usize,
            cohort.selected.len() + cohort.stragglers.len(),
            "every ACKer pays downlink"
        );
        // only completers have wire messages waiting
        let dl = driver.downlink();
        for &c in &cohort.selected {
            dl.recv(c as u32, Duration::from_secs(5)).unwrap();
        }
        for &c in &cohort.stragglers {
            assert!(
                dl.recv(c as u32, Duration::from_millis(30)).is_err(),
                "straggler {c} must not have a queued wire message (round {t})"
            );
        }
        assert_eq!(wire.slowest_download, wire_bytes(p, p, Encoding::Dense));
    }

    // -----------------------------------------------------------------
    // Lazy registration + sharded collect
    // -----------------------------------------------------------------

    /// Transport wrapper that records every `register_clients` call — the
    /// observable for the lazy-registration contract.
    struct Recording {
        inner: InProcess,
        calls: Arc<std::sync::Mutex<Vec<Vec<u32>>>>,
    }

    impl Transport for Recording {
        fn label(&self) -> &'static str {
            self.inner.label()
        }
        fn accepts_foreign_peers(&self) -> bool {
            self.inner.accepts_foreign_peers()
        }
        fn register_clients(&mut self, clients: &[u32]) -> Result<()> {
            self.calls.lock().unwrap().push(clients.to_vec());
            self.inner.register_clients(clients)
        }
        fn sink(&self) -> Arc<dyn UploadSink> {
            self.inner.sink()
        }
        fn send_downlink(&mut self, client: u32, payload: Arc<Vec<u8>>) -> Result<()> {
            self.inner.send_downlink(client, payload)
        }
        fn downlink(&self) -> Arc<dyn DownlinkSource> {
            self.inner.downlink()
        }
        fn begin_round(&mut self, expected: usize) {
            self.inner.begin_round(expected)
        }
        fn recv(&mut self) -> Result<Vec<u8>> {
            self.inner.recv()
        }
        fn try_recv_for(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
            self.inner.try_recv_for(timeout)
        }
    }

    /// Registration is lazy and per-cohort: building the driver registers
    /// nobody, the first broadcast registers exactly its cohort, and a
    /// later cohort registers only clients not yet connected.
    #[test]
    fn registration_is_lazy_per_cohort_and_idempotent() {
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.clients = 8;
        cfg.sampling = SamplingSchedule::DynamicExp { c0: 0.25, beta: 0.0 };
        cfg.min_clients = 2;
        let cfg = Arc::new(cfg);
        let calls: Arc<std::sync::Mutex<Vec<Vec<u32>>>> =
            Arc::new(std::sync::Mutex::new(Vec::new()));
        let transport = Recording {
            inner: InProcess::new(),
            calls: Arc::clone(&calls),
        };
        let mut driver =
            RoundDriver::with_transport(Arc::clone(&cfg), P, Box::new(transport)).unwrap();
        assert_eq!(driver.connected_clients(), 0, "construction opens no sessions");
        assert!(calls.lock().unwrap().is_empty());
        assert_eq!(driver.registered().len(), 8, "universe stays the full fleet");

        let availability = always_on(3);
        let params: Arc<Vec<f32>> = Arc::new(vec![0.5; P]);
        let cohort1 = driver.sample(&availability, 1);
        driver.broadcast(&params, &cohort1).unwrap();
        let c1: Vec<u32> = cohort1.selected.iter().map(|&c| c as u32).collect();
        assert_eq!(calls.lock().unwrap().as_slice(), std::slice::from_ref(&c1));
        assert_eq!(driver.connected_clients(), c1.len());

        let cohort2 = driver.sample(&availability, 2);
        driver.broadcast(&params, &cohort2).unwrap();
        let fresh: Vec<u32> = cohort2
            .selected
            .iter()
            .map(|&c| c as u32)
            .filter(|c| !c1.contains(c))
            .collect();
        {
            let calls = calls.lock().unwrap();
            if fresh.is_empty() {
                assert_eq!(calls.len(), 1, "repeat cohort must not re-register");
            } else {
                assert_eq!(calls.len(), 2);
                assert_eq!(calls[1], fresh, "only never-connected clients register");
            }
        }
        assert_eq!(driver.connected_clients(), c1.len() + fresh.len());
    }

    /// The index-cache lifecycle under real rounds: the first accepted
    /// fold seeds an epoch-1 cache over the decoded support, each further
    /// accepted fold advances the epoch, and a stateless encoding
    /// maintains no caches at all.
    #[test]
    fn index_cache_lifecycle_advances_only_on_accepted_folds() {
        let p = 24usize;
        let params0: Arc<Vec<f32>> =
            Arc::new((0..p).map(|j| (j as f32 * 0.11).cos()).collect());
        let cfg = driver_cfg(
            TransportKind::InProcess,
            NetworkKind::Ideal,
            Encoding::SparseCached,
            false,
            3,
        );
        let mut driver = RoundDriver::new(Arc::clone(&cfg), p).unwrap();
        driver.set_upload_timeout(Duration::from_secs(30));
        assert!(driver.index_caches.iter().all(Option::is_none), "no cache before any fold");

        let (agg1, _, _) = run_fake_round(&mut driver, &params0, 1, MaskTarget::Weights);
        let epochs: Vec<u32> =
            driver.index_caches.iter().map(|c| c.as_ref().expect("accepted fold").epoch).collect();
        assert_eq!(epochs, vec![1; 3], "first accepted fold seeds epoch-1 caches");

        let params1 = Arc::new(agg1);
        run_fake_round(&mut driver, &params1, 2, MaskTarget::Weights);
        for (c, cache) in driver.index_caches.iter().enumerate() {
            let cache = cache.as_ref().expect("accepted fold");
            assert_eq!(cache.epoch, 2, "accepted fold advances the epoch");
            // fake_update's support is the client's residue class mod 4
            let want: Vec<u32> = (0..p as u32).filter(|j| j % 4 == (c as u32) % 4).collect();
            assert_eq!(cache.indices, want, "cache holds the accepted support");
        }

        // a stateless encoding never populates the cache table
        let cfg = driver_cfg(
            TransportKind::InProcess,
            NetworkKind::Ideal,
            Encoding::SparseDelta,
            false,
            3,
        );
        let mut driver = RoundDriver::new(Arc::clone(&cfg), p).unwrap();
        driver.set_upload_timeout(Duration::from_secs(30));
        run_fake_round(&mut driver, &params0, 1, MaskTarget::Weights);
        assert!(driver.index_caches.iter().all(Option::is_none));
    }

    /// The sharded drain produces the bitwise-identical aggregate to the
    /// serial drain, across shard counts — the driver-level face of the
    /// tree-merge exactness property.
    #[test]
    fn sharded_drain_matches_serial_drain_bitwise() {
        let k = 6usize;
        let selected: Vec<usize> = (0..k).collect();
        let payloads: Vec<Vec<u8>> = (0..k).map(|c| payload_for(c as u32, 5)).collect();
        let feed = |transport: &mut dyn Transport| {
            let sink = transport.sink();
            transport.begin_round(k);
            let (tx, results) = channel::<(usize, Result<JobMeta>)>();
            for (i, p) in payloads.iter().enumerate() {
                sink.send(p.clone()).unwrap();
                tx.send((i, Ok((0.0, 1, p.len())))).unwrap();
            }
            results
        };

        let mut transport = InProcess::new();
        let results = feed(&mut transport);
        let mut agg = fresh_agg();
        drain_round_uploads(
            &mut transport,
            &results,
            &mut RoundFold::Serial(agg.as_mut()),
            &mut DecodeScratch::default(),
            &selected,
            &vec![true; k],
            None,
            5,
            P,
            false,
            Duration::from_secs(30),
            Duration::from_millis(25),
            None,
        )
        .unwrap();
        let reference = agg.finish().unwrap();

        for shards in [1usize, 2, 8] {
            let mut transport = InProcess::new();
            let results = feed(&mut transport);
            let partials: Vec<Box<dyn Aggregator>> = (0..shards).map(|_| fresh_agg()).collect();
            let mut tree = ShardedAggregator::spawn(partials).unwrap();
            let metas = drain_round_uploads(
                &mut transport,
                &results,
                &mut RoundFold::Sharded(&mut tree),
                &mut DecodeScratch::default(),
                &selected,
                &vec![true; k],
                None,
                5,
                P,
                false,
                Duration::from_secs(30),
                Duration::from_millis(25),
                None,
            )
            .unwrap()
            .metas;
            assert_eq!(metas.len(), k);
            assert_eq!(tree.routed(), k);
            let merged = tree.finish().unwrap();
            assert_eq!(merged, reference, "shards {shards}");
        }
    }
}
