//! The fused mask→stream pipeline — the client upload hot path.
//!
//! [`mask_stream_selective`] is the single-pass twin of
//! [`selective_mask_rust_with`]: instead of materializing a dense masked
//! `Vec<f32>` that the encoder then re-walks to census, it feeds the kept
//! (index, value) pairs of the top-k partition *directly* into a
//! [`MaskedStream`], which accumulates the census sideband (nnz, varint
//! gap bytes, quantizer min/max) as the pairs arrive. Downstream,
//! [`crate::transport::codec::encode_masked`] prices and writes the wire
//! frame straight from the stream — so on the fused path no dense masked
//! vector, no second census walk, and no intermediate code vector exist.
//!
//! Correctness anchor: the keep decision is *shared code*, not parallel
//! code — [`segment_threshold`] (the descending `select_nth_unstable`
//! partition and tie budget) is the same function the staged masker
//! calls, so the two paths cannot drift on tie-breaking. The property
//! suite pins `fused == staged` bitwise across every encoding, scope and
//! mask target (`tests/properties.rs`).
//!
//! This module is on the `fedlint` panic-free SCOPE (whole file): no
//! indexing, no unwrap/expect, typed errors for contract violations the
//! staged path would assert on. Layer tables that are in-bounds but not
//! sorted/disjoint (never produced by a manifest, but representable) take
//! a cold fallback through the staged masker so the emitted stream stays
//! bitwise-faithful to the oracle in every reachable configuration.

use crate::fl::masking::{
    keep_count, segment_threshold, selective_mask_rust_with, MaskScope, MaskScratch,
};
use crate::runtime::manifest::LayerInfo;
use crate::transport::codec::MaskedStream;
use crate::util::error::{Error, Result};

/// Every layer's `[offset, offset + size)` fits in a `p`-vector without
/// overflow.
fn table_in_bounds(layers: &[LayerInfo], p: usize) -> bool {
    layers
        .iter()
        .all(|l| l.offset.checked_add(l.size).is_some_and(|end| end <= p))
}

/// Layers are sorted by offset and non-overlapping — the precondition for
/// emitting stream indices in strictly increasing order with one walk.
fn table_sorted_disjoint(layers: &[LayerInfo]) -> bool {
    let mut pos = 0usize;
    for l in layers {
        if l.offset < pos {
            return false;
        }
        // in-bounds was checked first, so this add cannot overflow; stay
        // defensive anyway
        match l.offset.checked_add(l.size) {
            Some(end) => pos = end,
            None => return false,
        }
    }
    true
}

/// Emit `w[start..end]` into the stream verbatim (gaps between layers and
/// unmasked layers pass through untouched; the stream drops exact zeros,
/// exactly as the census would have).
fn push_passthrough(stream: &mut MaskedStream, w: &[f32], start: usize, end: usize) {
    if let Some(seg) = w.get(start..end) {
        for (j, &v) in seg.iter().enumerate() {
            stream.push((start + j) as u32, v);
        }
    }
}

/// Fused equivalent of `selective_mask_segment`: top-k of one masked
/// segment by |w_new - w_old|, kept entries pushed into the stream at
/// `offset + j` instead of zeroing the rest in place.
fn push_segment_masked(
    stream: &mut MaskedStream,
    w_new: &[f32],
    w_old: &[f32],
    offset: usize,
    gamma: f32,
    scratch: &mut MaskScratch,
) {
    let n = w_new.len();
    let k = keep_count(n, gamma);
    if k == 0 {
        return; // the staged path zero-fills; here the entries just never exist
    }
    if k >= n {
        for (j, &v) in w_new.iter().enumerate() {
            stream.push((offset + j) as u32, v);
        }
        return;
    }
    scratch.deltas.clear();
    scratch
        .deltas
        .extend(w_new.iter().zip(w_old).map(|(n, o)| (n - o).abs()));
    scratch.part.clear();
    scratch.part.extend_from_slice(&scratch.deltas);
    let (thresh, mut kept) = segment_threshold(&mut scratch.part, k);
    // keep d >= thresh, tie budget capped at k — the same walk, in the
    // same order, as the staged masker
    for ((j, &w), &d) in w_new.iter().enumerate().zip(scratch.deltas.iter()) {
        let keep = if d > thresh {
            true
        } else if d == thresh && kept < k {
            kept += 1;
            true
        } else {
            false
        };
        if keep {
            stream.push((offset + j) as u32, w);
        }
    }
}

/// Selective masking (Alg. 4) fused with stream construction: fills
/// `stream` with exactly the (index, value) pairs that
/// `selective_mask_rust_with(w_new, w_old, gamma, layers, scope)` would
/// leave non-zero, in one pass, with zero steady-state allocation (all
/// buffers live in `scratch` / `stream` and reuse capacity).
///
/// Errors (typed, where the staged path would panic): `w_new` / `w_old`
/// length mismatch, or a layer extending past the model dimension.
pub fn mask_stream_selective(
    w_new: &[f32],
    w_old: &[f32],
    gamma: f32,
    layers: &[LayerInfo],
    scope: MaskScope,
    scratch: &mut MaskScratch,
    stream: &mut MaskedStream,
) -> Result<()> {
    let p = w_new.len();
    if w_old.len() != p {
        return Err(Error::invalid(format!(
            "pipeline: w_new has {p} params, w_old has {}",
            w_old.len()
        )));
    }
    if !table_in_bounds(layers, p) {
        return Err(Error::invalid(format!(
            "pipeline: layer table extends past model dimension {p}"
        )));
    }
    if !table_sorted_disjoint(layers) {
        // cold path for irregular (test-only) tables: run the staged
        // oracle and lift its dense result into the stream — allocates,
        // but stays bitwise-faithful where the fused walk cannot run
        let masked = selective_mask_rust_with(w_new, w_old, gamma, layers, scope, scratch);
        stream.from_dense(&masked);
        return Ok(());
    }

    stream.reset(p);
    match scope {
        MaskScope::PerLayer => {
            let mut pos = 0usize;
            for l in layers {
                push_passthrough(stream, w_new, pos, l.offset);
                let end = l.offset + l.size;
                if l.masked {
                    let (Some(ns), Some(os)) =
                        (w_new.get(l.offset..end), w_old.get(l.offset..end))
                    else {
                        return Err(Error::invalid("pipeline: layer slice out of range"));
                    };
                    push_segment_masked(stream, ns, os, l.offset, gamma, scratch);
                } else {
                    push_passthrough(stream, w_new, l.offset, end);
                }
                pos = end;
            }
            push_passthrough(stream, w_new, pos, p);
        }
        MaskScope::Global => {
            // pass 1: gather |delta| over all masked entries, in table
            // (== index) order, and derive the joint threshold
            scratch.deltas.clear();
            for l in layers.iter().filter(|l| l.masked) {
                let end = l.offset + l.size;
                let (Some(ns), Some(os)) = (w_new.get(l.offset..end), w_old.get(l.offset..end))
                else {
                    return Err(Error::invalid("pipeline: layer slice out of range"));
                };
                scratch
                    .deltas
                    .extend(ns.iter().zip(os).map(|(n, o)| (n - o).abs()));
            }
            let m = scratch.deltas.len();
            let k = keep_count(m, gamma);
            let keep_all = k >= m;
            let (thresh, mut kept) = if keep_all || k == 0 {
                (0.0f32, 0usize) // unused sentinels; both branches short-circuit
            } else {
                scratch.part.clear();
                scratch.part.extend_from_slice(&scratch.deltas);
                segment_threshold(&mut scratch.part, k)
            };
            // pass 2: one walk over the model — passthrough outside the
            // masked regions, the shared keep rule (with a single global
            // tie budget) inside them, a cursor into the gathered deltas
            let mut pos = 0usize;
            let mut dcur = 0usize;
            for l in layers {
                push_passthrough(stream, w_new, pos, l.offset);
                let end = l.offset + l.size;
                if l.masked {
                    let (Some(ns), Some(ds)) =
                        (w_new.get(l.offset..end), scratch.deltas.get(dcur..dcur + l.size))
                    else {
                        return Err(Error::invalid("pipeline: delta cursor out of range"));
                    };
                    for ((j, &w), &d) in ns.iter().enumerate().zip(ds.iter()) {
                        let keep = if keep_all {
                            true
                        } else if k == 0 {
                            false
                        } else if d > thresh {
                            true
                        } else if d == thresh && kept < k {
                            kept += 1;
                            true
                        } else {
                            false
                        };
                        if keep {
                            stream.push((l.offset + j) as u32, w);
                        }
                    }
                    dcur += l.size;
                } else {
                    push_passthrough(stream, w_new, l.offset, end);
                }
                pos = end;
            }
            push_passthrough(stream, w_new, pos, p);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::masking::selective_mask_rust;
    use crate::util::prop::{check, Gen};

    fn layers_of(sizes: &[(usize, bool)]) -> Vec<LayerInfo> {
        let mut out = Vec::new();
        let mut offset = 0;
        for (i, &(size, masked)) in sizes.iter().enumerate() {
            out.push(LayerInfo {
                name: format!("l{i}"),
                shape: vec![size],
                offset,
                size,
                masked,
            });
            offset += size;
        }
        out
    }

    fn stream_to_dense(stream: &MaskedStream) -> Vec<f32> {
        let mut out = vec![0.0f32; stream.p()];
        for (&i, &v) in stream.indices().iter().zip(stream.values()) {
            out[i as usize] = v;
        }
        out
    }

    #[test]
    fn fused_stream_matches_staged_mask_both_scopes() {
        check("fused mask == staged mask", 60, |g| {
            let a = g.usize_in(4, 200);
            let b = g.usize_in(4, 200);
            let c = g.usize_in(1, 50);
            let gamma = g.f32_in(0.05, 1.0);
            let layers = layers_of(&[(a, true), (c, false), (b, true)]);
            let p = a + b + c;
            let wn = g.normal_vec(p);
            let wo = g.normal_vec(p);
            let mut scratch = MaskScratch::default();
            let mut stream = MaskedStream::default();
            for scope in [MaskScope::PerLayer, MaskScope::Global] {
                let staged = selective_mask_rust(&wn, &wo, gamma, &layers, scope);
                mask_stream_selective(&wn, &wo, gamma, &layers, scope, &mut scratch, &mut stream)
                    .unwrap();
                assert_eq!(
                    stream_to_dense(&stream),
                    staged,
                    "scope {scope:?} seed {:#x}",
                    g.seed
                );
                assert_eq!(
                    stream.nnz(),
                    staged.iter().filter(|v| **v != 0.0).count(),
                    "nnz sideband must match"
                );
            }
        });
    }

    #[test]
    fn tie_heavy_input_matches_staged_exactly() {
        // constant |delta| everywhere: every entry ties, the budget walk
        // decides — both paths must pick the same prefix
        let layers = layers_of(&[(10, true), (10, true)]);
        let wo = vec![0.0f32; 20];
        let wn = vec![2.0f32; 20];
        let mut scratch = MaskScratch::default();
        let mut stream = MaskedStream::default();
        for scope in [MaskScope::PerLayer, MaskScope::Global] {
            let staged = selective_mask_rust(&wn, &wo, 0.5, &layers, scope);
            mask_stream_selective(&wn, &wo, 0.5, &layers, scope, &mut scratch, &mut stream)
                .unwrap();
            assert_eq!(stream_to_dense(&stream), staged, "{scope:?}");
        }
    }

    #[test]
    fn gaps_and_unmasked_layers_pass_through() {
        // a layer table with a hole: [0,5) masked, [5,8) untracked gap,
        // [8,12) unmasked — gap and unmasked entries must arrive verbatim
        let layers = vec![
            LayerInfo {
                name: "a".into(),
                shape: vec![5],
                offset: 0,
                size: 5,
                masked: true,
            },
            LayerInfo {
                name: "b".into(),
                shape: vec![4],
                offset: 8,
                size: 4,
                masked: false,
            },
        ];
        let wn: Vec<f32> = (1..=12).map(|i| i as f32).collect();
        let wo = vec![0.0f32; 12];
        let mut scratch = MaskScratch::default();
        let mut stream = MaskedStream::default();
        mask_stream_selective(
            &wn,
            &wo,
            0.4,
            &layers,
            MaskScope::PerLayer,
            &mut scratch,
            &mut stream,
        )
        .unwrap();
        let dense = stream_to_dense(&stream);
        assert_eq!(&dense[5..12], &wn[5..12], "gap + unmasked pass through");
        assert_eq!(dense[..5].iter().filter(|v| **v != 0.0).count(), 2); // keep_count(5, 0.4)
    }

    #[test]
    fn empty_and_all_zero_inputs() {
        let mut scratch = MaskScratch::default();
        let mut stream = MaskedStream::default();
        // empty model
        mask_stream_selective(
            &[],
            &[],
            0.5,
            &[],
            MaskScope::PerLayer,
            &mut scratch,
            &mut stream,
        )
        .unwrap();
        assert_eq!(stream.nnz(), 0);
        assert_eq!(stream.p(), 0);
        // all-zero weights: everything masked or not, nothing survives
        let layers = layers_of(&[(16, true)]);
        let wn = vec![0.0f32; 16];
        let wo = vec![0.0f32; 16];
        for scope in [MaskScope::PerLayer, MaskScope::Global] {
            mask_stream_selective(&wn, &wo, 0.5, &layers, scope, &mut scratch, &mut stream)
                .unwrap();
            assert_eq!(stream.nnz(), 0, "{scope:?}");
        }
    }

    #[test]
    fn contract_violations_are_typed_errors() {
        let mut scratch = MaskScratch::default();
        let mut stream = MaskedStream::default();
        // length mismatch
        let err = mask_stream_selective(
            &[1.0, 2.0],
            &[1.0],
            0.5,
            &[],
            MaskScope::PerLayer,
            &mut scratch,
            &mut stream,
        )
        .unwrap_err();
        assert!(err.to_string().contains("w_old"), "{err}");
        // out-of-bounds layer
        let layers = layers_of(&[(10, true)]);
        let err = mask_stream_selective(
            &[0.0; 5],
            &[0.0; 5],
            0.5,
            &layers,
            MaskScope::PerLayer,
            &mut scratch,
            &mut stream,
        )
        .unwrap_err();
        assert!(err.to_string().contains("past model dimension"), "{err}");
    }

    #[test]
    fn unsorted_table_takes_the_staged_fallback_bitwise() {
        // two disjoint but out-of-order layers: fused walk can't emit
        // increasing indices, so the result must equal the staged oracle
        let layers = vec![
            LayerInfo {
                name: "hi".into(),
                shape: vec![6],
                offset: 6,
                size: 6,
                masked: true,
            },
            LayerInfo {
                name: "lo".into(),
                shape: vec![6],
                offset: 0,
                size: 6,
                masked: true,
            },
        ];
        let mut g = Gen::new(11);
        let wn = g.normal_vec(12);
        let wo = g.normal_vec(12);
        let mut scratch = MaskScratch::default();
        let mut stream = MaskedStream::default();
        for scope in [MaskScope::PerLayer, MaskScope::Global] {
            let staged = selective_mask_rust(&wn, &wo, 0.3, &layers, scope);
            mask_stream_selective(&wn, &wo, 0.3, &layers, scope, &mut scratch, &mut stream)
                .unwrap();
            assert_eq!(stream_to_dense(&stream), staged, "{scope:?}");
        }
    }
}
