//! Deterministic chaos harness: seeded fault injection across the
//! transport stack, with the recovery contract pinned by
//! `tests/chaos_scenarios.rs` and documented in `docs/CHAOS.md`.
//!
//! The paper's simulation assumes every sampled client uploads cleanly;
//! cross-device reality does not. This module makes failure a first-class,
//! *reproducible* experiment input:
//!
//! * [`FaultPlan`] — a pure, seeded description of what goes wrong. For
//!   every `(round, client)` pair it derives an [`UploadFate`] and a
//!   [`DownlinkFate`] from one `Rng::new(seed)` fork chain, so the same
//!   plan produces the same faults on every transport, every run, with no
//!   shared mutable state. The round driver consults the *same* pure
//!   functions to predict delivery counts, which is what keeps the
//!   `Simulated` transport's cohort barrier exact under injected loss.
//! * [`ChaosTransport`] — a [`Transport`] wrapper that *executes* the plan:
//!   drops, duplicates, reorders, truncates/bit-flips, disconnects (uplink
//!   and downlink independently), delays past the round, and substitutes
//!   Byzantine payloads (well-formed frames carrying wrong-but-valid codec
//!   bodies). Every injected fault is recorded in a [`ChaosLog`] and
//!   surfaces per round as the [`FaultLog`] field of
//!   [`crate::metrics::recorder::RoundRecord`].
//! * [`Scenario`] — a named, JSON-loadable composition of chaos plan,
//!   availability model, and network model, so one file (or one
//!   `--scenario` flag) fully determines a run. The adversarial
//!   regressions that used to be bespoke test setup are named scenarios
//!   here ([`WireAdversary`] drives the raw-socket attacks).
//!
//! ## Stacking order
//!
//! The driver composes `Simulated(ChaosTransport(base))`: chaos sits
//! *inside* the virtual-time wrapper so the simulated cohort barrier
//! counts post-chaos deliveries (a dropped upload never arrives; a
//! duplicated one arrives twice) and its count is predicted exactly from
//! the plan. Reordering inside chaos is therefore only observable on the
//! `Ideal` network — under `Simulated` the virtual clock re-sorts
//! arrivals, which is the correct physical reading (the wire scrambles,
//! the model re-times).

use std::collections::VecDeque;
use std::io::Write;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::experiment::NetworkKind;
use crate::sim::rng::Rng;
use crate::transport::codec::{encode_update, peek_header, Encoding};
use crate::transport::frame::{frame_bytes, FrameKind, FRAME_HEADER_BYTES, FRAME_MAGIC, FRAME_VERSION};
use crate::transport::link::{DownlinkSource, Transport, UploadSink};
use crate::transport::socket::{ClientConn, Loopback, WireAddr};
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Fork label for the per-(round, client) uplink fate draw.
const UPLINK_LANE: u64 = 0x0b;
/// Fork label for the per-(round, client) downlink fate draw.
const DOWNLINK_LANE: u64 = 0xd0;
/// Fork label for the corrupt-style draw (truncate vs bit-flip).
const CORRUPT_LANE: u64 = 0xbad;
/// Fork label for the per-round reorder shuffle.
const REORDER_LANE: u64 = 0x5e0;

/// How many uploads the reorder window buffers before shuffling them out.
const REORDER_WINDOW: usize = 4;
/// How long the reorder window waits for another arrival before flushing
/// a partial batch (keeps blocking receives from stalling on stragglers).
const REORDER_IDLE: Duration = Duration::from_millis(10);

// ---------------------------------------------------------------------
// Fates: the pure per-(round, client) fault decisions
// ---------------------------------------------------------------------

/// What happens to one client's upload in one round. Derived purely from
/// the plan's seed, so the driver can *predict* delivery counts without
/// observing the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UploadFate {
    /// The upload crosses the wire untouched.
    Deliver,
    /// The upload vanishes (lossy link).
    Drop,
    /// The upload arrives after the round has closed — from the round's
    /// point of view, identical to a drop, but logged distinctly because
    /// the recovery contract differs (a delayed frame must not corrupt
    /// the *next* round's cohort barrier).
    Delay,
    /// The client's uplink dies mid-round: nothing arrives.
    DisconnectUplink,
    /// The upload arrives twice (retransmit storm); it must fold once and
    /// bill twice.
    Duplicate,
    /// The payload is truncated or bit-flipped in flight; it must be
    /// rejected pre-fold.
    Corrupt,
    /// The client is adversarial: a well-formed frame carrying a valid
    /// codec body with the wrong model width, rejected pre-fold by the
    /// width check.
    Byzantine,
}

/// What happens to one client's broadcast in one round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DownlinkFate {
    /// The broadcast reaches the client.
    Deliver,
    /// The client's downlink dies before the broadcast lands: the client
    /// never starts the round (and so never uploads).
    Disconnect,
}

/// Seeded description of every fault the harness injects. Pure data: two
/// plans with equal fields produce byte-identical fault schedules.
///
/// The upload probabilities are *exclusive* — one uniform draw per
/// (round, client) is cut into bands, so their sum must be ≤ 1; the
/// remainder is the clean-delivery probability. `byzantine_clients` is a
/// deterministic roster checked before any draw (a client on it is
/// Byzantine every round). `disconnect_downlink_prob` is an independent
/// draw on the downlink side.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    /// Master chaos seed; every fate forks from it.
    pub seed: u64,
    pub drop_prob: f64,
    pub dup_prob: f64,
    pub corrupt_prob: f64,
    pub delay_prob: f64,
    pub disconnect_uplink_prob: f64,
    pub disconnect_downlink_prob: f64,
    pub byzantine_prob: f64,
    /// Clients that are Byzantine every round, regardless of the draws.
    pub byzantine_clients: Vec<u32>,
    /// Buffer and shuffle upload arrivals in seeded windows.
    pub reorder: bool,
}

impl FaultPlan {
    /// Whether the plan injects anything at all (an inactive plan is not
    /// wrapped around the transport).
    pub fn is_active(&self) -> bool {
        self.drop_prob > 0.0
            || self.dup_prob > 0.0
            || self.corrupt_prob > 0.0
            || self.delay_prob > 0.0
            || self.disconnect_uplink_prob > 0.0
            || self.disconnect_downlink_prob > 0.0
            || self.byzantine_prob > 0.0
            || !self.byzantine_clients.is_empty()
            || self.reorder
    }

    pub fn validate(&self) -> Result<()> {
        let probs = [
            ("chaos drop_prob", self.drop_prob),
            ("chaos dup_prob", self.dup_prob),
            ("chaos corrupt_prob", self.corrupt_prob),
            ("chaos delay_prob", self.delay_prob),
            ("chaos disconnect_uplink_prob", self.disconnect_uplink_prob),
            ("chaos disconnect_downlink_prob", self.disconnect_downlink_prob),
            ("chaos byzantine_prob", self.byzantine_prob),
        ];
        for (name, p) in probs {
            if !(0.0..=1.0).contains(&p) {
                return Err(Error::invalid(format!("{name} {p} must be in [0, 1]")));
            }
        }
        let sum = self.byzantine_prob
            + self.drop_prob
            + self.disconnect_uplink_prob
            + self.delay_prob
            + self.corrupt_prob
            + self.dup_prob;
        if sum > 1.0 + 1e-9 {
            return Err(Error::invalid(format!(
                "chaos upload fault probabilities sum to {sum:.4} > 1 (they are exclusive bands of one draw)"
            )));
        }
        Ok(())
    }

    /// The fate of `client`'s upload in `round`: one uniform draw cut into
    /// exclusive bands (byzantine, drop, disconnect, delay, corrupt,
    /// duplicate, else deliver), after the deterministic Byzantine roster.
    pub fn upload_fate(&self, round: u32, client: u32) -> UploadFate {
        if self.byzantine_clients.contains(&client) {
            return UploadFate::Byzantine;
        }
        let mut rng = Rng::new(self.seed).fork(round as u64).fork(client as u64).fork(UPLINK_LANE);
        let draw = rng.next_f64();
        let mut edge = self.byzantine_prob;
        if draw < edge {
            return UploadFate::Byzantine;
        }
        edge += self.drop_prob;
        if draw < edge {
            return UploadFate::Drop;
        }
        edge += self.disconnect_uplink_prob;
        if draw < edge {
            return UploadFate::DisconnectUplink;
        }
        edge += self.delay_prob;
        if draw < edge {
            return UploadFate::Delay;
        }
        edge += self.corrupt_prob;
        if draw < edge {
            return UploadFate::Corrupt;
        }
        edge += self.dup_prob;
        if draw < edge {
            return UploadFate::Duplicate;
        }
        UploadFate::Deliver
    }

    /// The fate of `client`'s broadcast in `round` (independent draw: a
    /// downlink can die while the uplink would have been fine).
    pub fn downlink_fate(&self, round: u32, client: u32) -> DownlinkFate {
        if self.disconnect_downlink_prob <= 0.0 {
            return DownlinkFate::Deliver;
        }
        let mut rng =
            Rng::new(self.seed).fork(round as u64).fork(client as u64).fork(DOWNLINK_LANE);
        if rng.next_f64() < self.disconnect_downlink_prob {
            DownlinkFate::Disconnect
        } else {
            DownlinkFate::Deliver
        }
    }

    /// How many payloads actually cross the wire for an upload with this
    /// fate — the number the `Simulated` cohort barrier must count.
    pub fn deliveries(&self, fate: UploadFate) -> usize {
        match fate {
            UploadFate::Drop | UploadFate::Delay | UploadFate::DisconnectUplink => 0,
            UploadFate::Duplicate => 2,
            UploadFate::Deliver | UploadFate::Corrupt | UploadFate::Byzantine => 1,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("drop_prob", Json::num(self.drop_prob)),
            ("dup_prob", Json::num(self.dup_prob)),
            ("corrupt_prob", Json::num(self.corrupt_prob)),
            ("delay_prob", Json::num(self.delay_prob)),
            ("disconnect_uplink_prob", Json::num(self.disconnect_uplink_prob)),
            ("disconnect_downlink_prob", Json::num(self.disconnect_downlink_prob)),
            ("byzantine_prob", Json::num(self.byzantine_prob)),
            (
                "byzantine_clients",
                Json::Arr(self.byzantine_clients.iter().map(|&c| Json::num(c as f64)).collect()),
            ),
            ("reorder", Json::Bool(self.reorder)),
        ])
    }

    pub fn from_json(root: &Json) -> Result<FaultPlan> {
        let get_f64 = |k: &str| -> Result<f64> {
            match root.opt(k) {
                Some(v) => v.as_f64(),
                None => Ok(0.0),
            }
        };
        let mut plan = FaultPlan {
            seed: match root.opt("seed") {
                Some(v) => v.as_f64()? as u64,
                None => 0,
            },
            drop_prob: get_f64("drop_prob")?,
            dup_prob: get_f64("dup_prob")?,
            corrupt_prob: get_f64("corrupt_prob")?,
            delay_prob: get_f64("delay_prob")?,
            disconnect_uplink_prob: get_f64("disconnect_uplink_prob")?,
            disconnect_downlink_prob: get_f64("disconnect_downlink_prob")?,
            byzantine_prob: get_f64("byzantine_prob")?,
            byzantine_clients: Vec::new(),
            reorder: match root.opt("reorder") {
                Some(v) => v.as_bool()?,
                None => false,
            },
        };
        if let Some(v) = root.opt("byzantine_clients") {
            plan.byzantine_clients = v.as_usize_vec()?.into_iter().map(|c| c as u32).collect();
        }
        plan.validate()?;
        Ok(plan)
    }
}

// ---------------------------------------------------------------------
// Fault log: what was actually injected, per round
// ---------------------------------------------------------------------

/// The taxonomy of injected faults (see `docs/CHAOS.md` for the recovery
/// guarantee each one is pinned against).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    DropUpload,
    DelayUpload,
    DisconnectUplink,
    DisconnectDownlink,
    DuplicateUpload,
    CorruptUpload,
    ByzantineUpload,
}

impl FaultKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultKind::DropUpload => "drop-upload",
            FaultKind::DelayUpload => "delay-upload",
            FaultKind::DisconnectUplink => "disconnect-uplink",
            FaultKind::DisconnectDownlink => "disconnect-downlink",
            FaultKind::DuplicateUpload => "duplicate-upload",
            FaultKind::CorruptUpload => "corrupt-upload",
            FaultKind::ByzantineUpload => "byzantine-upload",
        }
    }
}

/// One injected fault: which round and client, what was done, and how many
/// payload bytes were involved (suppressed, duplicated, or substituted).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub round: u32,
    pub client: u32,
    pub kind: FaultKind,
    pub bytes: usize,
}

/// The faults injected in one round, in canonical (client, kind, bytes)
/// order — so two identically-seeded runs produce byte-identical logs no
/// matter how threads interleaved the injections.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FaultLog {
    pub events: Vec<FaultEvent>,
}

/// Shared fault accumulator: the sink half injects from worker threads,
/// the driver drains per round into a [`FaultLog`].
#[derive(Default)]
pub struct ChaosLog {
    events: Mutex<Vec<FaultEvent>>,
}

impl ChaosLog {
    fn record(&self, event: FaultEvent) {
        // a poisoned lock only means a worker panicked mid-push; the log
        // itself is append-only and still coherent
        self.events.lock().unwrap_or_else(|e| e.into_inner()).push(event);
    }

    /// Drain (and canonically order) the events of `round`, leaving other
    /// rounds' events (e.g. a delayed frame logged late) in place.
    pub fn take_round(&self, round: u32) -> FaultLog {
        let mut guard = self.events.lock().unwrap_or_else(|e| e.into_inner());
        let mut taken = Vec::new();
        guard.retain(|e| {
            if e.round == round {
                taken.push(*e);
                false
            } else {
                true
            }
        });
        taken.sort_by_key(|e| (e.client, e.kind, e.bytes));
        FaultLog { events: taken }
    }

    /// Injection-time duplicate accounting for `round`: (redundant
    /// frames, redundant bytes). Non-destructive — the events stay in
    /// the log for [`ChaosLog::take_round`]. The drain cannot count
    /// these reliably (whether it pulls a duplicate's second copy before
    /// the round completes depends on arrival interleaving), but the
    /// sink logs every injected copy before the job reports, so by the
    /// time a round's collect returns this sum is complete — and
    /// identical across reruns.
    pub fn round_duplicates(&self, round: u32) -> (u64, u64) {
        let guard = self.events.lock().unwrap_or_else(|e| e.into_inner());
        guard
            .iter()
            .filter(|e| e.round == round && e.kind == FaultKind::DuplicateUpload)
            .fold((0u64, 0u64), |(frames, bytes), e| (frames + 1, bytes + e.bytes as u64))
    }
}

// ---------------------------------------------------------------------
// ChaosTransport: the plan, executed on the wire
// ---------------------------------------------------------------------

/// The upload half: consults the plan once per payload (fate keyed by the
/// header's round and client, so it needs no driver coordination) and
/// injects on the way into the inner sink. Runs on engine worker threads.
struct ChaosSink {
    inner: Arc<dyn UploadSink>,
    plan: Arc<FaultPlan>,
    log: Arc<ChaosLog>,
}

impl ChaosSink {
    /// Deterministically mangle a payload so it is *detectably* corrupt:
    /// either truncate (at least one byte short, codec length checks trip)
    /// or flip a bit inside the codec magic/version (header unparseable).
    fn corrupt(&self, round: u32, client: u32, mut payload: Vec<u8>) -> Vec<u8> {
        let mut rng =
            Rng::new(self.plan.seed).fork(round as u64).fork(client as u64).fork(CORRUPT_LANE);
        if payload.len() > 5 && rng.next_f64() < 0.5 {
            let keep = 4 + rng.next_below((payload.len() - 4) as u64) as usize;
            payload.truncate(keep);
        } else {
            let bit = rng.next_below(24) as usize;
            // peek_header succeeded upstream, so >= 24 header bytes exist;
            // get_mut keeps the ingestion path index-free regardless
            if let Some(b) = payload.get_mut(bit / 8) {
                *b ^= 1 << (bit % 8);
            }
        }
        payload
    }
}

impl UploadSink for ChaosSink {
    fn send(&self, payload: Vec<u8>) -> Result<()> {
        let Some(h) = peek_header(&payload) else {
            // not one of our updates — pass through untouched
            return self.inner.send(payload);
        };
        let bytes = payload.len();
        let event = |kind: FaultKind, bytes: usize| FaultEvent {
            round: h.round,
            client: h.client,
            kind,
            bytes,
        };
        match self.plan.upload_fate(h.round, h.client) {
            UploadFate::Deliver => self.inner.send(payload),
            UploadFate::Drop => {
                self.log.record(event(FaultKind::DropUpload, bytes));
                Ok(())
            }
            UploadFate::Delay => {
                // delivery past the round is indistinguishable from loss
                // for the round itself; swallowing (instead of re-queuing
                // next round) keeps the next cohort barrier exact
                self.log.record(event(FaultKind::DelayUpload, bytes));
                Ok(())
            }
            UploadFate::DisconnectUplink => {
                self.log.record(event(FaultKind::DisconnectUplink, bytes));
                Ok(())
            }
            UploadFate::Duplicate => {
                self.log.record(event(FaultKind::DuplicateUpload, bytes));
                self.inner.send(payload.clone())?;
                self.inner.send(payload)
            }
            UploadFate::Corrupt => {
                let mangled = self.corrupt(h.round, h.client, payload);
                self.log.record(event(FaultKind::CorruptUpload, mangled.len()));
                self.inner.send(mangled)
            }
            UploadFate::Byzantine => {
                // well-formed frame, valid codec body, wrong model width:
                // survives every parse and dies at the pre-fold width check
                let wrong_p = if h.p == 3 { 5 } else { 3 };
                let forged = encode_update(
                    h.client,
                    h.round,
                    h.n_samples.max(1),
                    &vec![0.25f32; wrong_p],
                    Encoding::Dense,
                );
                self.log.record(event(FaultKind::ByzantineUpload, forged.len()));
                self.inner.send(forged)
            }
        }
    }
}

/// [`Transport`] wrapper executing a [`FaultPlan`] on any inner wire.
/// Upload faults happen in the sink (worker-thread side); downlink
/// disconnects and reordering happen here (server-loop side). All
/// injections are logged into the shared [`ChaosLog`].
pub struct ChaosTransport {
    inner: Box<dyn Transport>,
    plan: Arc<FaultPlan>,
    log: Arc<ChaosLog>,
    sink: Arc<ChaosSink>,
    /// Rounds seen via `begin_round`, used to reseed the reorder shuffle
    /// per round (so round k's shuffle never depends on round j's traffic).
    rounds_begun: u64,
    reorder_rng: Rng,
    /// Arrivals buffered for the current reorder window.
    stash: Vec<Vec<u8>>,
    /// Shuffled arrivals ready to hand to the server loop.
    released: VecDeque<Vec<u8>>,
}

impl ChaosTransport {
    pub fn new(inner: Box<dyn Transport>, plan: Arc<FaultPlan>, log: Arc<ChaosLog>) -> ChaosTransport {
        let sink = Arc::new(ChaosSink {
            inner: inner.sink(),
            plan: Arc::clone(&plan),
            log: Arc::clone(&log),
        });
        let reorder_rng = Rng::new(plan.seed).fork(0).fork(REORDER_LANE);
        ChaosTransport {
            inner,
            plan,
            log,
            sink,
            rounds_begun: 0,
            reorder_rng,
            stash: Vec::new(),
            released: VecDeque::new(),
        }
    }

    /// Shuffle the buffered window into the deliverable queue.
    fn flush_stash(&mut self) {
        if self.stash.is_empty() {
            return;
        }
        let mut batch = std::mem::take(&mut self.stash);
        self.reorder_rng.shuffle(&mut batch);
        self.released.extend(batch);
    }

    fn absorb(&mut self, payload: Vec<u8>) {
        self.stash.push(payload);
        if self.stash.len() >= REORDER_WINDOW {
            self.flush_stash();
        }
    }
}

impl Transport for ChaosTransport {
    fn label(&self) -> &'static str {
        "chaos"
    }

    /// Chaos *manufactures* invalid payloads (corrupt, Byzantine), so the
    /// server must treat them as droppable wire noise — exactly the
    /// shared-wire discipline — rather than fail the round on them.
    fn accepts_foreign_peers(&self) -> bool {
        true
    }

    fn register_clients(&mut self, clients: &[u32]) -> Result<()> {
        self.inner.register_clients(clients)
    }

    fn sink(&self) -> Arc<dyn UploadSink> {
        let sink: Arc<dyn UploadSink> = Arc::clone(&self.sink);
        sink
    }

    fn send_downlink(&mut self, client: u32, payload: Arc<Vec<u8>>) -> Result<()> {
        // broadcast payloads carry the round in the same fixed codec header
        let round = peek_header(&payload).map(|h| h.round).unwrap_or(0);
        match self.plan.downlink_fate(round, client) {
            DownlinkFate::Deliver => self.inner.send_downlink(client, payload),
            DownlinkFate::Disconnect => {
                self.log.record(FaultEvent {
                    round,
                    client,
                    kind: FaultKind::DisconnectDownlink,
                    bytes: payload.len(),
                });
                Ok(())
            }
        }
    }

    fn downlink(&self) -> Arc<dyn DownlinkSource> {
        self.inner.downlink()
    }

    fn begin_round(&mut self, expected: usize) {
        self.rounds_begun += 1;
        self.reorder_rng = Rng::new(self.plan.seed).fork(self.rounds_begun).fork(REORDER_LANE);
        // anything still buffered belongs to a closed round; release it so
        // the server's stray-rejection path (not the new barrier) eats it
        self.flush_stash();
        self.inner.begin_round(expected);
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        loop {
            if let Some(p) = self.released.pop_front() {
                return Ok(p);
            }
            if !self.plan.reorder {
                return self.inner.recv();
            }
            match self.inner.try_recv_for(REORDER_IDLE)? {
                Some(p) => self.absorb(p),
                None if !self.stash.is_empty() => self.flush_stash(),
                // idle and nothing buffered: block like the inner wire
                // would (its timeout error is the round's timeout error)
                None => {
                    let p = self.inner.recv()?;
                    self.absorb(p);
                }
            }
        }
    }

    fn try_recv_for(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        if let Some(p) = self.released.pop_front() {
            return Ok(Some(p));
        }
        if !self.plan.reorder {
            return self.inner.try_recv_for(timeout);
        }
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(p) = self.released.pop_front() {
                return Ok(Some(p));
            }
            let Some(window) = deadline
                .checked_duration_since(Instant::now())
                .filter(|w| !w.is_zero())
            else {
                // window lapsed: release a partial reorder batch rather
                // than wedge payloads behind an unfilled window
                self.flush_stash();
                return Ok(self.released.pop_front());
            };
            match self.inner.try_recv_for(window.min(REORDER_IDLE))? {
                Some(p) => self.absorb(p),
                None => self.flush_stash(),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Scenario: plan + availability + network, named or from a file
// ---------------------------------------------------------------------

/// One named failure environment: chaos plan, availability model
/// parameters, network model, and (for socket runs) the raw-wire
/// adversaries to launch alongside the cohort. JSON-loadable so a
/// scenario file plus a config fully determines a run; see
/// [`Scenario::named`] for the built-in registry.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub ack_prob: f64,
    pub straggler_prob: f64,
    pub compute_mean_s: f64,
    pub compute_jitter: f64,
    pub availability_seed: Option<u64>,
    pub network: NetworkKind,
    pub chaos: Option<FaultPlan>,
    pub wire_adversaries: Vec<WireAdversary>,
}

/// The built-in scenario names, in registry order.
pub const NAMED_SCENARIOS: &[&str] = &[
    "clean",
    "lossy-uplink",
    "duplicator",
    "flaky-sessions",
    "byzantine-one",
    "chaos-soup",
    "scrambled-arrivals",
    "malformed-peers",
    "spoofed-tokens",
];

impl Scenario {
    /// The no-fault baseline every other scenario perturbs.
    pub fn clean(name: &str) -> Scenario {
        Scenario {
            name: name.to_string(),
            ack_prob: 1.0,
            straggler_prob: 0.0,
            compute_mean_s: 1.0,
            compute_jitter: 0.0,
            availability_seed: None,
            network: NetworkKind::Ideal,
            chaos: None,
            wire_adversaries: Vec::new(),
        }
    }

    /// Look up a built-in scenario by name.
    pub fn named(name: &str) -> Result<Scenario> {
        use WireAdversary::*;
        let mut s = Scenario::clean(name);
        match name {
            "clean" => {}
            "lossy-uplink" => {
                s.chaos = Some(FaultPlan {
                    seed: 0x10e5,
                    drop_prob: 0.3,
                    delay_prob: 0.1,
                    ..FaultPlan::default()
                });
            }
            "duplicator" => {
                s.chaos = Some(FaultPlan { seed: 0xd0b1e, dup_prob: 1.0, ..FaultPlan::default() });
            }
            "flaky-sessions" => {
                s.chaos = Some(FaultPlan {
                    seed: 0xf1a2,
                    disconnect_uplink_prob: 0.15,
                    disconnect_downlink_prob: 0.15,
                    ..FaultPlan::default()
                });
            }
            "byzantine-one" => {
                s.chaos = Some(FaultPlan {
                    seed: 0xb42,
                    byzantine_clients: vec![0],
                    ..FaultPlan::default()
                });
            }
            "chaos-soup" => {
                // the acceptance scenario: drops + duplicates + reorder +
                // one Byzantine peer, all from one seed
                s.chaos = Some(FaultPlan {
                    seed: 0x50f3,
                    drop_prob: 0.25,
                    dup_prob: 0.25,
                    reorder: true,
                    byzantine_clients: vec![2],
                    ..FaultPlan::default()
                });
            }
            "scrambled-arrivals" => {
                s.network = NetworkKind::Simulated;
                s.compute_jitter = 0.8;
                s.chaos = Some(FaultPlan { seed: 0x5c4a, reorder: true, ..FaultPlan::default() });
            }
            "malformed-peers" => {
                s.wire_adversaries = vec![BadMagic, MidFrameDisconnect, OverCapLength, BadVersion];
            }
            "spoofed-tokens" => {
                s.wire_adversaries =
                    vec![SpoofToken, RegisterUnknownId, RegisterDuplicateId, CrossClient];
            }
            other => {
                return Err(Error::invalid(format!(
                    "unknown scenario '{other}' (built-ins: {})",
                    NAMED_SCENARIOS.join(", ")
                )))
            }
        }
        Ok(s)
    }

    /// Resolve a CLI `--scenario` spec: a path to a JSON file if one
    /// exists there, otherwise a built-in name.
    pub fn resolve(spec: &str) -> Result<Scenario> {
        let path = std::path::Path::new(spec);
        if path.is_file() {
            let text = std::fs::read_to_string(path)?;
            return Scenario::from_json(&crate::util::json::parse(&text)?);
        }
        Scenario::named(spec)
    }

    /// Impose this scenario on an experiment config (chaos plan,
    /// availability parameters, network model). Wire adversaries are not
    /// config — the test harness launches them against the live socket.
    pub fn apply(&self, cfg: &mut crate::config::experiment::ExperimentConfig) {
        cfg.ack_prob = self.ack_prob;
        cfg.straggler_prob = self.straggler_prob;
        cfg.compute_mean_s = self.compute_mean_s;
        cfg.compute_jitter = self.compute_jitter;
        cfg.availability_seed = self.availability_seed;
        cfg.network = self.network;
        cfg.chaos = self.chaos.clone();
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(&self.name)),
            ("ack_prob", Json::num(self.ack_prob)),
            ("straggler_prob", Json::num(self.straggler_prob)),
            ("compute_mean_s", Json::num(self.compute_mean_s)),
            ("compute_jitter", Json::num(self.compute_jitter)),
            (
                "network",
                Json::str(match self.network {
                    NetworkKind::Ideal => "ideal",
                    NetworkKind::Simulated => "simulated",
                }),
            ),
        ];
        if let Some(seed) = self.availability_seed {
            pairs.push(("availability_seed", Json::num(seed as f64)));
        }
        if let Some(plan) = &self.chaos {
            pairs.push(("chaos", plan.to_json()));
        }
        if !self.wire_adversaries.is_empty() {
            pairs.push((
                "wire_adversaries",
                Json::Arr(self.wire_adversaries.iter().map(|a| Json::str(a.as_str())).collect()),
            ));
        }
        Json::obj(pairs)
    }

    pub fn from_json(root: &Json) -> Result<Scenario> {
        let mut s = Scenario::clean(root.get("name")?.as_str()?);
        let get_f64 = |k: &str, d: f64| -> Result<f64> {
            match root.opt(k) {
                Some(v) => v.as_f64(),
                None => Ok(d),
            }
        };
        s.ack_prob = get_f64("ack_prob", s.ack_prob)?;
        s.straggler_prob = get_f64("straggler_prob", s.straggler_prob)?;
        s.compute_mean_s = get_f64("compute_mean_s", s.compute_mean_s)?;
        s.compute_jitter = get_f64("compute_jitter", s.compute_jitter)?;
        if let Some(v) = root.opt("availability_seed") {
            s.availability_seed = Some(v.as_f64()? as u64);
        }
        s.network = match root.opt("network").map(|v| v.as_str()).transpose()? {
            None | Some("ideal") => NetworkKind::Ideal,
            Some("simulated") => NetworkKind::Simulated,
            Some(other) => return Err(Error::invalid(format!("bad network '{other}'"))),
        };
        if let Some(v) = root.opt("chaos") {
            s.chaos = Some(FaultPlan::from_json(v)?);
        }
        if let Some(v) = root.opt("wire_adversaries") {
            s.wire_adversaries = v
                .as_arr()?
                .iter()
                .map(|a| WireAdversary::parse(a.as_str()?))
                .collect::<Result<Vec<_>>>()?;
        }
        Ok(s)
    }
}

// ---------------------------------------------------------------------
// WireAdversary: the raw-socket attacks, as reusable scenario pieces
// ---------------------------------------------------------------------

/// One raw-wire attack against a live socket server. These are the
/// adversaries the one-off socket regressions used to hand-roll; as enum
/// variants they compose into [`Scenario`]s and run from one launcher.
/// Every variant must leave the server's round intact — `launch` returns
/// `Err` only when the server *mishandled* the attack (e.g. admitted a
/// session it must refuse).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireAdversary {
    /// Garbage bytes that are not even a frame header.
    BadMagic,
    /// A valid upload header promising a body, disconnected mid-body.
    MidFrameDisconnect,
    /// A declared frame length over the hard cap (must be rejected before
    /// any allocation).
    OverCapLength,
    /// Well-formed frames claiming unsupported versions (the dead v1 wire
    /// included).
    BadVersion,
    /// Well-formed upload frames with a missing (0) and a guessed session
    /// token — the pre-auth-refactor spoof.
    SpoofToken,
    /// A registration attempt for an id the server never allowed.
    RegisterUnknownId,
    /// A re-registration attempt for a live client id (first-come holds
    /// the session).
    RegisterDuplicateId,
    /// An upload through a *valid* session naming another client.
    CrossClient,
}

impl WireAdversary {
    pub fn as_str(&self) -> &'static str {
        match self {
            WireAdversary::BadMagic => "bad-magic",
            WireAdversary::MidFrameDisconnect => "mid-frame-disconnect",
            WireAdversary::OverCapLength => "over-cap-length",
            WireAdversary::BadVersion => "bad-version",
            WireAdversary::SpoofToken => "spoof-token",
            WireAdversary::RegisterUnknownId => "register-unknown-id",
            WireAdversary::RegisterDuplicateId => "register-duplicate-id",
            WireAdversary::CrossClient => "cross-client",
        }
    }

    pub fn parse(s: &str) -> Result<WireAdversary> {
        match s {
            "bad-magic" => Ok(WireAdversary::BadMagic),
            "mid-frame-disconnect" => Ok(WireAdversary::MidFrameDisconnect),
            "over-cap-length" => Ok(WireAdversary::OverCapLength),
            "bad-version" => Ok(WireAdversary::BadVersion),
            "spoof-token" => Ok(WireAdversary::SpoofToken),
            "register-unknown-id" => Ok(WireAdversary::RegisterUnknownId),
            "register-duplicate-id" => Ok(WireAdversary::RegisterDuplicateId),
            "cross-client" => Ok(WireAdversary::CrossClient),
            other => Err(Error::invalid(format!("unknown wire adversary '{other}'"))),
        }
    }

    /// Run this attack against a live server. `claims` is the cohort
    /// client id the attack impersonates, `via` a *different* registered
    /// client whose valid session the cross-client attack launders
    /// through, `round`/`p` shape the spoofed payloads. `Ok` means the
    /// attack was absorbed as the contract requires.
    pub fn launch(
        &self,
        server: &Loopback,
        claims: u32,
        via: u32,
        round: u32,
        p: usize,
    ) -> Result<()> {
        match self {
            WireAdversary::BadMagic => {
                raw_write(server.addr(), &[0xde, 0xad, 0xbe, 0xef, 0, 0, 0, 0, 1, 2, 3])
            }
            WireAdversary::MidFrameDisconnect => {
                // valid upload header promising 1000 bytes, 12 delivered,
                // then the connection drops
                let mut bytes = upload_header(1000);
                bytes.extend_from_slice(&[7u8; 12]);
                raw_write(server.addr(), &bytes)
            }
            WireAdversary::OverCapLength => raw_write(server.addr(), &upload_header(u32::MAX)),
            WireAdversary::BadVersion => {
                for bad_version in [FRAME_VERSION + 9, 1] {
                    let mut framed = frame_bytes(FrameKind::Upload, 0, b"future payload")?;
                    framed[2] = bad_version;
                    raw_write(server.addr(), &framed)?;
                }
                Ok(())
            }
            WireAdversary::SpoofToken => {
                let spoof = encode_update(claims, round, 9_999, &vec![9.0f32; p], Encoding::Dense);
                for token in [0u64, 0xdead_beef_cafe_f00d] {
                    raw_write(server.addr(), &frame_bytes(FrameKind::Upload, token, &spoof)?)?;
                }
                Ok(())
            }
            WireAdversary::RegisterUnknownId => refusal(ClientConn::connect(server.addr(), 77)),
            WireAdversary::RegisterDuplicateId => {
                refusal(ClientConn::connect(server.addr(), claims))
            }
            WireAdversary::CrossClient => {
                let cross = encode_update(claims, round, 1_000, &vec![5.0f32; p], Encoding::Dense);
                let conn = server.client_conn(via).ok_or_else(|| {
                    Error::transport(format!("client {via} has no live session to launder through"))
                })?;
                conn.upload(&cross)
            }
        }
    }
}

/// A registration attack succeeded iff the server *refused* it.
fn refusal(attempt: Result<ClientConn>) -> Result<()> {
    match attempt {
        Ok(_) => Err(Error::transport("server admitted a session it must refuse")),
        Err(e) => {
            let msg = e.to_string();
            if msg.contains("refused") || msg.contains("closed") {
                Ok(())
            } else {
                Err(e)
            }
        }
    }
}

/// A frame v2 upload header declaring `len` payload bytes (and nothing
/// else — the attacks control what, if anything, follows).
fn upload_header(len: u32) -> Vec<u8> {
    let mut header = vec![0u8; FRAME_HEADER_BYTES];
    header[..2].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    header[2] = FRAME_VERSION;
    header[3] = FrameKind::Upload as u8;
    header[12..16].copy_from_slice(&len.to_le_bytes());
    header
}

/// Open a raw connection to the server's address and write attack bytes,
/// dropping the connection immediately (the mid-frame disconnect is the
/// point for several adversaries).
fn raw_write(addr: &WireAddr, bytes: &[u8]) -> Result<()> {
    match addr {
        WireAddr::Tcp(a) => {
            let mut s = std::net::TcpStream::connect(a)?;
            s.write_all(bytes)?;
        }
        WireAddr::Uds(p) => {
            let mut s = std::os::unix::net::UnixStream::connect(p)?;
            s.write_all(bytes)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::link::InProcess;

    fn upload(client: u32, round: u32, p: usize) -> Vec<u8> {
        let params: Vec<f32> = (0..p).map(|i| i as f32 * 0.5 - 1.0).collect();
        encode_update(client, round, 10 + client, &params, Encoding::Dense)
    }

    #[test]
    fn fates_are_deterministic_and_cover_the_bands() {
        let plan = FaultPlan {
            seed: 0xfa7e,
            drop_prob: 0.2,
            dup_prob: 0.2,
            corrupt_prob: 0.2,
            delay_prob: 0.1,
            disconnect_uplink_prob: 0.1,
            byzantine_prob: 0.1,
            ..FaultPlan::default()
        };
        plan.validate().unwrap();
        let grid: Vec<UploadFate> =
            (0..40).flat_map(|r| (0..40).map(move |c| (r, c))).map(|(r, c)| plan.upload_fate(r, c)).collect();
        let again: Vec<UploadFate> =
            (0..40).flat_map(|r| (0..40).map(move |c| (r, c))).map(|(r, c)| plan.upload_fate(r, c)).collect();
        assert_eq!(grid, again, "fates must be pure functions of (seed, round, client)");
        for fate in [
            UploadFate::Deliver,
            UploadFate::Drop,
            UploadFate::Duplicate,
            UploadFate::Corrupt,
            UploadFate::Delay,
            UploadFate::DisconnectUplink,
            UploadFate::Byzantine,
        ] {
            assert!(grid.contains(&fate), "band {fate:?} never drawn over a 1600 grid");
        }
        // an inactive plan delivers everything
        let clean = FaultPlan::default();
        assert!(!clean.is_active());
        assert_eq!(clean.upload_fate(3, 7), UploadFate::Deliver);
        assert_eq!(clean.downlink_fate(3, 7), DownlinkFate::Deliver);
    }

    #[test]
    fn byzantine_roster_overrides_every_draw() {
        let plan = FaultPlan {
            seed: 1,
            drop_prob: 1.0,
            byzantine_clients: vec![4],
            ..FaultPlan::default()
        };
        for r in 0..10 {
            assert_eq!(plan.upload_fate(r, 4), UploadFate::Byzantine);
            assert_eq!(plan.upload_fate(r, 5), UploadFate::Drop);
        }
    }

    #[test]
    fn validation_rejects_bad_probabilities() {
        let mut plan = FaultPlan { drop_prob: 1.5, ..FaultPlan::default() };
        assert!(plan.validate().is_err());
        plan.drop_prob = -0.1;
        assert!(plan.validate().is_err());
        // exclusive bands: the sum may not exceed one draw
        let plan = FaultPlan { drop_prob: 0.6, dup_prob: 0.6, ..FaultPlan::default() };
        let err = plan.validate().unwrap_err();
        assert!(err.to_string().contains("sum"), "{err}");
    }

    #[test]
    fn fault_plan_json_round_trips() {
        let plan = FaultPlan {
            seed: 99,
            drop_prob: 0.25,
            dup_prob: 0.25,
            corrupt_prob: 0.1,
            delay_prob: 0.05,
            disconnect_uplink_prob: 0.05,
            disconnect_downlink_prob: 0.2,
            byzantine_prob: 0.1,
            byzantine_clients: vec![2, 7],
            reorder: true,
        };
        let back = FaultPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan);
        // from_json validates
        let bad = crate::util::json::parse(r#"{"drop_prob": 2.0}"#).unwrap();
        assert!(FaultPlan::from_json(&bad).is_err());
    }

    #[test]
    fn chaos_log_drains_per_round_in_canonical_order() {
        let log = ChaosLog::default();
        let ev = |round, client, kind| FaultEvent { round, client, kind, bytes: 8 };
        log.record(ev(2, 5, FaultKind::DropUpload));
        log.record(ev(1, 9, FaultKind::DuplicateUpload));
        log.record(ev(1, 3, FaultKind::ByzantineUpload));
        log.record(ev(1, 3, FaultKind::DropUpload));
        let round1 = log.take_round(1);
        assert_eq!(
            round1.events,
            vec![
                ev(1, 3, FaultKind::DropUpload),
                ev(1, 3, FaultKind::ByzantineUpload),
                ev(1, 9, FaultKind::DuplicateUpload),
            ]
        );
        // round 2's event survived the drain, and draining twice is empty
        assert_eq!(log.take_round(1), FaultLog::default());
        assert_eq!(log.take_round(2).events, vec![ev(2, 5, FaultKind::DropUpload)]);
    }

    #[test]
    fn sink_executes_fates_and_logs_them() {
        // client 1 is Byzantine by roster; everyone else duplicates
        let plan = Arc::new(FaultPlan {
            seed: 7,
            dup_prob: 1.0,
            byzantine_clients: vec![1],
            ..FaultPlan::default()
        });
        let log = Arc::new(ChaosLog::default());
        let mut t =
            ChaosTransport::new(Box::new(InProcess::new()), Arc::clone(&plan), Arc::clone(&log));
        let sink = t.sink();
        t.begin_round(5);
        let p = 6;
        for c in 0..3u32 {
            sink.send(upload(c, 1, p)).unwrap();
        }
        // 2 dup'd clients deliver twice, the Byzantine one once
        let got: Vec<Vec<u8>> = (0..5).map(|_| t.recv().unwrap()).collect();
        let dup0 = got.iter().filter(|g| **g == upload(0, 1, p)).count();
        let dup2 = got.iter().filter(|g| **g == upload(2, 1, p)).count();
        assert_eq!((dup0, dup2), (2, 2), "duplicates must cross the wire twice");
        let forged: Vec<&Vec<u8>> = got
            .iter()
            .filter(|g| peek_header(g).map(|h| h.client) == Some(1))
            .collect();
        assert_eq!(forged.len(), 1);
        let h = peek_header(forged[0]).unwrap();
        assert_ne!(h.p as usize, p, "Byzantine forgery must carry the wrong width");
        let faults = log.take_round(1);
        let kinds: Vec<FaultKind> = faults.events.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![FaultKind::DuplicateUpload, FaultKind::ByzantineUpload, FaultKind::DuplicateUpload]
        );
    }

    #[test]
    fn corrupt_payloads_are_deterministic_and_detectably_broken() {
        let plan = Arc::new(FaultPlan { seed: 3, corrupt_prob: 1.0, ..FaultPlan::default() });
        let log = Arc::new(ChaosLog::default());
        let collect = |plan: &Arc<FaultPlan>, log: &Arc<ChaosLog>| -> Vec<Vec<u8>> {
            let mut t =
                ChaosTransport::new(Box::new(InProcess::new()), Arc::clone(plan), Arc::clone(log));
            let sink = t.sink();
            t.begin_round(8);
            for c in 0..8u32 {
                sink.send(upload(c, 2, 9)).unwrap();
            }
            (0..8).map(|_| t.recv().unwrap()).collect()
        };
        let first = collect(&plan, &log);
        let second = collect(&plan, &log);
        assert_eq!(first, second, "corruption must be seeded, not random");
        for (c, mangled) in first.iter().enumerate() {
            let clean = upload(c as u32, 2, 9);
            assert_ne!(*mangled, clean, "client {c}: payload not corrupted");
            // detectably corrupt: header unparseable, short, or flagged by
            // the driver's expect-mask (fate is Corrupt) — never foldable
            // as a clean update under a different identity
            if let Some(h) = peek_header(mangled) {
                assert_eq!(h.client, c as u32, "corruption must not forge another client");
            }
        }
    }

    #[test]
    fn reorder_window_shuffles_deterministically_and_loses_nothing() {
        let plan = Arc::new(FaultPlan { seed: 11, reorder: true, ..FaultPlan::default() });
        // three rounds of eight: six shuffle windows, so a seed whose every
        // window happens to be the identity permutation is ~(1/24)^6
        let run = || -> Vec<Vec<u8>> {
            let mut t = ChaosTransport::new(
                Box::new(InProcess::new()),
                Arc::clone(&plan),
                Arc::new(ChaosLog::default()),
            );
            let sink = t.sink();
            let mut got = Vec::new();
            for round in 1..=3u32 {
                t.begin_round(8);
                for c in 0..8u32 {
                    sink.send(upload(c, round, 4)).unwrap();
                }
                got.extend((0..8).map(|_| t.recv().unwrap()));
            }
            got
        };
        let first = run();
        let second = run();
        assert_eq!(first, second, "reorder must be seeded");
        let arrival: Vec<Vec<u8>> =
            (1..=3u32).flat_map(|r| (0..8u32).map(move |c| upload(c, r, 4))).collect();
        assert_ne!(first, arrival, "24 uploads over 3 rounds should actually scramble");
        let mut sorted = first.clone();
        sorted.sort();
        let mut sent = arrival.clone();
        sent.sort();
        assert_eq!(sorted, sent, "reordering must not lose or alter payloads");
    }

    #[test]
    fn downlink_disconnect_swallows_the_broadcast_and_logs_it() {
        let plan =
            Arc::new(FaultPlan { seed: 5, disconnect_downlink_prob: 1.0, ..FaultPlan::default() });
        let log = Arc::new(ChaosLog::default());
        let mut t =
            ChaosTransport::new(Box::new(InProcess::new()), Arc::clone(&plan), Arc::clone(&log));
        t.register_clients(&[0]).unwrap();
        let broadcast = encode_update(u32::MAX, 7, 0, &[0.5f32; 4], Encoding::Dense);
        t.send_downlink(0, Arc::new(broadcast.clone())).unwrap();
        let err = t.downlink().recv(0, Duration::from_millis(30)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        let faults = log.take_round(7);
        assert_eq!(faults.events.len(), 1);
        assert_eq!(faults.events[0].kind, FaultKind::DisconnectDownlink);
        assert_eq!(faults.events[0].bytes, broadcast.len());
    }

    #[test]
    fn named_scenarios_resolve_and_round_trip_through_json() {
        for name in NAMED_SCENARIOS {
            let s = Scenario::named(name).unwrap();
            assert_eq!(&s.name, name);
            if let Some(plan) = &s.chaos {
                plan.validate().unwrap();
            }
            let back = Scenario::from_json(&s.to_json()).unwrap();
            assert_eq!(back, s, "{name}: JSON round trip changed the scenario");
        }
        assert!(Scenario::named("carrier-pigeon").is_err());
        // the acceptance scenario composes all four headline faults
        let soup = Scenario::named("chaos-soup").unwrap().chaos.unwrap();
        assert!(soup.drop_prob > 0.0 && soup.dup_prob > 0.0 && soup.reorder);
        assert_eq!(soup.byzantine_clients, vec![2]);
    }

    #[test]
    fn wire_adversary_spellings_round_trip() {
        use WireAdversary::*;
        for adv in [
            BadMagic,
            MidFrameDisconnect,
            OverCapLength,
            BadVersion,
            SpoofToken,
            RegisterUnknownId,
            RegisterDuplicateId,
            CrossClient,
        ] {
            assert_eq!(WireAdversary::parse(adv.as_str()).unwrap(), adv);
        }
        assert!(WireAdversary::parse("ddos").is_err());
    }

    #[test]
    fn scenario_file_resolution_prefers_the_file() {
        let dir = std::env::temp_dir().join(format!("fedmask_scenario_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("my.json");
        let mut s = Scenario::clean("from-file");
        s.chaos = Some(FaultPlan { seed: 123, drop_prob: 0.5, ..FaultPlan::default() });
        std::fs::write(&path, s.to_json().to_pretty()).unwrap();
        let loaded = Scenario::resolve(path.to_str().unwrap()).unwrap();
        assert_eq!(loaded, s);
        // a non-path spec falls back to the registry
        assert_eq!(Scenario::resolve("clean").unwrap(), Scenario::clean("clean"));
        assert!(Scenario::resolve("no-such-scenario").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
