//! The federated-learning core — the paper's L3 contribution.
//!
//! * [`sampling`] — client sampling schedules: the FedAvg **static** rate
//!   (Alg. 1) and the paper's **dynamic exponential decay** (Alg. 3,
//!   Eq. 3), plus linear/step decay ablations.
//! * [`masking`] — upload masking policies: none, **random** (Alg. 2) and
//!   **selective top-k by |delta|** (Alg. 4), with both the exact rust
//!   implementation and the L1 Pallas kernel path.
//! * [`pipeline`] — the fused mask→stream hot path: selective masking
//!   emitted directly as a `MaskedStream` (kept pairs plus census
//!   sideband) for the single-pass encoder — no dense masked vector on
//!   the upload path (see `docs/SCALE.md` §"Hot path & memory").
//! * [`aggregate`] — streaming weighted federated averaging (Eq. 2): the
//!   [`aggregate::Aggregator`] trait folds decoded wire updates as they
//!   arrive (O(p) state, O(nnz) per sparse fold for FedAvg; buffering
//!   attentive), order-independently.
//! * [`client`] — simulated on-device training: receives the round's
//!   encoded broadcast from the transport's downlink half (decoding /
//!   delta-reconstructing it), runs local epochs + masking, and uploads
//!   an encoded `WireUpdate` payload — no dense parameter vector crosses
//!   the client↔server boundary in either direction.
//! * [`driver`] — the engine-free round state machine (sample →
//!   broadcast → collect → finalize): transport + per-client sessions,
//!   downlink encoding and pushes, the streaming upload drain, and the
//!   cost ledger, as separately testable phases.
//! * [`tree`] — parallel tree aggregation: `S` shard-local aggregator
//!   folds on worker threads, each decoding its own clients' payloads,
//!   merged bitwise-exactly at the root via [`aggregate::Aggregator::merge`]
//!   (see `docs/SCALE.md`).
//! * [`server`] — the simulation shell around the driver: data, the
//!   engine pool, job fan-out, evaluation, the virtual clock, records.
//! * [`chaos`] — the deterministic chaos harness: a seeded
//!   [`chaos::FaultPlan`] executed by a [`chaos::ChaosTransport`] wrapper
//!   (drops, duplicates, reordering, corruption, disconnects, Byzantine
//!   uploads), composed with availability and network models into named,
//!   JSON-loadable [`chaos::Scenario`]s (see `docs/CHAOS.md`).

pub mod aggregate;
pub mod chaos;
pub mod client;
pub mod driver;
pub mod masking;
pub mod pipeline;
pub mod sampling;
pub mod server;
pub mod tree;

pub use aggregate::{
    make_aggregator, Aggregator, Contribution, SparseContribution, StreamingFedAvg,
};
pub use chaos::{ChaosLog, ChaosTransport, FaultKind, FaultLog, FaultPlan, Scenario, WireAdversary};
pub use client::receive_broadcast;
pub use driver::{Cohort, Collected, RoundCost, RoundDriver, RoundWire};
pub use tree::ShardedAggregator;
pub use masking::{MaskEngine, MaskPolicy, MaskScope, MaskScratch, MaskTarget};
pub use pipeline::mask_stream_selective;
pub use sampling::SamplingSchedule;
pub use server::{Server, ServerOutcome};
