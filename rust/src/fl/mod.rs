//! The federated-learning core — the paper's L3 contribution.
//!
//! * [`sampling`] — client sampling schedules: the FedAvg **static** rate
//!   (Alg. 1) and the paper's **dynamic exponential decay** (Alg. 3,
//!   Eq. 3), plus linear/step decay ablations.
//! * [`masking`] — upload masking policies: none, **random** (Alg. 2) and
//!   **selective top-k by |delta|** (Alg. 4), with both the exact rust
//!   implementation and the L1 Pallas kernel path.
//! * [`aggregate`] — streaming weighted federated averaging (Eq. 2): the
//!   [`aggregate::Aggregator`] trait folds decoded wire updates as they
//!   arrive (O(p) state, O(nnz) per sparse fold for FedAvg; buffering
//!   attentive), order-independently.
//! * [`client`] — simulated on-device training (local epochs + masking +
//!   upload encoding); returns an encoded `WireUpdate` payload, never a
//!   dense parameter vector.
//! * [`server`] — the round loop: sample, ACK, broadcast (optionally
//!   delta-encoded), fan local training out over the engine pool, decode +
//!   fold uploads in completion order, account, evaluate.

pub mod aggregate;
pub mod client;
pub mod masking;
pub mod sampling;
pub mod server;

pub use aggregate::{
    make_aggregator, Aggregator, Contribution, SparseContribution, StreamingFedAvg,
};
pub use masking::{MaskEngine, MaskPolicy, MaskScope, MaskScratch, MaskTarget};
pub use sampling::SamplingSchedule;
pub use server::{Server, ServerOutcome};
