//! Parallel tree aggregation: shard-local folds, one bitwise-exact merge.
//!
//! The serial drain decodes and folds every upload on the round loop's
//! thread — O(sum_i nnz_i) of varint parsing, dequantization, and
//! fixed-point accumulation that a 10k-client cohort serializes behind one
//! core. [`ShardedAggregator`] splits that work by client: `S` worker
//! threads each own a shard-local [`Aggregator`] partial and a private
//! [`DecodeScratch`], and consume their own clients' *undecoded* payload
//! bytes from a bounded channel as the round loop routes them
//! ([`shard_of`] — the same hash that shards sessions, so one client's
//! state lives in one shard everywhere). At [`ShardedAggregator::finish`]
//! the partials are merged at the root via [`Aggregator::merge`] and
//! finished once.
//!
//! ## Why the result is exactly the serial one
//!
//! `StreamingFedAvg`'s state is integer sums on a fixed-point grid, and
//! integer addition is associative and commutative — so *any* partition of
//! the cohort into shard partials, merged in *any* order, produces the
//! same accumulator bits as the single-threaded fold, and therefore the
//! same `finish` output bit for bit. Parallelism here is free of the
//! usual float-reassociation caveat by construction. The property tests
//! in `fl::aggregate` and `tests/properties.rs` pin this across shard
//! counts, mask targets, and all wire encodings; `benches/transport.rs`
//! and `benches/aggregation.rs` measure the speedup at 1k–10k simulated
//! clients.
//!
//! ## Failure semantics
//!
//! A worker that hits a decode or fold error stops consuming and returns
//! the error. The round loop learns of it at the next
//! [`ShardedAggregator::route`] to that shard (its channel reports
//! disconnected and the worker is joined for the concrete error) or at
//! `finish`, whichever comes first — either way the round fails with the
//! worker's typed error, mirroring the serial path where a fold error
//! fails `collect` directly. Note one deliberate difference: the serial
//! drain can *reject* an undecodable stray payload and keep waiting on a
//! foreign-peer transport, because it decodes before folding. The sharded
//! drain validates the fixed header on the round loop (round, cohort
//! membership, duplicates, width — see `fl::driver`) but ships the body
//! undecoded, so a payload that passes those checks *and* session auth
//! yet carries a corrupt body fails the round. Reaching that state
//! requires an authenticated session uploading garbage under its own
//! name — an internal bug, which should fail loudly.

use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::fl::aggregate::{Aggregator, Contribution, SparseContribution};
use crate::transport::codec::{decode_update_view_cached, BodyView, DecodeScratch};
use crate::transport::session::{shard_of, IndexCache};
use crate::util::error::{Error, Result};

/// Bounded per-shard payload queue: deep enough to absorb a burst of
/// arrivals, small enough that a stalled worker backpressures the drain
/// loop instead of buffering the whole cohort in memory.
const SHARD_QUEUE_SLOTS: usize = 64;

/// Fold one decoded payload view into `agg` — the same dispatch the serial
/// drain performs, factored out so both paths stay identical. `cache` is
/// the uploading session's cross-round index cache (wire v3
/// `SparseCached` decodes against it; stateless payloads ignore it).
pub(crate) fn fold_view(
    agg: &mut dyn Aggregator,
    payload: &[u8],
    scratch: &mut DecodeScratch,
    cache: Option<&IndexCache>,
) -> Result<()> {
    let view = decode_update_view_cached(payload, scratch, cache)?;
    match view.body {
        BodyView::Dense(params) => agg.fold(Contribution {
            client: view.client as usize,
            params,
            n_samples: view.n_samples,
        }),
        BodyView::Sparse { indices, values } => agg.fold_sparse(SparseContribution {
            client: view.client as usize,
            p: view.p,
            indices,
            values,
            n_samples: view.n_samples,
        }),
    }
}

/// `S` shard-local aggregation folds on worker threads, merged
/// bitwise-exactly at the root. See the module doc for the exactness
/// argument and failure semantics.
pub struct ShardedAggregator {
    txs: Vec<SyncSender<(Vec<u8>, Option<Arc<IndexCache>>)>>,
    workers: Vec<Option<JoinHandle<Result<Box<dyn Aggregator>>>>>,
    routed: usize,
}

impl ShardedAggregator {
    /// Spawn one worker per partial. Build the partials with
    /// `make_aggregator` — one per shard, all from the same round state —
    /// so every shard folds under the identical configuration `merge`
    /// requires.
    pub fn spawn(partials: Vec<Box<dyn Aggregator>>) -> Result<ShardedAggregator> {
        if partials.is_empty() {
            return Err(Error::invalid("tree aggregation needs at least one shard"));
        }
        let mut txs = Vec::with_capacity(partials.len());
        let mut workers = Vec::with_capacity(partials.len());
        for (i, mut agg) in partials.into_iter().enumerate() {
            let (tx, rx) =
                sync_channel::<(Vec<u8>, Option<Arc<IndexCache>>)>(SHARD_QUEUE_SLOTS);
            let handle = std::thread::Builder::new()
                .name(format!("fedmask-agg-{i}"))
                .spawn(move || -> Result<Box<dyn Aggregator>> {
                    let mut scratch = DecodeScratch::default();
                    // recv errors only on disconnect: every tx dropped,
                    // i.e. finish() (or an aborted round) — clean exit.
                    while let Ok((payload, cache)) = rx.recv() {
                        fold_view(agg.as_mut(), &payload, &mut scratch, cache.as_deref())?;
                    }
                    Ok(agg)
                })
                .map_err(|e| Error::Engine(format!("failed to spawn aggregation shard: {e}")))?;
            txs.push(tx);
            workers.push(Some(handle));
        }
        Ok(ShardedAggregator { txs, workers, routed: 0 })
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Payloads routed so far (the sharded analog of
    /// [`Aggregator::folded`] — folds the workers have *accepted*, not
    /// necessarily completed yet).
    pub fn routed(&self) -> usize {
        self.routed
    }

    /// Ship one validated, undecoded payload — plus the uploading
    /// session's index cache, which its shard worker decodes any
    /// `SparseCached` body against — to its client's shard. Blocks only
    /// when that shard's bounded queue is full (backpressure). If the
    /// shard's worker already failed, joins it and returns its concrete
    /// error — the round fails with the real cause, not a channel error.
    pub fn route(
        &mut self,
        client: u32,
        payload: Vec<u8>,
        cache: Option<Arc<IndexCache>>,
    ) -> Result<()> {
        let s = shard_of(client, self.txs.len());
        if self.txs[s].send((payload, cache)).is_err() {
            return Err(self.worker_error(s));
        }
        self.routed += 1;
        Ok(())
    }

    /// The concrete error of a worker whose channel reported disconnect.
    fn worker_error(&mut self, shard: usize) -> Error {
        match self.workers[shard].take().map(JoinHandle::join) {
            Some(Ok(Err(e))) => e,
            Some(Ok(Ok(_))) => {
                Error::Engine(format!("aggregation shard {shard} exited before the round ended"))
            }
            Some(Err(_)) => Error::Engine(format!("aggregation shard {shard} panicked")),
            None => Error::Engine(format!("aggregation shard {shard} already failed")),
        }
    }

    /// Close the queues, join every worker, merge the partials in shard
    /// order at the root, and finish. The first worker error (every worker
    /// is still joined) fails the round.
    pub fn finish(mut self) -> Result<Vec<f32>> {
        // dropping the senders disconnects every shard's queue; workers
        // drain what is buffered, then exit with their partial
        self.txs.clear();
        let mut partials: Vec<Box<dyn Aggregator>> = Vec::with_capacity(self.workers.len());
        let mut first_err: Option<Error> = None;
        for (i, slot) in self.workers.iter_mut().enumerate() {
            let Some(handle) = slot.take() else {
                first_err
                    .get_or_insert_with(|| Error::Engine(format!("aggregation shard {i} already failed")));
                continue;
            };
            match handle.join() {
                Ok(Ok(agg)) => partials.push(agg),
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err
                        .get_or_insert_with(|| Error::Engine(format!("aggregation shard {i} panicked")));
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let mut root = partials.remove(0);
        for partial in partials {
            root.merge(partial)?;
        }
        root.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::AggregatorKind;
    use crate::fl::aggregate::make_aggregator;
    use crate::fl::masking::MaskTarget;
    use crate::runtime::manifest::LayerInfo;
    use crate::transport::codec::{encode_update, Encoding};
    use crate::util::prop::Gen;

    fn one_layer(size: usize) -> Vec<LayerInfo> {
        vec![LayerInfo {
            name: "w".into(),
            shape: vec![size],
            offset: 0,
            size,
            masked: true,
        }]
    }

    fn masked_update(g: &mut Gen, p: usize, density: f32) -> Vec<f32> {
        (0..p)
            .map(|_| if g.f32_in(0.0, 1.0) < density { g.f32_in(-2.0, 2.0) } else { 0.0 })
            .collect()
    }

    #[test]
    fn threaded_sharded_fold_is_bitwise_equal_to_flat_fold() {
        let mut g = Gen::new(0x7ee5);
        let p = 96;
        let layers = one_layer(p);
        let broadcast = g.normal_vec(p);
        let payloads: Vec<(u32, Vec<u8>)> = (0..24u32)
            .map(|c| {
                let v = masked_update(&mut g, p, 0.3);
                let enc = *g.choose(Encoding::ALL);
                (c, encode_update(c, 1, 10 + c, &v, enc))
            })
            .collect();
        for target in [MaskTarget::Weights, MaskTarget::Delta] {
            let mut flat =
                make_aggregator(AggregatorKind::FedAvg, target, &broadcast, &layers).unwrap();
            let mut scratch = DecodeScratch::default();
            for (_, payload) in &payloads {
                fold_view(flat.as_mut(), payload, &mut scratch, None).unwrap();
            }
            let reference = flat.finish().unwrap();
            for shards in [1usize, 2, 8] {
                let partials: Vec<Box<dyn Aggregator>> = (0..shards)
                    .map(|_| {
                        make_aggregator(AggregatorKind::FedAvg, target, &broadcast, &layers)
                            .unwrap()
                    })
                    .collect();
                let mut tree = ShardedAggregator::spawn(partials).unwrap();
                assert_eq!(tree.shards(), shards);
                for (c, payload) in &payloads {
                    tree.route(*c, payload.clone(), None).unwrap();
                }
                assert_eq!(tree.routed(), payloads.len());
                let merged = tree.finish().unwrap();
                assert_eq!(merged, reference, "shards {shards} target {target:?}");
            }
        }
    }

    #[test]
    fn worker_decode_error_fails_finish_with_the_concrete_cause() {
        let partials: Vec<Box<dyn Aggregator>> =
            vec![Box::new(crate::fl::aggregate::StreamingFedAvg::new(4))];
        let mut tree = ShardedAggregator::spawn(partials).unwrap();
        tree.route(0, vec![0xde, 0xad, 0xbe, 0xef], None).unwrap();
        let err = tree.finish().unwrap_err();
        assert!(matches!(err, Error::Parse(_) | Error::Invalid(_)), "{err}");
    }

    #[test]
    fn route_after_worker_death_surfaces_the_worker_error() {
        let partials: Vec<Box<dyn Aggregator>> =
            vec![Box::new(crate::fl::aggregate::StreamingFedAvg::new(4))];
        let mut tree = ShardedAggregator::spawn(partials).unwrap();
        tree.route(0, vec![1, 2, 3], None).unwrap();
        // the worker dies on the garbage; keep routing until the channel
        // reports it (the queue may accept a few sends first)
        let good = encode_update(0, 1, 5, &[1.0, 0.0, 0.0, 0.0], Encoding::Auto);
        let mut surfaced = None;
        for _ in 0..SHARD_QUEUE_SLOTS + 2 {
            if let Err(e) = tree.route(0, good.clone(), None) {
                surfaced = Some(e);
                break;
            }
        }
        let err = surfaced.expect("worker death must surface through route");
        assert!(matches!(err, Error::Parse(_) | Error::Invalid(_)), "{err}");
    }

    #[test]
    fn spawn_rejects_zero_shards() {
        assert!(ShardedAggregator::spawn(Vec::new()).is_err());
    }
}
