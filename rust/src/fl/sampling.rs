//! Client sampling schedules (paper §3.2 and §4.1).
//!
//! Static sampling keeps the FedAvg fraction `C` for every round; the
//! paper's dynamic sampling anneals it exponentially,
//! `c(t) = C / exp(beta * t)` (Eq. 3), trading late-round participation for
//! communication. Linear and step decay are included as ablations (the
//! "declining rate ... can be chosen accordingly" remark in §4.1).
//!
//! Round indexing follows the paper: `t` starts at 1 (Alg. 3 line 6), so
//! the first dynamic round already pays the `exp(-beta)` discount.

use crate::util::error::{Error, Result};

/// A sampling-rate schedule over rounds.
#[derive(Debug, Clone, PartialEq)]
pub enum SamplingSchedule {
    /// Alg. 1: constant fraction `c0`.
    Static { c0: f64 },
    /// Alg. 3 / Eq. 3: `c0 / exp(beta * t)`.
    DynamicExp { c0: f64, beta: f64 },
    /// Ablation: `c0 * max(0, 1 - slope * t)`.
    DynamicLinear { c0: f64, slope: f64 },
    /// Ablation: multiply by `factor` every `every` rounds.
    DynamicStep { c0: f64, every: usize, factor: f64 },
}

impl SamplingSchedule {
    /// Parse from config strings: `static`, `dynamic-exp`, `dynamic-linear`,
    /// `dynamic-step`. `every` is the step schedule's decay period in
    /// rounds (config key `sampling_every`, default 10) — the other
    /// schedules have no period and ignore it. Validated ≥ 1 like every
    /// other schedule parameter.
    pub fn from_config(kind: &str, c0: f64, param: f64, every: usize) -> Result<SamplingSchedule> {
        let s = match kind {
            "static" => SamplingSchedule::Static { c0 },
            "dynamic-exp" => SamplingSchedule::DynamicExp { c0, beta: param },
            "dynamic-linear" => SamplingSchedule::DynamicLinear { c0, slope: param },
            "dynamic-step" => SamplingSchedule::DynamicStep {
                c0,
                every,
                factor: param,
            },
            other => {
                return Err(Error::invalid(format!(
                    "unknown sampling schedule '{other}'"
                )))
            }
        };
        s.validate()?;
        Ok(s)
    }

    pub fn validate(&self) -> Result<()> {
        let c0 = self.c0();
        if !(0.0 < c0 && c0 <= 1.0) {
            return Err(Error::invalid(format!("sampling c0 {c0} not in (0, 1]")));
        }
        match self {
            SamplingSchedule::DynamicExp { beta, .. } if *beta < 0.0 => {
                Err(Error::invalid("beta must be >= 0"))
            }
            SamplingSchedule::DynamicLinear { slope, .. } if *slope < 0.0 => {
                Err(Error::invalid("slope must be >= 0"))
            }
            SamplingSchedule::DynamicStep { every, factor, .. }
                if *every == 0 || !(0.0..=1.0).contains(factor) =>
            {
                Err(Error::invalid("step schedule needs every >= 1, factor in [0,1]"))
            }
            _ => Ok(()),
        }
    }

    pub fn c0(&self) -> f64 {
        match self {
            SamplingSchedule::Static { c0 }
            | SamplingSchedule::DynamicExp { c0, .. }
            | SamplingSchedule::DynamicLinear { c0, .. }
            | SamplingSchedule::DynamicStep { c0, .. } => *c0,
        }
    }

    /// Sampling rate at round `t` (1-based, per the paper).
    pub fn rate(&self, t: usize) -> f64 {
        assert!(t >= 1, "rounds are 1-based");
        match self {
            SamplingSchedule::Static { c0 } => *c0,
            SamplingSchedule::DynamicExp { c0, beta } => c0 / (beta * t as f64).exp(),
            SamplingSchedule::DynamicLinear { c0, slope } => {
                (c0 * (1.0 - slope * t as f64)).max(0.0)
            }
            SamplingSchedule::DynamicStep { c0, every, factor } => {
                c0 * factor.powi((t / every) as i32)
            }
        }
    }

    /// Number of clients to select at round `t` from `m` registered:
    /// `max(rate * M, 1)` per Alg. 1/3, with the paper's floor of two
    /// clients for dynamic schedules (§4.1) expressed via `min_clients`.
    pub fn num_clients(&self, t: usize, m: usize, min_clients: usize) -> usize {
        let raw = (self.rate(t) * m as f64).round() as usize;
        raw.max(1).max(min_clients).min(m)
    }

    /// The paper's default client floor: 1 for static, 2 for dynamic.
    pub fn default_min_clients(&self) -> usize {
        match self {
            SamplingSchedule::Static { .. } => 1,
            _ => 2,
        }
    }

    /// Human label for figure legends.
    pub fn label(&self) -> String {
        match self {
            SamplingSchedule::Static { c0 } => format!("static(C={c0})"),
            SamplingSchedule::DynamicExp { c0, beta } => format!("dynamic(C={c0},beta={beta})"),
            SamplingSchedule::DynamicLinear { c0, slope } => {
                format!("linear(C={c0},slope={slope})")
            }
            SamplingSchedule::DynamicStep { c0, every, factor } => {
                format!("step(C={c0},every={every},x{factor})")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn static_rate_is_constant() {
        let s = SamplingSchedule::Static { c0: 0.3 };
        for t in 1..100 {
            assert_eq!(s.rate(t), 0.3);
        }
    }

    #[test]
    fn dynamic_exp_matches_eq3() {
        let s = SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.1 };
        for t in [1usize, 10, 31] {
            let want = 1.0 / (0.1 * t as f64).exp();
            assert!((s.rate(t) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn paper_example_31_vs_10_epochs() {
        // §5.2: "with a decay coefficient of 0.1 and the same amount of
        // transportation cost, the dynamic method can update 31 epochs,
        // while static method can only train 10 epochs"
        let dynamic = SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.1 };
        let static_cost_10: f64 = 10.0; // 10 rounds at rate 1.0
        let dynamic_cost_31: f64 = (1..=31).map(|t| dynamic.rate(t)).sum();
        assert!(
            dynamic_cost_31 <= static_cost_10,
            "31 dynamic rounds ({dynamic_cost_31:.2}) should cost <= 10 static rounds"
        );
        let dynamic_cost_32: f64 = (1..=32).map(|t| dynamic.rate(t)).sum();
        // 31 is the last round within the budget, consistent with the paper
        assert!(dynamic_cost_32 > static_cost_10 * 0.9);
    }

    #[test]
    fn num_clients_floor_behaviour() {
        let s = SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.5 };
        // late rounds decay below 2/M; the paper floors at two clients
        assert_eq!(s.num_clients(50, 100, 2), 2);
        assert_eq!(s.num_clients(1, 100, 2), 61); // 100/e^0.5 ~ 60.7
        // never exceeds m even when the floor would demand more
        assert_eq!(s.num_clients(50, 2, 2), 2);
        assert_eq!(s.num_clients(1, 3, 2), 2); // round(0.61 * 3) = 2
        assert_eq!(s.default_min_clients(), 2);
        assert_eq!(SamplingSchedule::Static { c0: 0.1 }.default_min_clients(), 1);
    }

    #[test]
    fn step_and_linear_decay() {
        let step = SamplingSchedule::DynamicStep {
            c0: 1.0,
            every: 10,
            factor: 0.5,
        };
        assert_eq!(step.rate(5), 1.0);
        assert_eq!(step.rate(10), 0.5);
        assert_eq!(step.rate(25), 0.25);
        let lin = SamplingSchedule::DynamicLinear { c0: 1.0, slope: 0.02 };
        assert!((lin.rate(25) - 0.5).abs() < 1e-12);
        assert_eq!(lin.rate(100), 0.0);
    }

    #[test]
    fn config_parsing_and_validation() {
        assert!(SamplingSchedule::from_config("static", 0.5, 0.0, 10).is_ok());
        assert!(SamplingSchedule::from_config("dynamic-exp", 1.0, 0.1, 10).is_ok());
        assert!(SamplingSchedule::from_config("bogus", 1.0, 0.1, 10).is_err());
        assert!(SamplingSchedule::from_config("static", 0.0, 0.0, 10).is_err());
        assert!(SamplingSchedule::from_config("static", 1.5, 0.0, 10).is_err());
        assert!(SamplingSchedule::from_config("dynamic-exp", 1.0, -0.1, 10).is_err());
    }

    #[test]
    fn step_period_is_threaded_through_config_not_hardcoded() {
        // regression: `every` used to be silently pinned to 10, so the
        // config's period had no effect
        let s = SamplingSchedule::from_config("dynamic-step", 1.0, 0.5, 3).unwrap();
        assert_eq!(
            s,
            SamplingSchedule::DynamicStep { c0: 1.0, every: 3, factor: 0.5 }
        );
        assert_eq!(s.rate(2), 1.0);
        assert_eq!(s.rate(3), 0.5);
        assert_eq!(s.rate(6), 0.25);
        // the period is validated like every other parameter
        assert!(SamplingSchedule::from_config("dynamic-step", 1.0, 0.5, 0).is_err());
        // non-step schedules have no period and ignore the knob
        assert_eq!(
            SamplingSchedule::from_config("dynamic-exp", 1.0, 0.1, 0).unwrap(),
            SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.1 }
        );
    }

    #[test]
    fn prop_rate_monotone_nonincreasing_and_bounded() {
        check("schedule monotonicity", 100, |g| {
            let c0 = g.f64_in(0.05, 1.0);
            let s = match g.usize_in(0, 2) {
                0 => SamplingSchedule::DynamicExp {
                    c0,
                    beta: g.f64_in(0.0, 1.0),
                },
                1 => SamplingSchedule::DynamicLinear {
                    c0,
                    slope: g.f64_in(0.0, 0.05),
                },
                _ => SamplingSchedule::DynamicStep {
                    c0,
                    every: g.usize_in(1, 20),
                    factor: g.f64_in(0.1, 1.0),
                },
            };
            let mut prev = f64::INFINITY;
            for t in 1..=100 {
                let r = s.rate(t);
                assert!(r <= prev + 1e-12, "rate must not increase");
                assert!((0.0..=1.0 + 1e-12).contains(&r));
                prev = r;
            }
        });
    }

    #[test]
    fn prop_num_clients_within_bounds() {
        check("num_clients bounds", 100, |g| {
            let m = g.usize_in(2, 500);
            let s = SamplingSchedule::DynamicExp {
                c0: g.f64_in(0.05, 1.0),
                beta: g.f64_in(0.0, 1.0),
            };
            let min = g.usize_in(1, 2);
            for t in 1..=50 {
                let n = s.num_clients(t, m, min);
                assert!(n >= min.min(m) && n <= m, "n={n} m={m} min={min}");
            }
        });
    }
}
