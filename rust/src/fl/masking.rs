//! Upload masking policies (paper §3.2.1 and §4.2).
//!
//! * **Random masking** (Alg. 2): each maskable layer keeps a random
//!   `gamma` fraction of entries (`randi` in the paper), seeded per
//!   (client, round) so runs replay exactly.
//! * **Selective masking** (Alg. 4): keep the `gamma` fraction with the
//!   largest `|W_{t+1} - W_t|` per layer (Eq. 4–5).
//!
//! Selective masking has two interchangeable implementations:
//! the **L1 Pallas kernel** baked into each model's `mask` artifact
//! (threshold bisection; the production path), and an **exact rust**
//! `select_nth_unstable` fallback used as a baseline, for property tests
//! (kernel vs. exact), and by the masking criterion bench.
//!
//! `MaskTarget` selects what is masked: the paper-literal `Weights`
//! (Alg. 2/4 zero entries of `W_{t+1}` itself) or the production-sane
//! `Delta` variant (send `W_t + M (x) (W_{t+1} - W_t)`, i.e. a sparse
//! delta the server can apply losslessly) — an ablation DESIGN.md §4
//! calls out.

use crate::runtime::manifest::LayerInfo;
use crate::sim::rng::Rng;
use crate::util::error::{Error, Result};

/// What gets masked.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskTarget {
    /// Paper-literal: upload `M (x) W_{t+1}` (zeros replace dropped weights).
    Weights,
    /// Ablation: upload `W_t + M (x) delta` (dropped weights keep their old
    /// value server-side; the wire carries the sparse delta).
    Delta,
}

/// Top-k scope for selective masking.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskScope {
    /// Per-layer top-k, exactly Alg. 4's layer loop (default).
    PerLayer,
    /// Single global top-k over all maskable parameters (ablation).
    Global,
}

/// Which implementation computes the selective mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskEngine {
    /// The AOT Pallas kernel (`{model}_mask.hlo.txt`) — production path.
    Hlo,
    /// Exact rust select_nth — baseline/oracle.
    Rust,
}

/// The masking policy attached to an experiment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MaskPolicy {
    /// Upload everything (vanilla FedAvg).
    None,
    /// Alg. 2: random keep of rate `gamma`.
    Random { gamma: f32 },
    /// Alg. 4: top-k keep of rate `gamma` by |delta|.
    Selective {
        gamma: f32,
        engine: MaskEngine,
        scope: MaskScope,
    },
}

impl MaskPolicy {
    pub fn selective(gamma: f32) -> MaskPolicy {
        MaskPolicy::Selective {
            gamma,
            engine: MaskEngine::Hlo,
            scope: MaskScope::PerLayer,
        }
    }

    pub fn random(gamma: f32) -> MaskPolicy {
        MaskPolicy::Random { gamma }
    }

    pub fn gamma(&self) -> f32 {
        match self {
            MaskPolicy::None => 1.0,
            MaskPolicy::Random { gamma } | MaskPolicy::Selective { gamma, .. } => *gamma,
        }
    }

    pub fn validate(&self) -> Result<()> {
        let g = self.gamma();
        if !(0.0 < g && g <= 1.0) {
            return Err(Error::invalid(format!("masking gamma {g} not in (0, 1]")));
        }
        Ok(())
    }

    /// From config strings: `none`, `random`, `selective`, `selective-rust`,
    /// `selective-global`.
    pub fn from_config(kind: &str, gamma: f32) -> Result<MaskPolicy> {
        let p = match kind {
            "none" => MaskPolicy::None,
            "random" => MaskPolicy::Random { gamma },
            "selective" => MaskPolicy::selective(gamma),
            "selective-rust" => MaskPolicy::Selective {
                gamma,
                engine: MaskEngine::Rust,
                scope: MaskScope::PerLayer,
            },
            "selective-global" => MaskPolicy::Selective {
                gamma,
                engine: MaskEngine::Rust,
                scope: MaskScope::Global,
            },
            other => return Err(Error::invalid(format!("unknown masking '{other}'"))),
        };
        p.validate()?;
        Ok(p)
    }

    pub fn label(&self) -> String {
        match self {
            MaskPolicy::None => "nomask".into(),
            MaskPolicy::Random { gamma } => format!("random(g={gamma})"),
            MaskPolicy::Selective { gamma, engine, scope } => format!(
                "selective(g={gamma},{}{})",
                match engine {
                    MaskEngine::Hlo => "hlo",
                    MaskEngine::Rust => "rust",
                },
                match scope {
                    MaskScope::PerLayer => "",
                    MaskScope::Global => ",global",
                }
            ),
        }
    }
}

// ----------------------------------------------------------------------
// Rust implementations (exact oracle + random)
// ----------------------------------------------------------------------

/// Keep-count for a segment of `size` entries at rate `gamma` —
/// `round(gamma * size)`, the convention shared with the Pallas kernel,
/// clamped to the segment boundaries: a non-empty segment with any
/// positive keep rate always keeps at least one entry (gamma -> 0 must
/// not silently zero a whole layer), and the count never exceeds the
/// segment size (gamma -> 1 with float round-off must not overrun).
pub fn keep_count(size: usize, gamma: f32) -> usize {
    if size == 0 || gamma <= 0.0 {
        return 0;
    }
    let k = ((gamma as f64) * size as f64).round() as usize;
    k.clamp(1, size)
}

/// Reusable scratch arena for the exact selective-mask path. One of these
/// per engine-pool worker means steady-state masking allocates nothing per
/// client per round: the per-segment |delta| buffer, its partition copy,
/// and the global-scope gather buffers all reuse their capacity.
#[derive(Debug, Default)]
pub struct MaskScratch {
    /// |w_new - w_old| per segment entry, in segment order.
    pub(crate) deltas: Vec<f32>,
    /// Partition workspace for `select_nth_unstable` (kept separate so
    /// `deltas` stays index-aligned with the segment).
    pub(crate) part: Vec<f32>,
    /// Global-scope gather buffers.
    pub(crate) gather_idx: Vec<usize>,
    pub(crate) gather_new: Vec<f32>,
    pub(crate) gather_old: Vec<f32>,
}

/// Descending k-th-largest partition over `part` (clobbered): returns the
/// keep threshold and the count of strictly-above-threshold entries — the
/// seed for the tie budget (`kept`) that the keep walk increments. This is
/// the single source of truth for selective-mask tie-breaking, shared by
/// the staged masker below and the fused pipeline (`fl::pipeline`), so the
/// two paths cannot drift. Requires `1 <= k <= part.len()`.
pub(crate) fn segment_threshold(part: &mut [f32], k: usize) -> (f32, usize) {
    debug_assert!(1 <= k && k <= part.len());
    // threshold = k-th largest |delta|; after the descending partition every
    // strictly-above-threshold element sits in the prefix [0, k-1), so the
    // tie budget comes straight from the partition — no second O(n) pass.
    let (above, nth, _) = part.select_nth_unstable_by(k - 1, |a, b| b.partial_cmp(a).unwrap());
    let t = *nth;
    (t, above.iter().filter(|d| **d > t).count())
}

/// Exact selective mask of one flat segment: zero all but the top-k
/// |w_new - w_old| entries of `w_new[seg]`. O(n) via select_nth_unstable.
fn selective_mask_segment(w_new: &mut [f32], w_old: &[f32], gamma: f32, scratch: &mut MaskScratch) {
    let n = w_new.len();
    let k = keep_count(n, gamma);
    if k >= n {
        return;
    }
    if k == 0 {
        w_new.fill(0.0);
        return;
    }
    scratch.deltas.clear();
    scratch
        .deltas
        .extend(w_new.iter().zip(w_old).map(|(n, o)| (n - o).abs()));
    scratch.part.clear();
    scratch.part.extend_from_slice(&scratch.deltas);
    let (thresh, mut kept) = segment_threshold(&mut scratch.part, k);
    // keep d >= thresh, but cap kept count at k to resolve ties exactly
    // like the sort-based oracle (first-come within equal values).
    for (w, &d) in w_new.iter_mut().zip(scratch.deltas.iter()) {
        let keep = if d > thresh {
            true
        } else if d == thresh && kept < k {
            kept += 1;
            true
        } else {
            false
        };
        if !keep {
            *w = 0.0;
        }
    }
}

/// Exact rust selective masking over the layer table (the oracle the HLO
/// kernel path is property-tested against). Allocates its scratch per call;
/// hot paths hold a [`MaskScratch`] and use [`selective_mask_rust_with`].
pub fn selective_mask_rust(
    w_new: &[f32],
    w_old: &[f32],
    gamma: f32,
    layers: &[LayerInfo],
    scope: MaskScope,
) -> Vec<f32> {
    selective_mask_rust_with(w_new, w_old, gamma, layers, scope, &mut MaskScratch::default())
}

/// [`selective_mask_rust`] with a caller-held scratch arena (reused across
/// segments, clients, and rounds by the engine-pool workers).
pub fn selective_mask_rust_with(
    w_new: &[f32],
    w_old: &[f32],
    gamma: f32,
    layers: &[LayerInfo],
    scope: MaskScope,
    scratch: &mut MaskScratch,
) -> Vec<f32> {
    assert_eq!(w_new.len(), w_old.len());
    let mut out = w_new.to_vec();
    match scope {
        MaskScope::PerLayer => {
            for l in layers {
                if l.masked {
                    let seg = l.offset..l.offset + l.size;
                    selective_mask_segment(&mut out[seg.clone()], &w_old[seg], gamma, scratch);
                }
            }
        }
        MaskScope::Global => {
            // gather maskable entries, mask jointly, scatter back (buffers
            // taken out of the scratch so it can also serve the segment call)
            let mut idx = std::mem::take(&mut scratch.gather_idx);
            let mut gathered_new = std::mem::take(&mut scratch.gather_new);
            let mut gathered_old = std::mem::take(&mut scratch.gather_old);
            idx.clear();
            gathered_new.clear();
            gathered_old.clear();
            idx.extend(
                layers
                    .iter()
                    .filter(|l| l.masked)
                    .flat_map(|l| l.offset..l.offset + l.size),
            );
            gathered_new.extend(idx.iter().map(|&i| w_new[i]));
            gathered_old.extend(idx.iter().map(|&i| w_old[i]));
            selective_mask_segment(&mut gathered_new, &gathered_old, gamma, scratch);
            for (j, &i) in idx.iter().enumerate() {
                out[i] = gathered_new[j];
            }
            scratch.gather_idx = idx;
            scratch.gather_new = gathered_new;
            scratch.gather_old = gathered_old;
        }
    }
    out
}

/// Random masking (Alg. 2): Bernoulli(gamma) keep per entry of each
/// maskable layer, derived from `rng` (seeded per client/round upstream).
pub fn random_mask_rust(w_new: &[f32], gamma: f32, layers: &[LayerInfo], rng: &mut Rng) -> Vec<f32> {
    let mut out = w_new.to_vec();
    for l in layers {
        if !l.masked {
            continue;
        }
        for v in &mut out[l.offset..l.offset + l.size] {
            if rng.next_f32() >= gamma {
                *v = 0.0;
            }
        }
    }
    out
}

/// Convert a masked-weights vector into the `Delta` target form:
/// positions the mask dropped revert to `w_old` instead of zero.
/// (A dropped position is one where masked == 0 but w_old != 0 — exact
/// because kept entries are w_new verbatim and true zeros are untouched.)
pub fn apply_delta_target(masked: &[f32], w_old: &[f32], layers: &[LayerInfo]) -> Vec<f32> {
    let mut out = masked.to_vec();
    for l in layers {
        if !l.masked {
            continue;
        }
        for i in l.offset..l.offset + l.size {
            if masked[i] == 0.0 {
                out[i] = w_old[i];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn layers_of(sizes: &[(usize, bool)]) -> Vec<LayerInfo> {
        let mut out = Vec::new();
        let mut offset = 0;
        for (i, &(size, masked)) in sizes.iter().enumerate() {
            out.push(LayerInfo {
                name: format!("l{i}"),
                shape: vec![size],
                offset,
                size,
                masked,
            });
            offset += size;
        }
        out
    }

    fn gen_pair(g: &mut Gen, n: usize) -> (Vec<f32>, Vec<f32>) {
        (g.normal_vec(n), g.normal_vec(n))
    }

    #[test]
    fn selective_keeps_exactly_k_per_layer() {
        check("selective exact k", 60, |g| {
            let n = g.usize_in(4, 800);
            let gamma = g.f32_in(0.05, 1.0);
            let (wn, wo) = gen_pair(g, n);
            let layers = layers_of(&[(n, true)]);
            let out = selective_mask_rust(&wn, &wo, gamma, &layers, MaskScope::PerLayer);
            let kept = out.iter().filter(|v| **v != 0.0).count();
            // exact-to-the-tie: continuous data means kept == k (unless a
            // kept w_new is exactly 0.0, measure-zero for normals)
            assert_eq!(kept, keep_count(n, gamma).min(n), "seed {:#x}", g.seed);
        });
    }

    #[test]
    fn selective_dominance_property() {
        check("selective dominance", 60, |g| {
            let n = g.usize_in(10, 500);
            let gamma = g.f32_in(0.1, 0.9);
            let (wn, wo) = gen_pair(g, n);
            let layers = layers_of(&[(n, true)]);
            let out = selective_mask_rust(&wn, &wo, gamma, &layers, MaskScope::PerLayer);
            let kept_min = out
                .iter()
                .zip(&wn)
                .zip(&wo)
                .filter(|((o, _), _)| **o != 0.0)
                .map(|((_, n), o)| (n - o).abs())
                .fold(f32::INFINITY, f32::min);
            let dropped_max = out
                .iter()
                .zip(&wn)
                .zip(&wo)
                .filter(|((o, _), _)| **o == 0.0)
                .map(|((_, n), o)| (n - o).abs())
                .fold(0.0f32, f32::max);
            assert!(kept_min >= dropped_max, "kept {kept_min} < dropped {dropped_max}");
        });
    }

    #[test]
    fn unmasked_layers_pass_through() {
        let layers = layers_of(&[(100, true), (10, false), (100, true)]);
        let mut g = Gen::new(1);
        let (wn, wo) = gen_pair(&mut g, 210);
        let out = selective_mask_rust(&wn, &wo, 0.2, &layers, MaskScope::PerLayer);
        assert_eq!(&out[100..110], &wn[100..110]);
    }

    #[test]
    fn global_scope_moves_budget_across_layers() {
        // layer A has huge deltas, layer B tiny ones; global top-k should
        // spend nearly all keeps in A
        let layers = layers_of(&[(100, true), (100, true)]);
        let wo = vec![0.0f32; 200];
        let mut wn = vec![0.0f32; 200];
        for i in 0..100 {
            wn[i] = 10.0 + i as f32; // layer A: big deltas
            wn[100 + i] = 0.001 * (i + 1) as f32; // layer B: small
        }
        let global = selective_mask_rust(&wn, &wo, 0.5, &layers, MaskScope::Global);
        let kept_a = global[..100].iter().filter(|v| **v != 0.0).count();
        let kept_b = global[100..].iter().filter(|v| **v != 0.0).count();
        assert_eq!(kept_a, 100);
        assert_eq!(kept_b, 0);
        // per-layer keeps 50/50 by construction
        let per = selective_mask_rust(&wn, &wo, 0.5, &layers, MaskScope::PerLayer);
        assert_eq!(per[..100].iter().filter(|v| **v != 0.0).count(), 50);
        assert_eq!(per[100..].iter().filter(|v| **v != 0.0).count(), 50);
    }

    #[test]
    fn random_mask_rate_and_determinism() {
        let layers = layers_of(&[(20_000, true)]);
        let wn = vec![1.0f32; 20_000];
        let a = random_mask_rust(&wn, 0.3, &layers, &mut Rng::new(5));
        let b = random_mask_rust(&wn, 0.3, &layers, &mut Rng::new(5));
        assert_eq!(a, b);
        let kept = a.iter().filter(|v| **v != 0.0).count() as f64 / 20_000.0;
        assert!((kept - 0.3).abs() < 0.02, "kept {kept}");
        let c = random_mask_rust(&wn, 0.3, &layers, &mut Rng::new(6));
        assert_ne!(a, c);
    }

    #[test]
    fn delta_target_restores_old_values() {
        let layers = layers_of(&[(6, true)]);
        let wo = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let masked = vec![9.0, 0.0, 9.0, 0.0, 0.0, 9.0];
        let out = apply_delta_target(&masked, &wo, &layers);
        assert_eq!(out, vec![9.0, 2.0, 9.0, 4.0, 5.0, 9.0]);
    }

    #[test]
    fn gamma_one_is_identity() {
        let layers = layers_of(&[(50, true)]);
        let mut g = Gen::new(2);
        let (wn, wo) = gen_pair(&mut g, 50);
        let out = selective_mask_rust(&wn, &wo, 1.0, &layers, MaskScope::PerLayer);
        assert_eq!(out, wn);
    }

    #[test]
    fn policy_validation_and_labels() {
        assert!(MaskPolicy::from_config("selective", 0.5).is_ok());
        assert!(MaskPolicy::from_config("random", 0.0).is_err());
        assert!(MaskPolicy::from_config("bogus", 0.5).is_err());
        assert!(MaskPolicy::selective(0.3).label().contains("selective"));
        assert_eq!(MaskPolicy::None.gamma(), 1.0);
    }

    #[test]
    fn keep_count_gamma_to_zero_never_empties_a_nonempty_layer() {
        // the rounded count would be 0 — a layer must still keep one entry
        assert_eq!(keep_count(1000, 1e-6), 1);
        assert_eq!(keep_count(3, 0.01), 1);
        assert_eq!(keep_count(1, 0.001), 1);
        // exact zero rate (invalid per policy validation) and empty layers
        // legitimately keep nothing
        assert_eq!(keep_count(5, 0.0), 0);
        assert_eq!(keep_count(0, 0.5), 0);
        // and the mask path honors the floor
        let layers = layers_of(&[(64, true)]);
        let mut g = Gen::new(3);
        let (wn, wo) = gen_pair(&mut g, 64);
        let out = selective_mask_rust(&wn, &wo, 0.001, &layers, MaskScope::PerLayer);
        assert_eq!(out.iter().filter(|v| **v != 0.0).count(), 1);
    }

    #[test]
    fn keep_count_gamma_to_one_never_exceeds_layer_size() {
        assert_eq!(keep_count(1000, 1.0), 1000);
        assert_eq!(keep_count(7, 0.999_999), 7);
        assert_eq!(keep_count(0, 1.0), 0);
        // mask path: gamma ~ 1 is identity on a non-degenerate layer
        let layers = layers_of(&[(50, true)]);
        let mut g = Gen::new(4);
        let (wn, wo) = gen_pair(&mut g, 50);
        let out = selective_mask_rust(&wn, &wo, 0.999_999, &layers, MaskScope::PerLayer);
        assert_eq!(out, wn);
    }

    #[test]
    fn scratch_reuse_is_identical_to_fresh_scratch() {
        // one worker-held arena across many (client, round) mask calls must
        // never change a bit of the output
        let mut scratch = MaskScratch::default();
        let mut g = Gen::new(9);
        for _ in 0..10 {
            let n = g.usize_in(8, 300);
            let gamma = g.f32_in(0.05, 0.95);
            let (wn, wo) = gen_pair(&mut g, n);
            let layers = layers_of(&[(n / 2, true), (n - n / 2, true)]);
            for scope in [MaskScope::PerLayer, MaskScope::Global] {
                let fresh = selective_mask_rust(&wn, &wo, gamma, &layers, scope);
                let reused =
                    selective_mask_rust_with(&wn, &wo, gamma, &layers, scope, &mut scratch);
                assert_eq!(fresh, reused);
            }
        }
    }

    #[test]
    fn tie_handling_caps_at_k() {
        // all deltas identical -> ties everywhere; kept must still be k
        let layers = layers_of(&[(10, true)]);
        let wo = vec![0.0f32; 10];
        let wn = vec![2.0f32; 10];
        let out = selective_mask_rust(&wn, &wo, 0.5, &layers, MaskScope::PerLayer);
        assert_eq!(out.iter().filter(|v| **v != 0.0).count(), 5);
    }
}
