//! Weighted federated averaging (paper §3.1), as a **streaming** operation.
//!
//! The aggregation rule is FedAvg's sample-weighted mean,
//! `Theta_{t+1} = sum_i (n_i / n) Theta_t^i` — Eq. 2 of the paper modulo its
//! extra `1/m` factor, which would shrink the aggregate by the cohort size
//! and contradicts both Eq. 1 and the cited McMahan et al.; DESIGN.md §4
//! records this as a presumed typo. Masked uploads are averaged exactly as
//! received (zeros included), which is the paper-literal semantics of
//! Alg. 2/4.
//!
//! Since the transport refactor the server no longer barriers on the full
//! cohort: decoded [`crate::transport::codec::WireUpdate`] payloads are
//! folded into an [`Aggregator`] as they arrive, in whatever order the
//! engine pool completes them. Two implementations:
//!
//! * [`StreamingFedAvg`] — O(p) server memory (one fixed-point accumulator
//!   per parameter, no per-client buffering). The weighted numerator
//!   `sum_i n_i * v_ij` accumulates in 128-bit fixed point (scale 2^-64),
//!   so folds are integer additions — associative and commutative — and the
//!   result is **bit-identical for every arrival order**. The fixed-point
//!   grid is exact while `|sum_i n_i * v_ij| < 2^63` per coordinate, far
//!   beyond any realistic cohort; the per-fold rounding error is below
//!   2^-65, invisible at f32 output resolution.
//! * [`BufferingAttentive`] — attentive aggregation (Ji et al. [11]) needs
//!   the whole cohort to form its softmax weights, so it buffers decoded
//!   updates (O(k*p), inherent to the rule) and canonicalizes by client id
//!   at `finish`, which restores arrival-order independence.
//!
//! The inner fold is the aggregation hot path (P-length multiply-adds); the
//! criterion bench `aggregation` tracks it, including streaming-vs-barrier.

use crate::runtime::manifest::LayerInfo;
use crate::util::error::{Error, Result};

/// One client's contribution to a round (a decoded, reconstructed update).
#[derive(Debug, Clone)]
pub struct Contribution<'a> {
    /// Originating client id (from the wire header; canonical sort key for
    /// buffering aggregators).
    pub client: usize,
    pub params: &'a [f32],
    /// Local training-sample count n_i (the FedAvg weight).
    pub n_samples: u32,
}

/// Streaming, order-insensitive aggregation: fold decoded updates as they
/// arrive, then finish into the next global model.
pub trait Aggregator {
    /// Fold one client's update into the running aggregate.
    fn fold(&mut self, contrib: Contribution<'_>) -> Result<()>;

    /// Number of contributions folded so far.
    fn folded(&self) -> usize;

    /// Heap bytes currently held by the aggregation state (the benchmark's
    /// O(p)-vs-O(k*p) memory evidence).
    fn state_bytes(&self) -> usize;

    /// Consume the aggregator and produce the new global model.
    fn finish(self: Box<Self>) -> Result<Vec<f32>>;
}

/// Build the configured aggregator for one round.
pub fn make_aggregator(
    kind: crate::config::experiment::AggregatorKind,
    global: &[f32],
    layers: &[LayerInfo],
) -> Box<dyn Aggregator> {
    match kind {
        crate::config::experiment::AggregatorKind::FedAvg => {
            Box::new(StreamingFedAvg::new(global.len()))
        }
        crate::config::experiment::AggregatorKind::Attentive { temp } => {
            Box::new(BufferingAttentive::new(global, layers, temp))
        }
    }
}

/// Fixed-point scale of the streaming FedAvg accumulator: products
/// `n_i * v_ij` are rounded to multiples of 2^-64 before the (integer,
/// therefore order-independent) accumulation.
const FIXED_POINT_SCALE: f64 = 18_446_744_073_709_551_616.0; // 2^64

/// A diverged client's update (NaN/inf) must fail loudly in every
/// aggregator — the FedAvg float->int cast would silently zero NaN and
/// the attentive softmax would propagate it into the whole global model.
fn check_finite(contrib: &Contribution<'_>) -> Result<()> {
    if contrib.params.iter().any(|v| !v.is_finite()) {
        return Err(Error::invalid(format!(
            "non-finite update from client {}",
            contrib.client
        )));
    }
    Ok(())
}

/// Sample-weighted FedAvg with O(p) state and arrival-order-independent
/// accumulation (see the module doc for the fixed-point argument).
pub struct StreamingFedAvg {
    /// Per-parameter weighted numerator `sum_i n_i * v_ij`, fixed point.
    acc: Vec<i128>,
    total_samples: u64,
    folded: usize,
}

impl StreamingFedAvg {
    pub fn new(p: usize) -> StreamingFedAvg {
        StreamingFedAvg {
            acc: vec![0i128; p],
            total_samples: 0,
            folded: 0,
        }
    }
}

impl Aggregator for StreamingFedAvg {
    fn fold(&mut self, contrib: Contribution<'_>) -> Result<()> {
        if contrib.params.len() != self.acc.len() {
            return Err(Error::invalid("contribution length mismatch"));
        }
        check_finite(&contrib)?;
        // Weighted products must stay inside the fixed-point grid
        // (|n_i * v| < 2^62 per coordinate): beyond it the float->int cast
        // would saturate silently — that magnitude only means a diverged
        // client, which must fail loudly.
        const GRID_LIMIT: f64 = 4.611_686_018_427_387_9e18; // 2^62
        let n = contrib.n_samples as f64;
        for (slot, &v) in self.acc.iter_mut().zip(contrib.params) {
            let x = n * v as f64;
            if x.abs() >= GRID_LIMIT {
                return Err(Error::invalid(format!(
                    "update magnitude from client {} exceeds the aggregation range",
                    contrib.client
                )));
            }
            *slot = slot
                .checked_add((x * FIXED_POINT_SCALE).round() as i128)
                .ok_or_else(|| Error::invalid("aggregation accumulator overflow"))?;
        }
        self.total_samples += contrib.n_samples as u64;
        self.folded += 1;
        Ok(())
    }

    fn folded(&self) -> usize {
        self.folded
    }

    fn state_bytes(&self) -> usize {
        self.acc.capacity() * std::mem::size_of::<i128>()
    }

    fn finish(self: Box<Self>) -> Result<Vec<f32>> {
        if self.folded == 0 {
            return Err(Error::invalid("cannot aggregate zero contributions"));
        }
        if self.total_samples == 0 {
            return Err(Error::invalid("total sample count is zero"));
        }
        let total = self.total_samples as f64;
        Ok(self
            .acc
            .iter()
            .map(|&a| ((a as f64 / FIXED_POINT_SCALE) / total) as f32)
            .collect())
    }
}

/// Attentive aggregation as an [`Aggregator`]: buffers decoded updates
/// (O(k*p) — the rule needs every client's distance before any weight is
/// known) and sorts by client id at finish so the result does not depend on
/// arrival order.
pub struct BufferingAttentive {
    global: Vec<f32>,
    layers: Vec<LayerInfo>,
    temp: f64,
    buffered: Vec<(usize, u32, Vec<f32>)>,
}

impl BufferingAttentive {
    pub fn new(global: &[f32], layers: &[LayerInfo], temp: f64) -> BufferingAttentive {
        BufferingAttentive {
            global: global.to_vec(),
            layers: layers.to_vec(),
            temp,
            buffered: Vec::new(),
        }
    }
}

impl Aggregator for BufferingAttentive {
    fn fold(&mut self, contrib: Contribution<'_>) -> Result<()> {
        if contrib.params.len() != self.global.len() {
            return Err(Error::invalid("contribution length mismatch"));
        }
        check_finite(&contrib)?;
        self.buffered
            .push((contrib.client, contrib.n_samples, contrib.params.to_vec()));
        Ok(())
    }

    fn folded(&self) -> usize {
        self.buffered.len()
    }

    fn state_bytes(&self) -> usize {
        self.global.capacity() * 4
            + self
                .buffered
                .iter()
                .map(|(_, _, v)| v.capacity() * 4)
                .sum::<usize>()
    }

    fn finish(mut self: Box<Self>) -> Result<Vec<f32>> {
        self.buffered.sort_by_key(|(client, _, _)| *client);
        let contribs: Vec<Contribution> = self
            .buffered
            .iter()
            .map(|(client, n_samples, params)| Contribution {
                client: *client,
                params,
                n_samples: *n_samples,
            })
            .collect();
        attentive_mean(&self.global, &contribs, &self.layers, self.temp)
    }
}

/// Barrier-style sample-weighted mean: folds `contribs` through
/// [`StreamingFedAvg`] in the given order and finishes. Because the fold is
/// order-independent, this is the reference the streamed server path is
/// asserted bit-identical against.
pub fn weighted_mean(contribs: &[Contribution]) -> Result<Vec<f32>> {
    if contribs.is_empty() {
        return Err(Error::invalid("cannot aggregate zero contributions"));
    }
    let mut agg = StreamingFedAvg::new(contribs[0].params.len());
    for c in contribs {
        agg.fold(c.clone())?;
    }
    Box::new(agg).finish()
}

/// Unweighted mean (Eq. 1) — kept for the uniform-shard fast path and the
/// ablation bench comparing the two rules.
pub fn uniform_mean(contribs: &[Contribution]) -> Result<Vec<f32>> {
    if contribs.is_empty() {
        return Err(Error::invalid("cannot aggregate zero contributions"));
    }
    let p = contribs[0].params.len();
    if contribs.iter().any(|c| c.params.len() != p) {
        return Err(Error::invalid("contribution length mismatch"));
    }
    let w = 1.0f64 / contribs.len() as f64;
    let mut acc = vec![0.0f64; p];
    for c in contribs {
        for (slot, &v) in acc.iter_mut().zip(c.params) {
            *slot += w * v as f64;
        }
    }
    Ok(acc.into_iter().map(|v| v as f32).collect())
}

/// Attentive aggregation (Ji et al. [11], the paper's cited improvement to
/// vanilla FedAvg): per layer, clients whose update stays closer to the
/// current global model get larger softmax weights,
/// `a_i = softmax(-d_i / (T * mean(d)))` with `d_i = ||Theta_i^l - Theta^l||_2`.
/// Normalizing by the mean distance makes the temperature `temp`
/// scale-free. Exposed as `aggregator = "attentive"` in the config and in
/// the ablation driver; downweights divergent/outlier clients.
pub fn attentive_mean(
    global: &[f32],
    contribs: &[Contribution],
    layers: &[LayerInfo],
    temp: f64,
) -> Result<Vec<f32>> {
    if contribs.is_empty() {
        return Err(Error::invalid("cannot aggregate zero contributions"));
    }
    if contribs.iter().any(|c| c.params.len() != global.len()) {
        return Err(Error::invalid("contribution length mismatch"));
    }
    if !(temp > 0.0) {
        return Err(Error::invalid("temperature must be positive"));
    }
    let mut out = vec![0.0f32; global.len()];
    for l in layers {
        let seg = l.offset..l.offset + l.size;
        // per-client L2 distance to the global layer
        let dists: Vec<f64> = contribs
            .iter()
            .map(|c| {
                c.params[seg.clone()]
                    .iter()
                    .zip(&global[seg.clone()])
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt()
            })
            .collect();
        let mean_d = dists.iter().sum::<f64>() / dists.len() as f64;
        let scale = if mean_d > 0.0 { temp * mean_d } else { 1.0 };
        let logits: Vec<f64> = dists.iter().map(|d| -d / scale).collect();
        let max_logit = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = logits.iter().map(|z| (z - max_logit).exp()).collect();
        let z: f64 = exps.iter().sum();
        for (c, w) in contribs.iter().zip(exps.iter().map(|e| e / z)) {
            for (slot, &v) in out[seg.clone()].iter_mut().zip(&c.params[seg.clone()]) {
                *slot += (w * v as f64) as f32;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn one_layer(size: usize) -> Vec<LayerInfo> {
        vec![LayerInfo {
            name: "w".into(),
            shape: vec![size],
            offset: 0,
            size,
            masked: true,
        }]
    }

    fn contrib(client: usize, params: &[f32], n_samples: u32) -> Contribution<'_> {
        Contribution {
            client,
            params,
            n_samples,
        }
    }

    #[test]
    fn attentive_equal_contribs_is_identity() {
        let global = vec![0.0f32; 8];
        let a = vec![1.0f32; 8];
        let contribs = vec![contrib(0, &a, 1), contrib(1, &a, 1)];
        let out = attentive_mean(&global, &contribs, &one_layer(8), 1.0).unwrap();
        for v in out {
            assert!((v - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn attentive_downweights_outlier() {
        let global = vec![0.0f32; 16];
        let near: Vec<f32> = vec![0.1; 16];
        let far: Vec<f32> = vec![10.0; 16];
        let contribs = vec![contrib(0, &near, 1), contrib(1, &near, 1), contrib(2, &far, 1)];
        let attn = attentive_mean(&global, &contribs, &one_layer(16), 0.5).unwrap();
        let plain = uniform_mean(&contribs).unwrap();
        assert!(
            attn[0] < plain[0],
            "attentive {} should pull toward the near majority vs mean {}",
            attn[0],
            plain[0]
        );
    }

    #[test]
    fn attentive_rejects_bad_inputs() {
        let global = vec![0.0f32; 4];
        assert!(attentive_mean(&global, &[], &one_layer(4), 1.0).is_err());
        let a = vec![1.0f32; 4];
        let c = vec![contrib(0, &a, 1)];
        assert!(attentive_mean(&global, &c, &one_layer(4), 0.0).is_err());
    }

    #[test]
    fn equal_weights_reduce_to_plain_mean() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![3.0f32, 4.0, 5.0];
        let out = weighted_mean(&[contrib(0, &a, 10), contrib(1, &b, 10)]).unwrap();
        assert_eq!(out, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn weights_follow_sample_counts() {
        let a = vec![0.0f32];
        let b = vec![4.0f32];
        let out = weighted_mean(&[contrib(0, &a, 3), contrib(1, &b, 1)]).unwrap();
        assert!((out[0] - 1.0).abs() < 1e-7);
    }

    #[test]
    fn rejects_degenerate_inputs() {
        assert!(weighted_mean(&[]).is_err());
        let a = vec![1.0f32, 2.0];
        let b = vec![1.0f32];
        assert!(weighted_mean(&[contrib(0, &a, 1), contrib(1, &b, 1)]).is_err());
        assert!(weighted_mean(&[contrib(0, &a, 0)]).is_err());
    }

    #[test]
    fn diverged_client_fails_loudly_instead_of_zeroing() {
        let nan = vec![1.0f32, f32::NAN];
        let inf = vec![f32::INFINITY, 0.0];
        // finite but beyond the fixed-point grid: saturating would corrupt
        let huge = vec![1e25f32, 0.0];
        assert!(weighted_mean(&[contrib(3, &nan, 1)]).is_err());
        let mut agg = StreamingFedAvg::new(2);
        assert!(agg.fold(contrib(3, &inf, 1)).is_err());
        assert_eq!(agg.folded(), 0);
        let mut agg = StreamingFedAvg::new(2);
        assert!(agg.fold(contrib(3, &huge, 500)).is_err());
        // the attentive buffer enforces the same invariant
        let mut attn = BufferingAttentive::new(&[0.0f32, 0.0], &one_layer(2), 1.0);
        assert!(attn.fold(contrib(3, &nan, 1)).is_err());
        assert_eq!(attn.folded(), 0);
    }

    #[test]
    fn single_contribution_is_identity() {
        let a = vec![1.5f32, -2.5, 0.0];
        let out = weighted_mean(&[contrib(0, &a, 7)]).unwrap();
        assert_eq!(out, a);
    }

    #[test]
    fn prop_mean_within_value_envelope() {
        check("aggregate envelope", 80, |g| {
            let p = g.usize_in(1, 300);
            let k = g.usize_in(1, 8);
            let vecs: Vec<Vec<f32>> = (0..k).map(|_| g.normal_vec(p)).collect();
            let contribs: Vec<Contribution> = vecs
                .iter()
                .enumerate()
                .map(|(i, v)| contrib(i, v, 1 + (g.seed % 100) as u32))
                .collect();
            let out = weighted_mean(&contribs).unwrap();
            for j in 0..p {
                let lo = vecs.iter().map(|v| v[j]).fold(f32::INFINITY, f32::min);
                let hi = vecs.iter().map(|v| v[j]).fold(f32::NEG_INFINITY, f32::max);
                assert!(out[j] >= lo - 1e-5 && out[j] <= hi + 1e-5);
            }
        });
    }

    #[test]
    fn prop_uniform_equals_weighted_when_counts_equal() {
        check("uniform == weighted under equal counts", 50, |g| {
            let p = g.usize_in(1, 200);
            let k = g.usize_in(1, 6);
            let vecs: Vec<Vec<f32>> = (0..k).map(|_| g.normal_vec(p)).collect();
            let cs: Vec<Contribution> =
                vecs.iter().enumerate().map(|(i, v)| contrib(i, v, 42)).collect();
            let a = weighted_mean(&cs).unwrap();
            let b = uniform_mean(&cs).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-6);
            }
        });
    }

    #[test]
    fn masked_zeros_dilute_the_mean() {
        // paper-literal semantics: a masked (zero) entry pulls the average
        // toward zero rather than being skipped
        let a = vec![2.0f32];
        let b = vec![0.0f32]; // masked out at this position
        let out = weighted_mean(&[contrib(0, &a, 1), contrib(1, &b, 1)]).unwrap();
        assert_eq!(out[0], 1.0);
    }

    #[test]
    fn streaming_fold_is_arrival_order_independent_bitwise() {
        check("streaming order independence", 60, |g| {
            let p = g.usize_in(1, 300);
            let k = g.usize_in(2, 10);
            let vecs: Vec<Vec<f32>> = (0..k).map(|_| g.normal_vec(p)).collect();
            let weights: Vec<u32> = (0..k).map(|_| g.usize_in(1, 1000) as u32).collect();
            let contribs: Vec<Contribution> = vecs
                .iter()
                .zip(&weights)
                .enumerate()
                .map(|(i, (v, &w))| contrib(i, v, w))
                .collect();
            let barrier = weighted_mean(&contribs).unwrap();
            // shuffled arrival order
            let mut order: Vec<usize> = (0..k).collect();
            let mut rng = crate::sim::rng::Rng::new(g.seed ^ 0x0bd3b);
            rng.shuffle(&mut order);
            let mut agg = StreamingFedAvg::new(p);
            for &i in &order {
                agg.fold(contribs[i].clone()).unwrap();
            }
            let streamed = Box::new(agg).finish().unwrap();
            assert_eq!(streamed, barrier, "arrival order changed the aggregate");
        });
    }

    #[test]
    fn streaming_state_is_o_p_independent_of_cohort_size() {
        let p = 512;
        let v = vec![1.0f32; p];
        let mut state_sizes = Vec::new();
        for k in [1usize, 8, 64] {
            let mut agg = StreamingFedAvg::new(p);
            for i in 0..k {
                agg.fold(contrib(i, &v, 10)).unwrap();
            }
            assert_eq!(agg.folded(), k);
            state_sizes.push(agg.state_bytes());
        }
        assert_eq!(state_sizes[0], state_sizes[1]);
        assert_eq!(state_sizes[1], state_sizes[2]);
        // while a buffering aggregator grows linearly in k
        let layers = one_layer(p);
        let global = vec![0.0f32; p];
        let mut small = BufferingAttentive::new(&global, &layers, 1.0);
        let mut big = BufferingAttentive::new(&global, &layers, 1.0);
        for i in 0..2 {
            small.fold(contrib(i, &v, 10)).unwrap();
        }
        for i in 0..16 {
            big.fold(contrib(i, &v, 10)).unwrap();
        }
        assert!(big.state_bytes() > small.state_bytes());
    }

    #[test]
    fn buffering_attentive_matches_barrier_attentive_any_order() {
        let p = 32;
        let layers = one_layer(p);
        let global = vec![0.0f32; p];
        let mut g = crate::util::prop::Gen::new(11);
        let vecs: Vec<Vec<f32>> = (0..5).map(|_| g.normal_vec(p)).collect();
        let contribs: Vec<Contribution> =
            vecs.iter().enumerate().map(|(i, v)| contrib(i, v, 7)).collect();
        let barrier = attentive_mean(&global, &contribs, &layers, 0.8).unwrap();
        for order in [[4usize, 2, 0, 3, 1], [1, 3, 0, 2, 4]] {
            let mut agg = BufferingAttentive::new(&global, &layers, 0.8);
            for &i in &order {
                agg.fold(contribs[i].clone()).unwrap();
            }
            let streamed = Box::new(agg).finish().unwrap();
            assert_eq!(streamed, barrier, "order {order:?} changed attentive result");
        }
    }

    #[test]
    fn make_aggregator_dispatches_on_kind() {
        use crate::config::experiment::AggregatorKind;
        let global = vec![0.0f32; 16];
        let layers = one_layer(16);
        let v = vec![2.0f32; 16];
        let mut fedavg = make_aggregator(AggregatorKind::FedAvg, &global, &layers);
        fedavg.fold(contrib(0, &v, 5)).unwrap();
        assert_eq!(fedavg.finish().unwrap(), v);
        let mut attn = make_aggregator(AggregatorKind::Attentive { temp: 1.0 }, &global, &layers);
        attn.fold(contrib(0, &v, 5)).unwrap();
        let out = attn.finish().unwrap();
        for x in out {
            assert!((x - 2.0).abs() < 1e-6);
        }
    }
}
